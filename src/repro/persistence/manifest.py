"""Durable checkpoint-manifest chain + two-level file IO.

This is the paper's structure at framework scale (DESIGN.md §2):

  * the manifest chain is a linked list rooted at the newest committed
    manifest; each manifest's ``prev`` field is the Supplement-2
    *original parent* pointer;
  * :class:`StagedIO` is the two-level memory: writes land in a volatile
    staging area (page cache), ``flush`` marks a file, ``fence`` moves all
    marked files to durable storage — exactly core/pmem.py semantics at
    file granularity, with the same crash adversary (any subset of
    unfenced staged files may have been "evicted" to disk);
  * a checkpoint is *published* by the manifest rename — the single
    atomic pointer swing (the CAS of the critical phase).  A step
    directory without a committed manifest is a marked-but-disconnected
    node: recovery trims it (Supplement 1's ``disconnect``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Dict, Iterable, Optional

import numpy as np

from ..core.pmem import evicted_mask


def _torn_payload(data: bytes, rng) -> bytes:
    """One torn image of ``data``: a strict prefix, tail either gone
    (short write) or bitwise-inverted in place (garbled sectors).  Never
    equal to ``data`` for non-empty payloads — the cut is strictly
    inside — so a "torn" eviction is guaranteed to actually tear."""
    if len(data) == 0:
        return data
    cut = int(rng.integers(0, len(data)))
    if int(rng.integers(0, 2)):
        return data[:cut] + bytes(255 - b for b in data[cut:])
    return data[:cut]


@dataclasses.dataclass
class IOCounters:
    writes: int = 0
    bytes_staged: int = 0
    flushes: int = 0
    fences: int = 0
    bytes_fenced: int = 0

    def snapshot(self):
        return dataclasses.asdict(self)


class StagedIO:
    """Two-level file IO with explicit flush/fence and crash injection."""

    def __init__(self, root: Path, seed: int = 0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._staged: Dict[str, bytes] = {}
        self._flushed: set = set()
        self.counters = IOCounters()
        self._rng = np.random.default_rng(seed)
        # optional repro.robustness.faultinject.CrashPlan: when set,
        # every persistence instruction (flush/fence/publish/trim)
        # reports a crash site before executing (attach via
        # CrashPlan.attach, never set directly).  Recorders that
        # additionally define ``on_event`` (repro.analysis.trace.
        # PersistTrace) receive the full stream, writes included.
        self.faults = None

    def _event(self, kind: str, target: str = "", **meta) -> None:
        """Report one executed instruction to an attached trace recorder."""
        cb = getattr(self.faults, "on_event", None) if self.faults else None
        if cb is not None:
            cb(kind, target, **meta)

    # -- volatile writes -------------------------------------------------- #
    def write(self, rel: str, data: bytes) -> None:
        self._staged[rel] = data
        self.counters.writes += 1
        self.counters.bytes_staged += len(data)
        if self.faults is not None:
            self._event("write", rel)

    def flush(self, rel: str) -> None:
        if rel in self._staged:
            if self.faults is not None:
                self.faults.on_site("flush", rel)
                self._event("flush", rel)
            self._flushed.add(rel)
            self.counters.flushes += 1

    def fence(self) -> None:
        if self.faults is not None:
            self.faults.on_site("fence", "")
            self._event("fence")
        self.counters.fences += 1
        for rel in sorted(self._flushed):
            data = self._staged.pop(rel, None)
            if data is None:
                continue
            path = self.root / rel
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_bytes(data)
            self.counters.bytes_fenced += len(data)
        self._flushed.clear()

    # -- the publish CAS --------------------------------------------------- #
    def publish(self, tmp_rel: str, final_rel: str) -> None:
        """Atomic rename of a durable file — the pointer swing.  The tmp
        file must already be fenced."""
        if self.faults is not None:
            self.faults.on_site("publish", final_rel)
            self._event("publish", final_rel, src=tmp_rel)
        os.replace(self.root / tmp_rel, self.root / final_rel)

    # -- crash adversary --------------------------------------------------- #
    def crash(self, evict: str = "none", p_evict: float = 0.5) -> None:
        """Lose the staging area; a chosen subset of staged-but-unfenced
        files may still have reached disk (background eviction).  The
        eviction policy is the shared seedable adversary
        (:func:`repro.core.pmem.evicted_mask`) applied over staged
        files in sorted order, so DRAM-line and file-staging crash
        models agree — and an unknown mode raises instead of silently
        evicting at random.

        ``evict="torn"`` is the partial-write adversary: a random
        subset reaches disk **torn** — a strict prefix of the payload,
        half the time with the remaining tail bitwise-garbled in place
        instead of truncated — modeling a kill mid-``write(2)``.
        Recovery must treat such a file exactly like a torn record.
        (File-granularity only: the 8-byte-atomic ``PMem`` model keeps
        rejecting the mode, partial cache lines do not exist there.)"""
        staged = sorted(self._staged)
        torn = evict == "torn"
        mask = evicted_mask(len(staged), "random" if torn else evict,
                            self._rng, p_evict)
        for rel, hit in zip(staged, mask):
            if hit:
                data = self._staged[rel]
                if torn:
                    data = _torn_payload(data, self._rng)
                path = self.root / rel
                path.parent.mkdir(parents=True, exist_ok=True)
                path.write_bytes(data)
        self._staged.clear()
        self._flushed.clear()

    # -- durable reads ----------------------------------------------------- #
    def read(self, rel: str) -> bytes:
        return (self.root / rel).read_bytes()

    def exists(self, rel: str) -> bool:
        return (self.root / rel).exists()

    def unlink(self, rel: str) -> None:
        """Remove one durable file (snapshot truncation, journal GC).
        A trim is a crash site too: recovery must tolerate a kill
        between any two unlinks of a truncation pass."""
        if self.faults is not None:
            self.faults.on_site("trim", rel)
            self._event("trim", rel)
        (self.root / rel).unlink(missing_ok=True)

    def remove_tree(self, rel: str) -> None:
        if self.faults is not None:
            self.faults.on_site("trim", rel)
            self._event("trim", rel)
        shutil.rmtree(self.root / rel, ignore_errors=True)


def digest(data: bytes) -> str:
    return hashlib.sha1(data).hexdigest()


@dataclasses.dataclass
class Manifest:
    step: int
    prev: Optional[int]
    files: Dict[str, dict]          # leaf path -> {"file","digest","owner"}
    aux: dict                       # data cursor, rng, mesh note, ...

    def to_bytes(self) -> bytes:
        return json.dumps(dataclasses.asdict(self), sort_keys=True).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "Manifest":
        d = json.loads(b.decode())
        return Manifest(step=d["step"], prev=d["prev"], files=d["files"],
                        aux=d.get("aux", {}))


def manifest_rel(step: int) -> str:
    return f"step_{step:08d}/MANIFEST.json"


def list_step_dirs(root: Path) -> Iterable[int]:
    for p in sorted(Path(root).glob("step_*")):
        try:
            yield int(p.name.split("_")[1])
        except (IndexError, ValueError):
            continue
