"""NVTraverse-style checkpoint manager (+ Izraelevitz-style baseline).

Commit protocol for ``save(step, tree, aux)`` — Protocols 1+2 at framework
scale:

  1. *node initialization*: write each changed leaf to the step dir and
     flush it (flush-after-local-write; no fence yet);
  2. *makePersistent / delta*: only leaves whose digest differs from the
     parent manifest are written at all — unchanged leaves reference the
     parent's file (the journey is not persisted);
  3. *ensureReachable*: the manifest (carrying the ``prev`` pointer that
     links this step into the recoverable chain) is written + flushed;
  4. **one fence**, then the atomic manifest rename (the publish CAS).

``policy="izraelevitz"`` instead fences after every single write — the
general-transform baseline the paper compares against; the benchmark
(benchmarks/checkpoint_bench.py) reports the fsync economy.

Recovery (:meth:`recover`) is ``disconnect(root)``: every step directory
that is not the target of a committed-manifest chain walk is a
marked-but-disconnected node and is trimmed; auxiliary volatile state
(compiled fns, data iterators) is rebuilt by the caller from ``aux``.

Mesh-agnostic: leaves are stored as logical full arrays (np.save bytes);
``restore(shardings=...)`` device_puts onto any new mesh — elastic
restarts re-shard freely.
"""
from __future__ import annotations

import io
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from .index import MembershipIndex, live_step_index
from .manifest import (Manifest, StagedIO, digest, list_step_dirs,
                       manifest_rel)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[name] = np.asarray(leaf)
    return flat


def _leaf_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _leaf_from_bytes(b: bytes) -> np.ndarray:
    return np.load(io.BytesIO(b), allow_pickle=False)


class CheckpointManager:
    def __init__(self, root, *, policy: str = "nvtraverse", seed: int = 0,
                 faults=None):
        """``faults`` (optional) attaches a
        :class:`repro.robustness.faultinject.CrashPlan` to the manager's
        IO, making every flush/fence/publish/trim of save()/gc() an
        enumerable crash site — the systematic generalization of the
        hand-picked ``crash_after`` hooks in :meth:`save`."""
        assert policy in ("nvtraverse", "izraelevitz")
        self.io = StagedIO(Path(root), seed=seed)
        if faults is not None:
            faults.attach(self.io)
        self.policy = policy
        self._last_manifest: Optional[Manifest] = None
        # live-step membership index, kept current across recover()/gc()
        # passes by mixed add/remove rounds instead of per-pass rebuilds
        self._step_index = MembershipIndex()

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, aux: Optional[dict] = None,
             *, crash_after: Optional[str] = None) -> Manifest:
        """Commit a checkpoint.  ``crash_after`` ∈ {"shards", "manifest",
        None} injects a crash for the durability tests (before the fence /
        before the publish rename respectively)."""
        flat = _flatten(tree)
        parent = self._last_manifest
        files = {}
        sdir = f"step_{step:08d}"
        for name, arr in flat.items():
            data = _leaf_bytes(arr)
            d = digest(data)
            if (parent is not None and name in parent.files
                    and parent.files[name]["digest"] == d):
                # unchanged since parent: reference, don't rewrite
                ref = dict(parent.files[name])
                ref["owner"] = ref.get("owner", parent.step)
                files[name] = ref
                continue
            rel = f"{sdir}/{name.replace('/', '_')}.npy"
            self.io.write(rel, data)
            self.io.flush(rel)
            if self.policy == "izraelevitz":
                self.io.fence()          # fence per write: the baseline
            files[name] = {"file": rel, "digest": d, "owner": step}
        if crash_after == "shards":
            return None
        man = Manifest(step=step, prev=(parent.step if parent else None),
                       files=files, aux=aux or {})
        tmp_rel = f"{sdir}/MANIFEST.tmp"
        self.io.write(tmp_rel, man.to_bytes())
        self.io.flush(tmp_rel)           # ensureReachable: the prev-link
        self.io.fence()                  # THE single fence
        if crash_after == "manifest":
            return None
        self.io.publish(tmp_rel, manifest_rel(step))   # the CAS
        self._last_manifest = man
        return man

    # ------------------------------------------------------------------ #
    def recover(self) -> Optional[Manifest]:
        """disconnect(root): trim every uncommitted step dir, return the
        newest committed manifest (head of the recoverable chain)."""
        committed = {}
        for step in list_step_dirs(self.io.root):
            rel = manifest_rel(step)
            if self.io.exists(rel):
                try:
                    committed[step] = Manifest.from_bytes(self.io.read(rel))
                except Exception:
                    continue            # torn manifest: treat as marked
        # a manifest is valid iff every referenced file verifies — the file
        # digests carry the full dependency closure (durable linearizability:
        # an op's effects require its dependencies), and remain checkable
        # even after older manifests are garbage-collected.
        valid: Dict[int, Manifest] = {}
        for step in sorted(committed):
            man = committed[step]
            ok = all(self.io.exists(info["file"])
                     and digest(self.io.read(info["file"])) == info["digest"]
                     for info in man.files.values())
            if ok:
                valid[step] = man
        head = valid[max(valid)] if valid else None
        # trim marked nodes: uncommitted or invalid step dirs not
        # referenced by the surviving chain.
        self._trim_dead(list(valid.values()),
                        list(list_step_dirs(self.io.root)))
        self._last_manifest = head
        return head

    def _trim_dead(self, manifests, candidates) -> None:
        """Remove every candidate step dir that no surviving manifest
        commits or delta-references.  Liveness is a membership probe on
        the durable-map manifest index (persistence/index.py); the index
        is updated in place — newly dead steps are trimmed from the live
        index by one mixed insert/delete round, not a rebuild."""
        keep_files = set()
        for man in manifests:
            keep_files.update(info["file"] for info in man.files.values())
        idx = live_step_index(manifests, keep_files, self._step_index)
        for step, alive in zip(candidates, idx.contains(candidates)):
            if not alive:
                self.io.remove_tree(f"step_{step:08d}")

    # ------------------------------------------------------------------ #
    def restore(self, tree_like, *, shardings=None):
        """Restore the newest committed checkpoint into ``tree_like``'s
        structure; optional shardings tree re-shards onto any mesh."""
        man = self.recover()
        if man is None:
            return None, None
        flat_like = _flatten(tree_like)
        restored = {}
        for name in flat_like:
            info = man.files[name]
            restored[name] = _leaf_from_bytes(self.io.read(info["file"]))
        # rebuild the pytree in original structure
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(
            tree_like)
        names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path) for path, _ in leaves_paths]
        leaves = [restored[n] for n in names]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            leaves = [jax.device_put(l, s)
                      for l, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.numpy.asarray(l) for l in leaves]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return man, tree

    def gc(self, keep: int = 2) -> None:
        """Drop all but the newest ``keep`` committed checkpoints (never
        breaking delta-references of the survivors)."""
        man = self.recover()
        if man is None:
            return
        steps = sorted(s for s in list_step_dirs(self.io.root)
                       if self.io.exists(manifest_rel(s)))
        manifests = [Manifest.from_bytes(self.io.read(manifest_rel(s)))
                     for s in steps[-keep:]]
        self._trim_dead(manifests, steps[:-keep])
