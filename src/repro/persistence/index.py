"""Checkpoint-manifest index on the JAX-native durable map.

Recovery and GC both answer the same set-membership question over step
directories — "is this step committed, or does a surviving manifest
reference a file it owns?"  At a few checkpoints the Python-set answer is
free; at production retention depths (thousands of delta-chained steps ×
dozens of shards) it is a hash-map workload, so it runs on the same
plan/commit engine (:mod:`repro.core.batched`) the serving path uses:
one ``insert_parallel`` batch to build the index (the commit), one
``vmap``'d :func:`repro.core.batched.lookup` batch to classify every
step dir (the journey — zero persistence work).
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import batched

N_BUCKETS = 128


def owner_step(rel: str) -> int:
    """Owner step of a manifest-referenced file path (``step_XXXXXXXX/…``)."""
    return int(rel.split("/", 1)[0].split("_")[1])


class MembershipIndex:
    """Growable set-membership index on the durable map.

    Keys are arbitrary ints.  Keys in ``[0, 2**31-2]`` are stored in the
    int32-keyed durable map as ``key + 1`` (node id 0 is the map's
    reserved null, so key 0 is avoided); the rare out-of-range key falls
    back to a Python-set side table rather than silently wrapping (the
    dict probe this index replaces took arbitrary ints).  The node pool
    doubles when a batch would not fit — ``insert_parallel`` fails
    cleanly on exhaustion rather than corrupting chains, but an index
    must never drop members, so growth happens *before* the commit."""

    def __init__(self, capacity: int = 4096, n_buckets: int = N_BUCKETS):
        self.n_buckets = n_buckets
        self.capacity = capacity
        self.state = batched.make_state(capacity, n_buckets)
        self._keys = np.zeros(0, np.int32)       # members, for rebuilds
        self._members: set = set()               # same, for O(1) add dedup
        self._oob: set = set()     # members outside the int32 key space
        self.last_stats = None

    @staticmethod
    def _in_range(k: int) -> bool:
        return 0 <= k < 2**31 - 1

    @staticmethod
    def _pad_pow2(ks: np.ndarray) -> np.ndarray:
        """Pad a key batch to the next power of two with a duplicate of
        its first key, capping jit retraces at one per (log2 size,
        capacity) instead of one per distinct batch length.  Duplicates
        never commit, so padding is invisible to the map."""
        n = max(1, 1 << (ks.size - 1).bit_length())
        return np.concatenate([ks, np.full(n - ks.size, ks[0], np.int32)])

    def add(self, keys: Iterable[int]) -> None:
        keys = {int(k) for k in keys}
        self._oob.update(k for k in keys if not self._in_range(k))
        # already-members are a no-op; the set probe keeps the dedup
        # O(batch) instead of np.isin's O(members) re-scan per add
        ks = np.asarray(sorted(k for k in keys if self._in_range(k)
                               and k not in self._members), np.int32)
        if ks.size == 0:
            return
        # cursor starts at 1; worst case every key in the batch is fresh
        needed = 1 + self._keys.size + ks.size
        if needed > self.capacity:
            while needed > self.capacity:
                self.capacity *= 2
            self.state = batched.make_state(self.capacity, self.n_buckets)
            if self._keys.size:
                old = jnp.asarray(self._pad_pow2(self._keys) + 1)
                self.state, _, _ = batched.insert_parallel(
                    self.state, old, old, self.n_buckets)
        n = ks.size
        padded = self._pad_pow2(ks)
        self.state, ok, self.last_stats = batched.insert_parallel(
            self.state, jnp.asarray(padded + 1), jnp.asarray(padded + 1),
            self.n_buckets)
        committed = ks[np.asarray(ok)[:n]]
        self._keys = np.concatenate([self._keys, committed])
        self._members.update(int(k) for k in committed)

    def contains(self, keys: Sequence[int]) -> np.ndarray:
        keys = [int(k) for k in keys]
        out = np.zeros(len(keys), np.bool_)
        in_range = [(i, k) for i, k in enumerate(keys)
                    if self._in_range(k)]
        if in_range:
            pos, ks = zip(*in_range)
            ks = np.asarray(ks, np.int32)
            found, _ = batched.lookup(
                self.state, jnp.asarray(self._pad_pow2(ks) + 1),
                self.n_buckets)
            out[list(pos)] = np.asarray(found)[:ks.size]
        for i, k in enumerate(keys):
            if not self._in_range(k):
                out[i] = k in self._oob
        return out


def live_step_index(manifests, keep_files: Iterable[str]) -> MembershipIndex:
    """Index of every step that must survive a trim pass: steps with a
    valid/surviving manifest plus owner steps of all delta-referenced
    files (an old step stays alive while any survivor references it)."""
    idx = MembershipIndex()
    steps = set()
    for man in manifests:
        steps.add(man.step)
    for rel in keep_files:
        steps.add(owner_step(rel))
    idx.add(steps)
    return idx
