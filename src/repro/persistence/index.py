"""Checkpoint-manifest index on the JAX-native durable map.

Recovery and GC both answer the same set-membership question over step
directories — "is this step committed, or does a surviving manifest
reference a file it owns?"  At a few checkpoints the Python-set answer is
free; at production retention depths (thousands of delta-chained steps ×
dozens of shards) it is a hash-map workload, so it runs on the same
plan/commit engine (:mod:`repro.core.batched`) the serving path uses:
one mixed ``update_parallel`` batch keeps the index current (new live
steps enter, dead steps leave — one commit round), one ``vmap``'d
:func:`repro.core.batched.lookup` batch classifies every step dir (the
journey — zero persistence work).

The map behind the index is pluggable: the default is the single-device
engine; ``n_shards`` switches to the bucket-range-sharded
:class:`repro.core.sharded.ShardedDurableMap` (same add/remove/update
API, commits stay per-shard-local) for multi-device deployments.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core import batched

N_BUCKETS = 128


def owner_step(rel: str) -> int:
    """Owner step of a manifest-referenced file path (``step_XXXXXXXX/…``)."""
    return int(rel.split("/", 1)[0].split("_")[1])


def _pad_pow2(xs: np.ndarray) -> np.ndarray:
    """Pad a batch to the next power of two with duplicates of its
    *last* element, capping jit retraces at one per (log2 size,
    capacity) instead of one per distinct batch length.  A duplicate
    of the batch's last op never commits — after an insert the key is
    live (a repeat insert fails), after a delete it is dead (a repeat
    delete fails) — so padding is invisible to the map.  Duplicating
    the *first* op would not be safe in a mixed batch: an insert
    replayed after a later delete of the same key would resurrect
    it."""
    n = max(1, 1 << (xs.size - 1).bit_length())
    return np.concatenate([xs, np.full(n - xs.size, xs[-1], xs.dtype)])


class _SingleBackend:
    """The single-device plan/commit engine behind the index."""

    def __init__(self, capacity: int, n_buckets: int):
        self.capacity = capacity
        self.n_buckets = n_buckets
        self.state = batched.make_state(capacity, n_buckets)
        self.migrations = 0

    def fits(self, ks: np.ndarray) -> bool:
        """Exact fit check for a batch of fresh-insert keys: only keys
        without a node (live *or* dead — a removed key keeps its node
        and is resurrected in place) allocate.  The probe (a device
        round-trip) only runs when the batch-size upper bound does not
        already prove fitness — the steady-state cost is one int
        comparison."""
        if int(self.state.cursor) + ks.size <= self.capacity:
            return True
        ex, _, _ = batched.probe(
            self.state, jnp.asarray(_pad_pow2(ks)), self.n_buckets)
        n_fresh = int((~np.asarray(ex)[:ks.size]).sum())
        return int(self.state.cursor) + n_fresh <= self.capacity

    def grow_for(self, ks: np.ndarray) -> None:
        """Online growth: migrate to a doubled pool (and doubled bucket
        count — a rehash) in bounded NVTraverse-correct rounds until the
        batch fits.  Dead nodes are compacted away by the drain, so one
        doubling usually suffices."""
        from ..core.migrate import migrate_state
        from ..obs.compile import get_tracker
        from ..obs.metrics import get_registry
        while not self.fits(ks):
            nb_old = self.n_buckets
            self.capacity *= 2
            self.n_buckets *= 2
            with get_tracker().reason("capacity_ladder"):
                self.state, _ = migrate_state(
                    self.state, nb_old, self.capacity, self.n_buckets)
            self.migrations += 1   # shim; registry mirror:
            get_registry().counter("dedup_migrations_total").inc()

    def update(self, ops: np.ndarray, ks: np.ndarray):
        pk = jnp.asarray(_pad_pow2(ks))
        self.state, ok, stats = batched.update_parallel(
            self.state, jnp.asarray(_pad_pow2(ops)), pk, pk,
            self.n_buckets)
        return np.asarray(ok)[:ks.size], stats

    def insert(self, ks: np.ndarray) -> np.ndarray:
        pk = jnp.asarray(_pad_pow2(ks))
        self.state, ok, _ = batched.insert_parallel(
            self.state, pk, pk, self.n_buckets)
        return np.asarray(ok)[:ks.size]

    def lookup(self, ks: np.ndarray) -> np.ndarray:
        found, _ = batched.lookup(
            self.state, jnp.asarray(_pad_pow2(ks)), self.n_buckets)
        return np.asarray(found)[:ks.size]


class _ShardedBackend:
    """Bucket-range-sharded map behind the index (multi-device).

    With ``auto_rebalance`` the map is a
    :class:`repro.core.rebalance.RebalancingShardedMap`: skewed member
    streams re-split the bucket-range boundaries *under live index
    traffic* (no stop-the-world drain), and growth runs through the same
    live machinery (finish any in-flight re-split, then migrate)."""

    def __init__(self, capacity: int, n_buckets: int, n_shards: int,
                 mesh=None, auto_rebalance: bool = False):
        if auto_rebalance:
            from ..core.rebalance import (AutoRebalancePolicy,
                                          RebalancingShardedMap)
            self.map = RebalancingShardedMap(
                n_shards, capacity=capacity, n_buckets=n_buckets,
                mesh=mesh, policy=AutoRebalancePolicy())
        else:
            from ..core.sharded import ShardedDurableMap
            self.map = ShardedDurableMap(
                n_shards, capacity=capacity, n_buckets=n_buckets,
                mesh=mesh)
        self._live = auto_rebalance
        self.migrations = 0

    @property
    def rebalances(self) -> int:
        return self.map.rebalances_completed if self._live else 0

    @property
    def state(self):
        return self.map.state

    @property
    def capacity(self) -> int:
        return self.map.cap_local * self.map.n_shards

    @property
    def n_buckets(self) -> int:
        return self.map.n_buckets

    def fits(self, ks: np.ndarray) -> bool:
        """Exact *per-shard* fit check: only keys without a node (live
        or dead — a removed key's node is resurrected in place)
        allocate, and each one burdens exactly its owner shard, so
        compare per-shard demand against each shard's own free pool —
        not the old fullest-shard-times-whole-batch worst case.  The
        mesh probe only runs when the batch-size upper bound does not
        already prove fitness.  (The live-rebalance map keeps the check
        exact mid-re-split: its ``cursors`` include the un-drained
        reserve, and its ``fresh_demand`` counts a key whose only node
        is a dead one in the frozen old map as allocating — the merged
        probe's ``exists`` would wrongly exclude it.)"""
        cursors = self.map.cursors
        if int(cursors.max()) + ks.size <= self.map.cap_local:
            return True
        demand = self.map.fresh_demand(np.unique(ks))
        return bool((cursors + demand <= self.map.cap_local).all())

    def grow_for(self, ks: np.ndarray) -> None:
        """Online growth over the mesh: migrate every chain to a map
        with doubled per-shard pools (and doubled bucket count) via the
        bounded drain rounds of
        :meth:`repro.core.sharded.ShardedDurableMap.migrate_to` until
        the batch fits each owner shard."""
        from ..obs.metrics import get_registry
        while not self.fits(ks):
            cap = 2 * self.map.cap_local * self.map.n_shards
            nb = 2 * self.map.n_buckets
            if self._live:
                self.map.grow_to(capacity=cap, n_buckets=nb)
            else:
                self.map, _ = self.map.migrate_to(capacity=cap,
                                                  n_buckets=nb)
            self.migrations += 1   # shim; registry mirror:
            get_registry().counter("dedup_migrations_total").inc()

    def update(self, ops: np.ndarray, ks: np.ndarray):
        return self.map.update(ops, ks, ks)

    def insert(self, ks: np.ndarray) -> np.ndarray:
        return self.map.insert(ks, ks)[0]

    def lookup(self, ks: np.ndarray) -> np.ndarray:
        found, _ = self.map.lookup(ks)
        return found


class MembershipIndex:
    """Growable set-membership index on the durable map.

    Keys are arbitrary ints.  Keys in ``[0, 2**31-2]`` are stored in the
    int32-keyed durable map as ``key + 1`` (node id 0 is the map's
    reserved null, so key 0 is avoided); the rare out-of-range key falls
    back to a Python-set side table rather than silently wrapping (the
    dict probe this index replaces took arbitrary ints).

    :meth:`update` commits adds *and* removes in one mixed plan/commit
    round (``batched.update_parallel``): removes are logical deletes on
    the durable map, so a removed key's node slot is reclaimed by
    resurrection if the key ever returns.  When a batch's fresh inserts
    would not fit — checked *exactly*, per owner shard on the sharded
    backend — the backend grows online: its chains migrate into a
    doubled (pool × buckets) map via the bounded drain rounds of
    :mod:`repro.core.migrate` / the sharded ``migrate_to``, before the
    commit, so an index never drops members (``update_parallel`` fails
    cleanly on exhaustion rather than corrupting chains) and removed
    members' dead nodes are compacted away by the drain.

    ``n_shards`` (optional) runs the map bucket-range-sharded across
    that many devices (:class:`repro.core.sharded.ShardedDurableMap`)
    with the identical public API; ``mesh`` overrides the auto-built
    1-D shard mesh.  ``auto_rebalance`` (sharded backend only) swaps
    the map for a :class:`repro.core.rebalance.RebalancingShardedMap`
    so skewed member streams re-split the bucket-range boundaries under
    live index traffic (:attr:`rebalances` counts completions)."""

    def __init__(self, capacity: int = 4096, n_buckets: int = N_BUCKETS,
                 n_shards: Optional[int] = None, mesh=None,
                 auto_rebalance: bool = False):
        self.n_buckets = n_buckets
        self.capacity = capacity
        self.n_shards = n_shards
        self._mesh = mesh
        self._auto_rebalance = auto_rebalance
        self._backend = self._make_backend(capacity)
        self._members: set = set()               # live in-range members
        self._oob: set = set()     # members outside the int32 key space
        self.last_stats = None

    def _make_backend(self, capacity: int):
        if self.n_shards is None:
            return _SingleBackend(capacity, self.n_buckets)
        return _ShardedBackend(capacity, self.n_buckets, self.n_shards,
                               self._mesh,
                               auto_rebalance=self._auto_rebalance)

    @property
    def state(self):
        """The backing map state (single-device ``HashMapState`` or the
        sharded ``ShardedState``)."""
        return self._backend.state

    @property
    def migrations(self) -> int:
        """Online growth migrations the backend has run so far."""
        return self._backend.migrations

    @property
    def rebalances(self) -> int:
        """Live cross-shard re-splits completed (0 unless the backend
        was opted in with ``auto_rebalance``)."""
        return getattr(self._backend, "rebalances", 0)

    @staticmethod
    def _in_range(k: int) -> bool:
        return 0 <= k < 2**31 - 1

    @property
    def members(self) -> set:
        """The current member set (copy), side-table keys included."""
        return self._members | self._oob

    def update(self, add_keys: Iterable[int] = (),
               remove_keys: Iterable[int] = ()) -> None:
        """Commit adds and removes in one mixed plan/commit round.

        Batch order is adds-then-removes, so a key named in both leaves
        the index (the remove wins)."""
        adds = {int(k) for k in add_keys}
        rems = {int(k) for k in remove_keys}
        self._oob.update(k for k in adds if not self._in_range(k))
        self._oob.difference_update(k for k in rems
                                    if not self._in_range(k))
        # already-members / non-members are no-ops; the set probes keep
        # the dedup O(batch) instead of an O(members) re-scan per call
        ins_set = {k for k in adds
                   if self._in_range(k) and k not in self._members}
        del_set = {k for k in rems if self._in_range(k)
                   and (k in self._members or k in ins_set)}
        ins = np.asarray(sorted(ins_set), np.int32)
        dels = np.asarray(sorted(del_set), np.int32)
        if ins.size + dels.size == 0:
            return
        if not self._backend.fits(ins + 1):
            # online growth: the backend migrates its chains into a
            # doubled (pool × buckets) map in bounded NVTraverse-correct
            # rounds — no stop-the-world rebuild, no re-insert retry
            # loop.  The fit check is exact (per shard, for the sharded
            # backend), so growth runs exactly when a shard would
            # actually overflow; migration drains only live keys, so
            # removed members' dead nodes are compacted away for free.
            self._backend.grow_for(ins + 1)
            self.capacity = self._backend.capacity
        ks = np.concatenate([ins, dels]) + 1
        ops = np.concatenate([
            np.full(ins.size, batched.OP_INSERT, np.int32),
            np.full(dels.size, batched.OP_DELETE, np.int32)])
        okh, self.last_stats = self._backend.update(ops, ks)
        # an index never drops members: every planned insert is a
        # non-member (dedup above) and growth ran before the commit, so
        # a failed insert here can only mean the growth math is wrong
        assert okh[:ins.size].all(), "membership insert dropped"
        self._members.update(int(k) for k in ins[okh[:ins.size]])
        self._members.difference_update(
            int(k) for k in dels[okh[ins.size:]])

    def add(self, keys: Iterable[int]) -> None:
        self.update(add_keys=keys)

    def remove(self, keys: Iterable[int]) -> None:
        """Logical batched delete on the same engine; a later re-add of
        the key resurrects its node in place (no fresh allocation)."""
        self.update(remove_keys=keys)

    def contains(self, keys: Sequence[int]) -> np.ndarray:
        keys = [int(k) for k in keys]
        out = np.zeros(len(keys), np.bool_)
        in_range = [(i, k) for i, k in enumerate(keys)
                    if self._in_range(k)]
        if in_range:
            pos, ks = zip(*in_range)
            ks = np.asarray(ks, np.int32)
            out[list(pos)] = self._backend.lookup(ks + 1)
        for i, k in enumerate(keys):
            if not self._in_range(k):
                out[i] = k in self._oob
        return out


class OrderedMembershipIndex:
    """Membership index on the batch-parallel *ordered* engine
    (:mod:`repro.core.ordered`) — the same ``update``/``contains``/
    ``members`` surface as :class:`MembershipIndex`, plus the ordered
    primitives a retention policy wants: :meth:`expired` answers "which
    members fall below the retention horizon?" with one tower descent +
    range walk over the sorted bottom list instead of materializing and
    sorting the whole member set, and :meth:`range_members` exposes the
    underlying ordered scan.  Used by the serving
    :class:`~repro.serving.engine.RequestLog` ``ordered_dedup`` mode,
    where keys are request ids and the eviction horizon is an
    ordered-by-rid trim.  Ordered primitives cover in-range keys only
    (side-table keys have no position in the bottom list).

    Same int32 key envelope as the hash-backed index: in-range keys are
    stored shifted by +1 (node 0 is the ordered map's head sentinel),
    out-of-range keys fall back to a side set.  Growth doubles the node
    pool and rebuilds from the live member set host-side (the ordered
    pool has no migration engine yet — :attr:`migrations` counts these
    rebuilds so callers can see them)."""

    rebalances = 0      # single-device pool: never re-splits

    def __init__(self, capacity: int = 4096, max_level: int = 8):
        from ..core import ordered
        self._ord = ordered
        self.capacity = capacity
        self.max_level = max_level
        self.state = ordered.make_ordered(capacity)
        self._towers = ordered.build_towers(self.state, max_level)
        self._members: set = set()
        self._oob: set = set()
        self.migrations = 0
        self.last_stats = None

    _in_range = staticmethod(MembershipIndex._in_range)

    @property
    def members(self) -> set:
        return self._members | self._oob

    def _grow_for(self, n_fresh: int) -> None:
        need = int(self.state.cursor) + n_fresh
        while self.capacity < need:
            self.capacity *= 2
        self.state = self._ord.make_ordered(self.capacity)
        live = np.asarray(sorted(self._members), np.int32)
        if live.size:
            self.state, ok, _ = self._ord.update_parallel_ordered(
                self.state, np.zeros(live.size, np.int32), live + 1,
                live + 1, max_level=self.max_level)
            assert bool(np.asarray(ok).all())
        self._towers = self._ord.build_towers(self.state, self.max_level)
        self.migrations += 1

    def update(self, add_keys: Iterable[int] = (),
               remove_keys: Iterable[int] = ()) -> None:
        """One mixed plan/commit round; a key named in both leaves
        (adds batch first, removes last — the remove wins)."""
        adds = {int(k) for k in add_keys}
        rems = {int(k) for k in remove_keys}
        self._oob.update(k for k in adds if not self._in_range(k))
        self._oob.difference_update(k for k in rems
                                    if not self._in_range(k))
        ins_set = {k for k in adds
                   if self._in_range(k) and k not in self._members}
        del_set = {k for k in rems if self._in_range(k)
                   and (k in self._members or k in ins_set)}
        ins = np.asarray(sorted(ins_set), np.int32)
        dels = np.asarray(sorted(del_set), np.int32)
        if ins.size + dels.size == 0:
            return
        if int(self.state.cursor) + ins.size > self.capacity:
            # upper bound is exact here: every planned insert is a
            # non-member, and dead nodes resurrect without allocating
            n_dead = len(self._dead_keys() & ins_set)
            if int(self.state.cursor) + ins.size - n_dead > self.capacity:
                self._grow_for(ins.size - n_dead)
        ks = np.concatenate([ins, dels]) + 1
        ops = np.concatenate([
            np.full(ins.size, batched.OP_INSERT, np.int32),
            np.full(dels.size, batched.OP_DELETE, np.int32)])
        self.state, ok, self.last_stats = \
            self._ord.update_parallel_ordered(
                self.state, ops, ks, ks, towers=self._towers,
                max_level=self.max_level)
        ok = np.asarray(ok)
        assert ok[:ins.size].all(), "ordered membership insert dropped"
        self._towers = self._ord.build_towers(self.state, self.max_level)
        self._members.update(int(k) for k in ins[ok[:ins.size]])
        self._members.difference_update(
            int(k) for k in dels[ok[ins.size:]])

    def _dead_keys(self) -> set:
        return {k - 1 for k, (lv, _) in
                self._ord.items_host(self.state).items() if not lv}

    def add(self, keys: Iterable[int]) -> None:
        self.update(add_keys=keys)

    def remove(self, keys: Iterable[int]) -> None:
        self.update(remove_keys=keys)

    def contains(self, keys: Sequence[int]) -> np.ndarray:
        keys = [int(k) for k in keys]
        out = np.zeros(len(keys), np.bool_)
        in_range = [(i, k) for i, k in enumerate(keys)
                    if self._in_range(k)]
        if in_range:
            pos, ks = zip(*in_range)
            found, _ = self._ord.lookup_ordered(
                self.state, jnp.asarray(ks, jnp.int32) + 1,
                self._towers)
            out[list(pos)] = np.asarray(found)
        for i, k in enumerate(keys):
            if not self._in_range(k):
                out[i] = k in self._oob
        return out

    def range_members(self, lo: int, hi: int, max_items: int) -> list:
        """Ascending live members in ``[lo, hi]`` (ordered scan —
        a pure journey)."""
        total, ks, _ = self._ord.range_query(
            self.state, lo + 1, hi + 1, max_items, self._towers)
        m = min(int(total), max_items)
        return [int(k) - 1 for k in np.asarray(ks)[:m]]

    def expired(self, retain: int) -> list:
        """Members below the retention horizon, ascending: everything
        but the ``retain`` largest — one :func:`repro.core.ordered.
        top_k` walk finds the horizon, one tower-descended range walk
        collects the victims.  The ordered analogue of the request
        log's insertion-order window (identical for monotone keys)."""
        n_live = len(self._members)
        n_evict = n_live - retain
        if n_evict <= 0:
            return []
        cnt, tk, _ = self._ord.top_k(self.state, retain + 1)
        if int(cnt) <= retain:               # fewer live than retain+1
            return []
        # tk is ascending: tk[0] is the (retain+1)-th largest stored
        # key — the largest member that must be evicted (inclusive)
        horizon = int(np.asarray(tk)[0])
        return self.range_members(self._ord.KEY_MIN, horizon - 1,
                                  n_evict)


def live_step_index(manifests, keep_files: Iterable[str],
                    idx: Optional[MembershipIndex] = None
                    ) -> MembershipIndex:
    """Index of every step that must survive a trim pass: steps with a
    valid/surviving manifest plus owner steps of all delta-referenced
    files (an old step stays alive while any survivor references it).

    When ``idx`` is given it is updated *in place* — newly live steps
    enter and since-died steps leave in one mixed plan/commit round —
    instead of rebuilding the map from scratch per pass."""
    steps = set()
    for man in manifests:
        steps.add(man.step)
    for rel in keep_files:
        steps.add(owner_step(rel))
    if idx is None:
        idx = MembershipIndex()
    idx.update(steps, idx.members - steps)
    return idx
