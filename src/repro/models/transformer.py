"""Layer stacks for every assigned family, built around ``lax.scan`` over
stacked per-layer parameters (small HLO, remat-friendly).

  * dense / vlm:  [attn → mlp] × L, optional local:global window pattern
    (gemma3) expressed as a *traced* window size inside one scanned block;
  * moe:          [attn → moe_ffn (+shared/+dense-residual)] × L;
  * ssm:          [mamba2 SSD] × L;
  * hybrid:       mamba2 backbone with a tied shared-attention block every
    k-th layer (zamba2) — the shared block's per-invocation KV caches ride
    in the scan carry;
  * encdec:       bidirectional encoder stack + causal decoder stack with
    cross-attention (whisper).

Remat: ``cfg.remat == "block"`` checkpoints each scanned block — the
standard activation policy for long stacks (§Perf iterates on it).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import (attn_params, cross_attention, cross_kv, mlp,
                     mlp_params, rms_norm, self_attention)
from .mamba2 import SSMCache, init_ssm_cache, mamba_block, mamba_params
from .moe import moe_ffn, moe_params


# --------------------------------------------------------------------- #
# single blocks                                                           #
# --------------------------------------------------------------------- #
def _sp(x, cfg, mode):
    """Sequence-parallel residual stream (Megatron-SP as a GSPMD
    constraint): shard the sequence dim of the per-block activations over
    the model axis, turning the two TP all-reduces per layer into
    reduce-scatter + all-gather pairs at half the volume (§Perf)."""
    if not getattr(cfg, "sp", False) or mode == "decode":
        return x
    from ..sharding.constraints import batch_axes, constrain
    return constrain(x, batch_axes(), "model", None)


def dense_block(p, x, cfg, *, positions, mode, window=None,
                cache=None, cache_pos=None):
    x = _sp(x, cfg, mode)
    h, new_cache = self_attention(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                  cfg, positions=positions, mode=mode,
                                  window=window, cache=cache,
                                  cache_pos=cache_pos)
    x = x + _sp(h, cfg, mode)
    x = x + _sp(mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act),
                cfg, mode)
    return x, new_cache


def moe_block(p, x, cfg, *, positions, mode, cache=None, cache_pos=None):
    x = _sp(x, cfg, mode)
    h, new_cache = self_attention(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                  cfg, positions=positions, mode=mode,
                                  cache=cache, cache_pos=cache_pos)
    x = x + _sp(h, cfg, mode)
    y, aux = moe_ffn(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x + _sp(y, cfg, mode), new_cache, aux


def encdec_block(p, x, cfg, *, positions, mode, cache=None, cache_pos=None,
                 enc_out=None, xa_cache=None):
    h, new_cache = self_attention(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                  cfg, positions=positions, mode=mode,
                                  cache=cache, cache_pos=cache_pos)
    x = x + h
    h, xa_kv = cross_attention(p["xattn"], rms_norm(x, p["ln_x"], cfg.norm_eps),
                               cfg, kv=enc_out, kv_cache=xa_cache)
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x, new_cache, xa_kv


# --------------------------------------------------------------------- #
# parameter builders                                                     #
# --------------------------------------------------------------------- #
def dense_block_params(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": attn_params(k1, cfg, dtype),
            "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, dtype, cfg.act,
                              fused=getattr(cfg, "fused_gate_up", False))}


def moe_block_params(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": attn_params(k1, cfg, dtype),
            "moe": moe_params(k2, cfg, dtype)}


def encdec_block_params(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "ln_x": jnp.zeros((cfg.d_model,), dtype),
            "attn": attn_params(k1, cfg, dtype),
            "xattn": attn_params(k2, cfg, dtype),
            "mlp": mlp_params(k3, cfg.d_model, cfg.d_ff, dtype, cfg.act,
                              fused=getattr(cfg, "fused_gate_up", False))}


def stacked_params(key, n: int, builder, cfg, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: builder(k, cfg, dtype))(keys)


# --------------------------------------------------------------------- #
# scanned stacks                                                          #
# --------------------------------------------------------------------- #
def unrolled_scan(f, init, xs, *, length: int):
    """lax.scan-compatible Python unrolling.

    Needed for honest compiled-cost accounting: XLA's cost analysis counts
    a while-loop body ONCE regardless of trip count, so the dry-run lowers
    stacks unrolled (``cfg.scan_layers=False``) when producing the roofline
    FLOPs/bytes; real training keeps ``lax.scan`` for compile time.
    """
    carry = init
    ys = []
    for i in range(length):
        x = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, x)
        ys.append(y)
    if all(l is None for l in jax.tree.leaves(ys[0], is_leaf=lambda v: v is None)):
        return carry, None
    stacked = jax.tree.map(lambda *e: jnp.stack(e), *ys)
    return carry, stacked


def _scan(cfg, f, init, xs, length: int):
    if cfg.scan_layers:
        return jax.lax.scan(f, init, xs)
    return unrolled_scan(f, init, xs, length=length)


def _maybe_remat(fn, cfg):
    if cfg.remat == "block":
        # save only the scanned-block boundaries; recompute inside the block
        # during backward — the standard long-stack activation policy
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _layer_window(cfg, idx):
    """Traced sliding-window size for layer ``idx`` (0 = full attention)."""
    if not cfg.local_per_global:
        return None
    period = cfg.local_per_global + 1
    is_global = (idx % period) == (period - 1)
    return jnp.where(is_global, 0, cfg.local_window)


def dense_stack(params, x, cfg, *, positions, mode, caches=None,
                cache_pos=None):
    """params: stacked [L, ...]; caches: stacked {'k','v'} or None."""
    L = cfg.n_layers

    def body(carry, inp):
        x = carry
        lp, idx, cache = inp
        window = _layer_window(cfg, idx)
        y, new_cache = dense_block(lp, x, cfg, positions=positions,
                                   mode=mode, window=window, cache=cache,
                                   cache_pos=cache_pos)
        return y, new_cache

    body = _maybe_remat(body, cfg)
    xs = (params, jnp.arange(L), caches)
    x, new_caches = _scan(cfg, body, x, xs, L)
    return x, new_caches


def moe_stack(params, x, cfg, *, positions, mode, caches=None,
              cache_pos=None):
    L = cfg.n_layers

    def body(carry, inp):
        x, aux = carry
        lp, cache = inp
        y, new_cache, a = moe_block(lp, x, cfg, positions=positions,
                                    mode=mode, cache=cache,
                                    cache_pos=cache_pos)
        return (y, aux + a), new_cache

    body = _maybe_remat(body, cfg)
    (x, aux), new_caches = _scan(
        cfg, body, (x, jnp.float32(0.0)), (params, caches), L)
    return x, new_caches, aux / L


def ssm_stack(params, x, cfg, *, caches=None):
    def body(carry, inp):
        x = carry
        lp, cache = inp
        y, new_cache = mamba_block(lp, rms_norm(x, lp["ln"], cfg.norm_eps),
                                   cfg, cache=cache)
        x = x + y
        return x, new_cache

    body = _maybe_remat(body, cfg)
    x, new_caches = _scan(cfg, body, x, (params, caches), cfg.n_layers)
    return x, new_caches


def hybrid_stack(params, x, cfg, *, positions, mode, caches=None,
                 cache_pos=None):
    """zamba2: mamba backbone + tied shared attn block every k-th layer.

    ``params = {"mamba": stacked[L], "shared": dense_block_params}``;
    ``caches = {"ssm": stacked[L] SSMCache, "attn": {'k','v'} [n_inv, ...]}``.
    The shared block's caches are carried (updated via dynamic slicing at
    the invocation index) because its parameters are tied across
    invocations but its KV history is not.
    """
    L, k = cfg.n_layers, cfg.shared_attn_every
    shared = params["shared"]

    def body(carry, inp):
        x, attn_caches = carry
        lp, idx, ssm_cache = inp
        h, new_ssm = mamba_block(lp, rms_norm(x, lp["ln"], cfg.norm_eps),
                                 cfg, cache=ssm_cache)
        x = x + h

        def with_shared(x, attn_caches):
            inv = idx // k
            if attn_caches is None:
                y, _ = dense_block(shared, x, cfg, positions=positions,
                                   mode=mode, cache=None,
                                   cache_pos=cache_pos)
                return y, attn_caches
            cache = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, inv, 0,
                                                       keepdims=False),
                attn_caches)
            y, new_cache = dense_block(shared, x, cfg, positions=positions,
                                       mode=mode, cache=cache,
                                       cache_pos=cache_pos)
            attn_caches = jax.tree.map(
                lambda buf, upd: jax.lax.dynamic_update_index_in_dim(
                    buf, upd, inv, 0),
                attn_caches, new_cache)
            return y, attn_caches

        is_shared = (idx % k) == (k - 1)
        if attn_caches is None:
            x = jax.lax.cond(is_shared,
                             lambda x: with_shared(x, None)[0],
                             lambda x: x, x)
            return (x, attn_caches), new_ssm
        x, attn_caches = jax.lax.cond(
            is_shared, with_shared, lambda x, c: (x, c), x, attn_caches)
        return (x, attn_caches), new_ssm

    body = _maybe_remat(body, cfg)
    ssm_caches = caches["ssm"] if caches is not None else None
    attn_caches = caches["attn"] if caches is not None else None
    (x, new_attn), new_ssm = _scan(
        cfg, body, (x, attn_caches),
        (params["mamba"], jnp.arange(L), ssm_caches), L)
    new_caches = (None if caches is None
                  else {"ssm": new_ssm, "attn": new_attn})
    return x, new_caches


def encoder_stack(params, x, cfg):
    def body(x, lp):
        y, _ = dense_block(lp, x, cfg, positions=None, mode="bidir")
        return y, None

    body = _maybe_remat(body, cfg)
    x, _ = _scan(cfg, body, x, params, cfg.enc_layers)
    return x


def decoder_stack(params, x, cfg, *, positions, mode, enc_out=None,
                  xa_caches=None, caches=None, cache_pos=None):
    """Whisper decoder: self-attn + cross-attn blocks.

    During train/prefill ``enc_out`` is given and per-layer cross KV is
    computed in-scan; during decode the precomputed ``xa_caches`` [L,...]
    are consumed.
    """
    def body(x, inp):
        lp, cache, xa_cache = inp
        y, new_cache, xa_kv = encdec_block(
            lp, x, cfg, positions=positions, mode=mode, cache=cache,
            cache_pos=cache_pos, enc_out=enc_out, xa_cache=xa_cache)
        return y, (new_cache, xa_kv)

    body = _maybe_remat(body, cfg)
    x, (new_caches, xa_kvs) = _scan(
        cfg, body, x, (params, caches, xa_caches), cfg.n_layers)
    return x, new_caches, xa_kvs


def precompute_cross_caches(params, enc_out, cfg):
    """[L]-stacked cross-attention KV from encoder output."""
    return jax.vmap(lambda lp: cross_kv(lp["xattn"], enc_out, cfg))(params)


def init_attn_caches(cfg, n_layers, batch, max_len, dtype):
    K, dh = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, max_len, K, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_ssm_caches(cfg, n_layers, batch, dtype):
    one = init_ssm_cache(cfg, batch, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_layers,) + a.shape), one)
