"""Mixture-of-Experts FFN with capacity-bounded sort-based dispatch.

Dense one-hot dispatch would multiply compiled FLOPs by E/top_k (64× for
arctic) and wreck the roofline; instead tokens are sorted by expert
assignment and each expert runs one dense [capacity, D] @ [D, F] GEMM —
compiled FLOPs stay ≈ active-FLOPs × capacity_factor, which is what the
6·N_active·D model-FLOPs accounting in the roofline expects.

Supports the two assigned MoE variants:
  * qwen2-moe: 60 routed top-4 + 4 fused *shared* experts (always-on);
  * arctic: 128 routed top-2 + a parallel *dense residual* FFN.

Expert-parallel sharding is applied from outside via PartitionSpecs on the
[E, D, F] weights (strategy "ep": E over the model axis; "tp": F over the
model axis — chosen per arch for divisibility, DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import mlp


def _capacity(n_tokens: int, cfg) -> int:
    cap = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)   # round up to a lane-friendly multiple


def moe_ffn(p: dict, x: jax.Array, cfg):
    """x: [B, S, D] → (y, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(T, D)

    # --- routing -------------------------------------------------------- #
    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                  # [T, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                            # mean router prob
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)   # top-1 load
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch -------------------------------------------- #
    cap = _capacity(T, cfg)
    flat_e = top_e.reshape(-1)                              # [T*K]
    flat_w = top_p.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e)                             # stable
    ranked_e = flat_e[order]
    tok_of = order // K                                     # source token
    # position within the expert segment
    seg_start = jnp.searchsorted(ranked_e, jnp.arange(E), side="left")
    seg_pos = jnp.arange(T * K) - seg_start[ranked_e]
    keep = seg_pos < cap
    dest = jnp.where(keep, ranked_e * cap + seg_pos, E * cap)  # E*cap = drop

    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    buf = buf.at[dest].set(xf[tok_of])
    eb = buf[:-1].reshape(E, cap, D)

    # --- expert GEMMs ---------------------------------------------------- #
    if "w_gate_up" in p["experts"]:
        gu = jnp.einsum("ecd,edf->ecf", eb,
                        p["experts"]["w_gate_up"].astype(x.dtype))
        g, u = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb,
                                    p["experts"]["w_gate"].astype(x.dtype)))
             * jnp.einsum("ecd,edf->ecf", eb,
                          p["experts"]["w_up"].astype(x.dtype)))
    ey = jnp.einsum("ecf,efd->ecd", h,
                    p["experts"]["w_down"].astype(x.dtype))

    # --- combine ---------------------------------------------------------- #
    flat_y = ey.reshape(E * cap, D)
    gathered = jnp.where(keep[:, None],
                         flat_y[jnp.clip(dest, 0, E * cap - 1)], 0.0)
    gathered = gathered * flat_w[order][:, None]
    y = jnp.zeros((T, D), x.dtype).at[tok_of].add(gathered)
    y = y.reshape(B, S, D)

    # --- always-on paths --------------------------------------------------#
    if "shared" in p:
        y = y + mlp(p["shared"], x, cfg.act)
    if "dense_res" in p:
        y = y + mlp(p["dense_res"], x, cfg.act)
    return y, aux.astype(jnp.float32)


def moe_params(key, cfg, dtype):
    from .layers import dense_init, mlp_params
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    if getattr(cfg, "fused_gate_up", False):
        experts = {
            "w_gate_up": dense_init(ks[1], (E, D, 2 * F), dtype,
                                    scale=D ** -0.5),
            "w_down": dense_init(ks[3], (E, F, D), dtype, scale=F ** -0.5),
        }
    else:
        experts = {
            "w_gate": dense_init(ks[1], (E, D, F), dtype, scale=D ** -0.5),
            "w_up": dense_init(ks[2], (E, D, F), dtype, scale=D ** -0.5),
            "w_down": dense_init(ks[3], (E, F, D), dtype, scale=F ** -0.5),
        }
    p = {
        "router": dense_init(ks[0], (D, E), dtype),
        "experts": experts,
    }
    fused = getattr(cfg, "fused_gate_up", False)
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(ks[4], D, cfg.d_ff_shared, dtype, cfg.act,
                                 fused=fused)
    if cfg.moe_dense_residual:
        p["dense_res"] = mlp_params(ks[5], D, cfg.d_ff_dense, dtype,
                                    cfg.act, fused=fused)
    return p
