"""Mamba2 block — SSD (state-space duality), chunked matmul form.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks of Q tokens: within a chunk the recurrence is computed as a masked
attention-like GEMM (MXU-friendly), across chunks a small state
[H, P, N] is carried by a scan — exactly the TPU-native formulation (the
hardware-adaptation of the CUDA selective-scan in DESIGN.md).  The
``ssd_scan`` Pallas kernel implements the same chunk computation; this
module is its pure-jnp reference and the XLA path used by the dry-run.

Block layout (Mamba2 paper):
  in_proj → [z (gate), xBC (conv features), dt] ; causal depthwise conv on
  xBC ; SSD ; gated RMSNorm ; out_proj.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm


class SSMCache(NamedTuple):
    state: jax.Array        # [B, H, P, N] carried SSD state
    conv: jax.Array         # [B, ck-1, conv_dim] conv tail


def _conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def mamba_params(key, cfg, dtype):
    D, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    cdim = _conv_dim(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((D,), dtype),               # pre-norm (residual)
        # in_proj emits [z (di), xBC (cdim), dt (H)]
        "in_proj": dense_init(ks[0], (D, di + cdim + H), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, cdim), dtype, scale=0.5),
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, D), dtype, scale=di ** -0.5),
    }


def _split_proj(p, x, cfg):
    """in_proj → z [B,S,di], xBC [B,S,cdim], dt [B,S,H]."""
    di, H = cfg.d_inner, cfg.ssm_heads
    cdim = _conv_dim(cfg)
    u = jnp.einsum("bsd,dn->bsn", x, p["in_proj"].astype(x.dtype))
    z, xBC, dt = jnp.split(u, [di, di + cdim], axis=-1)
    return z, xBC, dt


def _causal_conv(p, u: jax.Array, tail: Optional[jax.Array] = None):
    """Depthwise causal conv (kernel ck) via shift-and-add.

    u: [B,S,cdim]; tail: [B,ck-1,cdim] previous inputs (decode) or None
    (train: zero history).  Returns (y, new_tail)."""
    w = p["conv_w"].astype(u.dtype)                 # [ck, cdim]
    ck = w.shape[0]
    B, S, cdim = u.shape
    if tail is None:
        tail = jnp.zeros((B, ck - 1, cdim), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)        # [B, S+ck-1, cdim]
    y = sum(ext[:, i:i + S, :] * w[i] for i in range(ck))
    y = jax.nn.silu(y + p["conv_b"].astype(u.dtype))
    return y, ext[:, -(ck - 1):, :]


def _segsum_exp(cum: jax.Array) -> jax.Array:
    """exp(cum_i - cum_j) for j <= i else 0.  cum: [..., Q]."""
    diff = cum[..., :, None] - cum[..., None, :]
    Q = cum.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD.  xh: [B,S,H,P]; dt: [B,S,H] (post-softplus);
    A: [H] (negative); Bm/Cm: [B,S,N] (one group).
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = chunk
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    C_ = Sp // Q

    f32 = jnp.float32
    xh_ = xh.reshape(B, C_, Q, H, P)
    dt_ = dt.reshape(B, C_, Q, H).astype(f32)
    Bm_ = Bm.reshape(B, C_, Q, N)
    Cm_ = Cm.reshape(B, C_, Q, N)

    dA = dt_ * A[None, None, None, :]               # [B,C,Q,H] (<= 0)
    cum = jnp.cumsum(dA, axis=2)                    # inclusive
    # intra-chunk: masked attention-like term
    L = _segsum_exp(jnp.moveaxis(cum, -1, 2))       # [B,C,H,Q,Q]
    cb = jnp.einsum("bcin,bcjn->bcij", Cm_.astype(f32), Bm_.astype(f32))
    scores = cb[:, :, None] * L * dt_.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores.astype(xh.dtype),
                         xh_)

    # chunk-local final states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # [B,C,Q,H]
    sloc = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                      (decay_to_end * dt_).astype(xh.dtype), Bm_, xh_)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # [B,C,H]
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), xh.dtype)

    def step(carry, inp):
        dec, s_local = inp                  # dec [B,H], s_local [B,H,P,N]
        before = carry
        carry = carry * dec[..., None, None].astype(carry.dtype) + s_local
        return carry, before

    final, before = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(sloc, 1, 0)))
    before = jnp.moveaxis(before, 0, 1)                          # [B,C,H,P,N]

    y_inter = (jnp.einsum("bcqn,bchpn->bcqhp", Cm_, before)
               * jnp.exp(cum)[..., None].astype(xh.dtype))
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    return y, final


def mamba_block(p: dict, x: jax.Array, cfg, *,
                cache: Optional[SSMCache] = None):
    """Full Mamba2 block.  x: [B,S,D].  Returns (y, new_cache)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner
    z, xBC, dtr = _split_proj(p, x, cfg)
    xBC, new_tail = _causal_conv(p, xBC, cache.conv if cache else None)
    xs, Bm, Cm = jnp.split(xBC, [di, di + cfg.ssm_groups * cfg.ssm_state],
                           axis=-1)
    xh = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    if cache is None:
        y, final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
        new_cache = None
    elif S == 1:
        # recurrent decode step
        dA = jnp.exp(dt[:, 0] * A[None, :])          # [B,H]
        st = cache.state * dA[..., None, None].astype(cache.state.dtype)
        st = st + jnp.einsum("bh,bhp,bn->bhpn",
                             dt[:, 0].astype(x.dtype), xh[:, 0], Bm[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], st)[:, None]    # [B,1,H,P]
        final = st
        new_cache = SSMCache(state=final, conv=new_tail)
    else:
        # chunked prefill with state carry-in
        y, final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                               init_state=cache.state)
        new_cache = SSMCache(state=final, conv=new_tail)

    y = y + (p["D"].astype(x.dtype)[None, None, :, None] * xh)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsn,nd->bsd", y, p["out_proj"].astype(x.dtype))
    if cache is None:
        return out, None
    return out, new_cache


def init_ssm_cache(cfg, batch: int, dtype) -> SSMCache:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return SSMCache(
        state=jnp.zeros((batch, H, P, N), dtype),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, _conv_dim(cfg)), dtype))
