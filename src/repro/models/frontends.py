"""Modality frontend STUBS (per the assignment brief).

``[audio]`` (whisper) and ``[vlm]`` (internvl2) specify the transformer
BACKBONE only; the conv/ViT frontends are stubs: ``input_specs()`` (and the
synthetic generators here) provide precomputed frame / patch embeddings of
the correct shape and dtype.  A production deployment would plug the real
frontend in ahead of these tensors; nothing in the backbone, sharding or
serving path depends on how they were produced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synth_audio_frames(key, batch: int, cfg, dtype=jnp.bfloat16):
    """Stub for whisper's conv1d+GELU frontend: [B, enc_seq, d_model]."""
    return jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model), dtype)


def synth_vision_patches(key, batch: int, cfg, dtype=jnp.bfloat16):
    """Stub for InternViT: [B, vis_tokens, d_model] patch embeddings."""
    return jax.random.normal(key, (batch, cfg.vis_tokens, cfg.d_model), dtype)
