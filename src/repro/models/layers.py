"""Neural net layers: norms, rotary embeddings, attention (GQA / qk-norm /
bias / sliding-window / cross), MLPs — pure JAX, param-dict style.

All ``apply`` functions take a params dict and are shape-polymorphic over
batch/sequence.  Attention supports three modes:

  * ``causal``  — train/prefill self-attention (optionally sliding-window);
  * ``bidir``   — encoder self-attention;
  * ``decode``  — one query token against a persistent KV cache.

The XLA einsum path here is the dry-run/compile reference; the Pallas
flash-attention kernel (kernels/flash_attention) is numerically validated
against `attention_scores` semantics and can be swapped in via ops.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: RMS over the head dim of [..., heads, head_dim]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# --------------------------------------------------------------------- #
# rotary position embeddings                                             #
# --------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: [B, S, H, dh]; positions: [B, S] (int32)."""
    if theta <= 0.0:
        return x
    dh = x.shape[-1]
    half = dh // 2
    freq = jnp.exp(-jnp.log(theta) *
                   jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [B,S,half]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention                                                              #
# --------------------------------------------------------------------- #
def _proj(x, w, b=None):
    y = jnp.einsum("bsd,dn->bsn", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def qkv(p: dict, x: jax.Array, cfg, positions: Optional[jax.Array],
        *, use_rope: bool = True):
    """Project to q/k/v with GQA layout [B,S,H,dh] / [B,S,K,dh].

    With ``cfg.fused_qkv`` the three projections are ONE matmul — in
    backward this turns three [B,S,D] model-axis all-reduces (dx from each
    projection's transpose) into one (§Perf fusion iteration)."""
    B, S, _ = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if "wqkv" in p:
        u = _proj(x, p["wqkv"], p.get("bqkv"))
        q, k, v = jnp.split(u, [H * dh, (H + K) * dh], axis=-1)
        q = q.reshape(B, S, H, dh)
        k = k.reshape(B, S, K, dh)
        v = v.reshape(B, S, K, dh)
    else:
        q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, H, dh)
        k = _proj(x, p["wk"], p.get("bk")).reshape(B, S, K, dh)
        v = _proj(x, p["wv"], p.get("bv")).reshape(B, S, K, dh)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: Optional[jax.Array]) -> jax.Array:
    """GQA attention.  q: [B,Sq,H,dh], k/v: [B,Sk,K,dh], mask broadcastable
    to [B,1,Sq,Sk] (True = attend).  Returns [B,Sq,H,dh].

    KV heads are repeated up to H so there is ONE head axis, explicitly
    constrained over the "model" mesh axis — GSPMD then keeps the [Sq,Sk]
    score tensor sharded H-ways instead of inventing a mixed K/G layout
    (the 8.6 GB/buffer failure mode recorded in EXPERIMENTS.md §Perf #0).
    Per device the repeat materializes only the local heads' copies.
    """
    from ..sharding.constraints import batch_axes, constrain
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    ba = batch_axes()
    if H != K:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    q = constrain(q, ba, None, "model", None)
    k = constrain(k, ba, None, "model", None)
    v = constrain(v, ba, None, "model", None)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    scores = constrain(scores, ba, "model", None, None)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out


def attention_blocked(q, k, v, *, causal: bool, window, chunk: int = 1024):
    """Online-softmax attention, scanned over KV chunks (XLA flash).

    Peak score materialization drops from O(Sq·Sk) to O(Sq·chunk) — the
    §Perf memory-term optimization for the 32k prefill cells; numerics
    match the naive path (same f32 softmax).  q/k/v: [B,S,H,dh] with KV
    already repeated to H (caller).  window may be traced.
    """
    from ..sharding.constraints import batch_axes, constrain
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sk + pad) // chunk
    ba = batch_axes()
    scale = 1.0 / (dh ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, H, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, H, dh), 1, 0)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, j = inp
        kb = constrain(kb, ba, None, "model", None)
        vb = constrain(vb, ba, None, "model", None)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        mask = kpos < Sk                       # padding
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            w = jnp.asarray(window)
            mask = mask & jnp.where(w > 0, kpos > qpos - w, True)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).astype(q.dtype)
    return out.transpose(0, 2, 1, 3)           # [B,Sq,H,dh]


def causal_mask(Sq: int, Sk: int, q_offset, window: int = 0):
    """[1,1,Sq,Sk] boolean mask; window>0 = sliding-window causal."""
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window:
        m = m & (kpos > qpos - window)
    return m[None, None]


def self_attention(p: dict, x: jax.Array, cfg, *, positions,
                   mode: str = "causal", window=0,
                   cache: Optional[dict] = None, cache_pos=None):
    """Self-attention for all modes; returns (out, new_cache).

    ``window`` may be a traced scalar (0 = full attention) so that the
    gemma3 local/global pattern compiles as ONE scanned block.

    ``cache`` (a {'k','v'} buffer of length S_max) is consumed+updated in
    decode mode; in causal mode a provided cache buffer is *filled* from
    position 0 (prefill) and the attention itself runs over the current
    tokens only.
    """
    B, S, _ = x.shape
    q, k, v = qkv(p, x, cfg, positions)
    if mode == "decode":
        # one new token (S == 1) against the persistent cache
        assert cache is not None
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, 1)
        Sk = ck.shape[1]
        kpos = jnp.arange(Sk)
        m = kpos <= cache_pos
        if window is not None:
            w_active = jnp.asarray(window)
            m = m & jnp.where(w_active > 0, kpos > cache_pos - w_active, True)
        mask = m[None, None, None, :]
        out = attention_scores(q, ck, cv, mask)
        new_cache = {"k": ck, "v": cv}
    elif mode == "bidir":
        out = attention_scores(q, k, v, None)
        new_cache = None
    elif getattr(cfg, "attn_impl", "naive") == "blocked":
        # §Perf: XLA online-softmax flash — O(Sq·chunk) score footprint
        from ..sharding.constraints import batch_axes, constrain
        H, K = q.shape[2], k.shape[2]
        kk = jnp.repeat(k, H // K, axis=2) if H != K else k
        vv = jnp.repeat(v, H // K, axis=2) if H != K else v
        ba = batch_axes()
        qq = constrain(q, ba, None, "model", None)
        out = attention_blocked(qq, kk, vv, causal=True, window=window,
                                chunk=getattr(cfg, "attn_chunk", 1024))
        if cache is not None:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
            }
        else:
            new_cache = None
    else:  # causal train/prefill
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        m = kpos <= qpos
        if window is not None:
            w_active = jnp.asarray(window)
            m = m & jnp.where(w_active > 0, kpos > qpos - w_active, True)
        mask = m[None, None]
        out = attention_scores(q, k, v, mask)
        if cache is not None:   # prefill: fill the decode buffer
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
            }
        else:
            new_cache = None
    B, Sq, H, dh = out.shape
    y = jnp.einsum("bsn,nd->bsd", out.reshape(B, Sq, H * dh),
                   p["wo"].astype(x.dtype))
    return y, new_cache


def cross_attention(p: dict, x: jax.Array, cfg, *, kv=None, kv_cache=None):
    """Decoder cross-attention over encoder output.

    ``kv``: encoder activations [B,Se,D] (prefill/train) — projected here;
    ``kv_cache``: precomputed {"k","v"} (decode).
    """
    B, S, _ = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, H, dh)
    if kv_cache is None:
        Se = kv.shape[1]
        k = _proj(kv, p["wk"]).reshape(B, Se, K, dh)
        v = _proj(kv, p["wv"]).reshape(B, Se, K, dh)
    else:
        k, v = kv_cache["k"], kv_cache["v"]
    out = attention_scores(q, k, v, None)
    y = jnp.einsum("bsn,nd->bsd", out.reshape(B, S, H * dh),
                   p["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}


def cross_kv(p: dict, kv: jax.Array, cfg) -> dict:
    """Precompute the cross-attention KV cache from encoder output."""
    B, Se, _ = kv.shape
    K, dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": _proj(kv, p["wk"]).reshape(B, Se, K, dh),
            "v": _proj(kv, p["wv"]).reshape(B, Se, K, dh)}


# --------------------------------------------------------------------- #
# MLPs                                                                   #
# --------------------------------------------------------------------- #
def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    if act == "gelu":
        h = jax.nn.gelu(_proj(x, p["w_up"]))
    elif "w_gate_up" in p:
        gu = _proj(x, p["w_gate_up"])
        g, u = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.silu(_proj(x, p["w_gate"])) * _proj(x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# --------------------------------------------------------------------- #
# initializers                                                           #
# --------------------------------------------------------------------- #
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def attn_params(key, cfg, dtype):
    H, K, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    if getattr(cfg, "fused_qkv", False):
        p = {
            "wqkv": dense_init(ks[0], (D, (H + 2 * K) * dh), dtype),
            "wo": dense_init(ks[3], (H * dh, D), dtype,
                             scale=(H * dh) ** -0.5),
        }
        if cfg.qkv_bias:
            p["bqkv"] = jnp.zeros(((H + 2 * K) * dh,), dtype)
        if cfg.qk_norm:
            p.update(q_norm=jnp.zeros((dh,), dtype),
                     k_norm=jnp.zeros((dh,), dtype))
        return p
    p = {
        "wq": dense_init(ks[0], (D, H * dh), dtype),
        "wk": dense_init(ks[1], (D, K * dh), dtype),
        "wv": dense_init(ks[2], (D, K * dh), dtype),
        "wo": dense_init(ks[3], (H * dh, D), dtype, scale=(H * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((H * dh,), dtype),
                 bk=jnp.zeros((K * dh,), dtype),
                 bv=jnp.zeros((K * dh,), dtype))
    if cfg.qk_norm:
        p.update(q_norm=jnp.zeros((dh,), dtype),
                 k_norm=jnp.zeros((dh,), dtype))
    return p


def mlp_params(key, d_model, d_ff, dtype, act="silu", fused=False):
    ks = jax.random.split(key, 3)
    if act != "gelu" and fused:
        return {"w_gate_up": dense_init(ks[0], (d_model, 2 * d_ff), dtype),
                "w_down": dense_init(ks[2], (d_ff, d_model), dtype,
                                     scale=d_ff ** -0.5)}
    p = {"w_up": dense_init(ks[1], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[2], (d_ff, d_model), dtype,
                              scale=d_ff ** -0.5)}
    if act != "gelu":
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dtype)
    return p
