"""Unified model API over all assigned families.

    model = build_model(cfg)
    params = model.init(rng)
    loss   = model.loss(params, batch)              # train step body
    logits, caches = model.prefill(params, batch, max_len)
    logits, caches = model.decode_step(params, tokens, caches, pos)

Batch conventions (matching ``input_specs`` in launch/dryrun.py):
  * lm (dense/moe/ssm/hybrid):  {"tokens": int32[B, S+1]}
  * encdec (whisper):  {"frames": f[B, Se, D] (conv-stub output),
                        "tokens": int32[B, S+1]}
  * vlm (internvl):    {"vis": f[B, Tv, D] (ViT-stub output),
                        "tokens": int32[B, S+1]}  (loss on text only)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import transformer as T
from .layers import dense_init, rms_norm
from .mamba2 import mamba_params


def _dtype(name: str):
    return jnp.dtype(name)


def padded_vocab(cfg) -> int:
    """Vocab rounded up to a multiple of 128 so the embedding/logits dim
    shards evenly over the model axis (padded logits are masked out)."""
    return -(-cfg.vocab // 128) * 128


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: object

    # ------------------------------------------------------------------ #
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        D, V = cfg.d_model, padded_vocab(cfg)
        params = {
            "embed": dense_init(ks[0], (V, D), dt, scale=1.0),
            "final_norm": jnp.zeros((D,), dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], (D, V), dt)
        fam = cfg.family
        if fam in ("dense", "vlm"):
            params["blocks"] = T.stacked_params(
                ks[2], cfg.n_layers, T.dense_block_params, cfg, dt)
        elif fam == "moe":
            params["blocks"] = T.stacked_params(
                ks[2], cfg.n_layers, T.moe_block_params, cfg, dt)
        elif fam == "ssm":
            params["blocks"] = T.stacked_params(
                ks[2], cfg.n_layers,
                lambda k, c, d: mamba_params(k, c, d), cfg, dt)
        elif fam == "hybrid":
            params["blocks"] = {
                "mamba": T.stacked_params(
                    ks[2], cfg.n_layers,
                    lambda k, c, d: mamba_params(k, c, d), cfg, dt),
                "shared": T.dense_block_params(ks[3], cfg, dt),
            }
        elif fam == "encdec":
            params["encoder"] = T.stacked_params(
                ks[2], cfg.enc_layers, T.dense_block_params, cfg, dt)
            params["enc_pos"] = dense_init(ks[4], (cfg.enc_seq, D), dt,
                                           scale=0.02)
            params["enc_norm"] = jnp.zeros((D,), dt)
            params["blocks"] = T.stacked_params(
                ks[3], cfg.n_layers, T.encdec_block_params, cfg, dt)
            params["dec_pos"] = dense_init(ks[5], (8192, D), dt, scale=0.02)
        else:
            raise ValueError(fam)
        return params

    # ------------------------------------------------------------------ #
    def _embed(self, params, tokens, positions):
        cfg = self.cfg
        ct = _dtype(cfg.compute_dtype)
        x = params["embed"].astype(ct)[tokens]
        if cfg.family == "encdec" and cfg.rope_theta <= 0:
            # absolute positional embeddings (whisper-style decoder)
            pe = params["dec_pos"].astype(ct)
            x = x + pe[jnp.clip(positions, 0, pe.shape[0] - 1)]
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        Vp = logits.shape[-1]
        if Vp != cfg.vocab:   # mask the padded vocab tail
            logits = jnp.where(jnp.arange(Vp) < cfg.vocab, logits, -1e30)
        return logits

    def _encode(self, params, frames):
        cfg = self.cfg
        ct = _dtype(cfg.compute_dtype)
        x = frames.astype(ct) + params["enc_pos"].astype(ct)[None]
        x = T.encoder_stack(params["encoder"], x, cfg)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _backbone(self, params, x, *, positions, mode, caches=None,
                  cache_pos=None, enc_out=None, xa_caches=None):
        cfg = self.cfg
        fam = cfg.family
        aux = jnp.float32(0.0)
        if fam in ("dense", "vlm"):
            x, new_caches = T.dense_stack(params["blocks"], x, cfg,
                                          positions=positions, mode=mode,
                                          caches=caches, cache_pos=cache_pos)
        elif fam == "moe":
            x, new_caches, aux = T.moe_stack(params["blocks"], x, cfg,
                                             positions=positions, mode=mode,
                                             caches=caches,
                                             cache_pos=cache_pos)
        elif fam == "ssm":
            x, new_caches = T.ssm_stack(params["blocks"], x, cfg,
                                        caches=caches)
        elif fam == "hybrid":
            x, new_caches = T.hybrid_stack(params["blocks"], x, cfg,
                                           positions=positions, mode=mode,
                                           caches=caches,
                                           cache_pos=cache_pos)
        elif fam == "encdec":
            x, new_caches, xa_kvs = T.decoder_stack(
                params["blocks"], x, cfg, positions=positions, mode=mode,
                enc_out=enc_out, xa_caches=xa_caches, caches=caches,
                cache_pos=cache_pos)
            return x, (new_caches, xa_kvs), aux
        else:
            raise ValueError(fam)
        return x, new_caches, aux

    # ------------------------------------------------------------------ #
    # training                                                            #
    # ------------------------------------------------------------------ #
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        B, S = inp.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        enc_out = None
        x = self._embed(params, inp, positions)
        n_prefix = 0
        if cfg.family == "vlm":
            ct = x.dtype
            x = jnp.concatenate([batch["vis"].astype(ct), x], axis=1)
            n_prefix = batch["vis"].shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(n_prefix + S), (B, n_prefix + S))
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"])
        x, _, aux = self._backbone(params, x, positions=positions,
                                   mode="causal", enc_out=enc_out)
        if n_prefix:
            x = x[:, n_prefix:]
        logits = self._logits(params, x).astype(jnp.float32)
        # NLL via one-hot contraction: take_along_axis would gather over the
        # model-sharded vocab dim and force full logits replication under
        # GSPMD (EXPERIMENTS.md §Perf #0); the one-hot einsum partitions.
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = (labels[..., None] ==
                  jnp.arange(logits.shape[-1])[None, None, :])
        picked = jnp.sum(logits * onehot, axis=-1)
        loss = jnp.mean(lse - picked)
        if cfg.family == "moe":
            loss = loss + 0.01 * aux
        return loss

    # ------------------------------------------------------------------ #
    # serving                                                             #
    # ------------------------------------------------------------------ #
    def init_caches(self, batch: int, max_len: int):
        cfg = self.cfg
        ct = _dtype(cfg.compute_dtype)
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            return T.init_attn_caches(cfg, cfg.n_layers, batch, max_len, ct)
        if fam == "ssm":
            return T.init_ssm_caches(cfg, cfg.n_layers, batch, ct)
        if fam == "hybrid":
            n_inv = cfg.n_layers // cfg.shared_attn_every
            return {
                "ssm": T.init_ssm_caches(cfg, cfg.n_layers, batch, ct),
                "attn": T.init_attn_caches(cfg, n_inv, batch, max_len, ct),
            }
        if fam == "encdec":
            return {
                "self": T.init_attn_caches(cfg, cfg.n_layers, batch,
                                           max_len, ct),
                # cross buffers sized to the encoder output; prefill
                # overwrites them with the actual projected encoder KV
                "cross": T.init_attn_caches(cfg, cfg.n_layers, batch,
                                            cfg.enc_seq, ct),
            }
        raise ValueError(fam)

    def prefill(self, params, batch, max_len: int):
        """Forward over the prompt; returns (last-token logits, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self._embed(params, tokens, positions)
        caches = self.init_caches(B, max_len)
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["vis"].astype(x.dtype), x], axis=1)
            Sv = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(Sv), (B, Sv))
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"])
            x, (new_self, xa_kvs), _ = self._backbone(
                params, x, positions=positions, mode="causal",
                caches=caches["self"], enc_out=enc_out)
            logits = self._logits(params, x[:, -1:])
            return logits, {"self": new_self, "cross": xa_kvs}
        x, new_caches, _ = self._backbone(params, x, positions=positions,
                                          mode="causal", caches=caches)
        logits = self._logits(params, x[:, -1:])
        return logits, new_caches

    def decode_step(self, params, tokens, caches, pos):
        """One decode step.  tokens: int32[B]; pos: int32 scalar (the
        position being written, == current cache length)."""
        cfg = self.cfg
        B = tokens.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x = self._embed(params, tokens[:, None], positions)
        if cfg.family == "encdec":
            x, (new_self, xa), _ = self._backbone(
                params, x, positions=positions, mode="decode",
                caches=caches["self"], xa_caches=caches["cross"],
                cache_pos=pos)
            logits = self._logits(params, x)
            return logits, {"self": new_self, "cross": xa}
        x, new_caches, _ = self._backbone(params, x, positions=positions,
                                          mode="decode", caches=caches,
                                          cache_pos=pos)
        return self._logits(params, x), new_caches


def build_model(cfg) -> Model:
    return Model(cfg)
