"""Three-term roofline from the dry-run artifacts (deliverable g).

Per (arch × shape) cell, from the single-pod compiled program:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
    memory term     = HLO_bytes_per_device / HBM_bw             [s]
    collective term = collective_bytes_per_device / link_bw     [s]

(the dry-run records per-DEVICE numbers — the partitioned module — so the
spec's global/(chips × bw) formula reduces to per-device/bw).  Also:

    MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens of the
    step; the MODEL/HLO ratio exposes remat & padding waste; the roofline
    fraction = useful-compute time / dominant term is the §Perf score.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

from ..configs.base import SHAPES
from ..configs.registry import ARCHS
from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CHIPS = 256


def tokens_of(shape) -> int:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch          # decode: one token per sequence


def model_flops(cfg, shape) -> float:
    """6·N·D with MoE active params; decode counts the KV/state read as
    compute via the same 6·N·D convention (2·N per token fwd, no bwd)."""
    n = cfg.n_active_params()
    toks = tokens_of(shape)
    if shape.kind == "train":
        return 6.0 * n * toks
    return 2.0 * n * toks              # forward-only

def analyze_cell(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok" or "cost" not in rec:
        return None
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll_dev = rec["cost"]["collective_bytes"]
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / CHIPS
    useful_t = mf_dev / PEAK_FLOPS_BF16
    frac = useful_t / max(terms.values()) if max(terms.values()) else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_dev": mf_dev, "hlo_flops_dev": flops_dev,
        "model_over_hlo": mf_dev / flops_dev if flops_dev else 0.0,
        "roofline_fraction": frac,
        "temp_gb": rec.get("temp_size_in_bytes", 0) / 1e9,
        "args_gb": rec.get("argument_size_in_bytes", 0) / 1e9,
        "collective_detail": rec["cost"].get("collective_detail", {}),
    }


def load_table(dryrun_dir="benchmarks/results/dryrun", mesh="single"):
    rows, skips = [], []
    for p in sorted(Path(dryrun_dir).glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "skipped":
            skips.append(rec)
            continue
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    return rows, skips


def render_markdown(rows, skips) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | HBM GB (args+temp) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['model_over_hlo']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['args_gb'] + r['temp_gb']:.1f} |")
    if skips:
        out.append("")
        out.append(f"Skipped cells ({len(skips)}): " + ", ".join(
            f"{s['arch']}:{s['shape']}" for s in skips) +
            " — pure full-attention archs at 500k (DESIGN.md §4).")
    return "\n".join(out)


def main():
    for tag, d in (("", "benchmarks/results/dryrun"),
                   ("_opt", "benchmarks/results/dryrun_opt")):
        if not Path(d).exists():
            continue
        rows, skips = load_table(d)
        if not rows:
            continue
        print(f"==== roofline{tag or ' (baseline)'} ====")
        print(render_markdown(rows, skips))
        Path(f"benchmarks/results/roofline{tag}.md").write_text(
            render_markdown(rows, skips) + "\n")
        Path(f"benchmarks/results/roofline{tag}.json").write_text(
            json.dumps({"rows": rows, "skips": [
                {"arch": s["arch"], "shape": s["shape"]} for s in skips]},
                indent=1))


if __name__ == "__main__":
    main()
