"""Train-step factory: microbatched grad accumulation (scan), optimizer
update, and the sharded jit wiring used by both the dry-run and the real
training driver (launch/train.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .optimizer import Optimizer


def shape_batch_for_accum(batch: dict, microbatches: int) -> dict:
    """[B, ...] -> [M, B/M, ...] on every batch leaf."""
    def r(a):
        B = a.shape[0]
        assert B % microbatches == 0, (B, microbatches)
        return a.reshape((microbatches, B // microbatches) + a.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(model, cfg, optimizer: Optimizer):
    """Returns train_step(params, opt_state, batch, step) ->
    (params', opt_state', metrics).

    When ``cfg.microbatches > 1`` the batch must arrive PRE-SHAPED as
    [M, B/M, ...] (use :func:`shape_batch_for_accum` host-side) — reshaping
    inside the jitted step loses the batch-dim sharding under GSPMD.
    Gradient accumulation is a ``lax.scan`` over microbatches; the
    accumulator dtype follows ``cfg.opt_dtype`` (bf16 for the 480B MoE so
    the extra gradient buffer stays inside the HBM budget)."""
    M = max(1, cfg.microbatches)
    acc_dt = jnp.dtype(cfg.opt_dtype)

    def loss_fn(p, mb):
        return model.loss(p, mb)

    def train_step(params, opt_state, batch, step):
        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = batch   # pre-shaped [M, B/M, ...]
            from ..sharding.constraints import constrain_like_params
            pin = (lambda t: constrain_like_params(t, cfg)) \
                if getattr(cfg, "accum_constraint", False) else (lambda t: t)

            def acc_step(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = pin(jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gsum, g))
                return (gsum, lsum + l), None

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            (gsum, lsum), _ = jax.lax.scan(
                acc_step, (zeros, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: (g / M).astype(jnp.float32), gsum)
            loss = lsum / M
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               step)
        return new_params, new_opt, {"loss": loss}

    return train_step


# --------------------------------------------------------------------- #
# manual-DP variant with gradient compression (multi-pod feature)        #
# --------------------------------------------------------------------- #
def make_compressed_psum_grads(axis_name: str = "pod"):
    """bf16-compressed cross-pod gradient all-reduce with fp32 error
    feedback — used by the shard_map DP wrapper in launch/train.py when
    ``--grad-compression`` is on.

    Returns f(grads_fp32, error_fp32) -> (reduced_fp32, new_error)."""

    def f(grads, err):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            g16 = g.astype(jnp.bfloat16)
            new_e = g - g16.astype(jnp.float32)      # residual kept locally
            red = jax.lax.pmean(g16, axis_name).astype(jnp.float32)
            return red, new_e

        out = jax.tree.map(one, grads, err)
        red = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return red, new_err

    return f
