"""GPipe-style pipeline parallelism over a "stage" mesh axis (optional
strategy; DESIGN.md §5).

The model's layer stack is split into S contiguous stage groups; each
stage's devices hold only their group's parameters (true PP memory
scaling).  Microbatches stream through stages with ``jax.lax.ppermute``
boundary rotation inside ``shard_map`` — the classic GPipe schedule with
S-1 bubble slots, expressed JAX-natively (no torch.distributed-style
point-to-point emulation; the permute IS the pipe).

This module is deliberately self-contained (a stack of dense blocks) —
it demonstrates and tests the schedule; wiring arbitrary families through
PP is a config-level extension (the production mesh for the assigned
cells has no stage axis, per the brief).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def mlp_block(p, x):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w2"])
    return x + h @ p["w3"]


def init_pipeline_params(key, *, n_stages: int, layers_per_stage: int,
                         d_model: int, d_ff: int):
    """[S, Lps, ...] — leading dim sharded over the stage axis."""
    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        s = d_model ** -0.5
        # small output scale keeps the normalization-free demo stack stable
        return {"w1": jax.random.normal(k1, (d_model, d_ff)) * s,
                "w2": jax.random.normal(k2, (d_model, d_ff)) * s,
                "w3": jax.random.normal(k3, (d_ff, d_model))
                      * 0.1 * d_ff ** -0.5}
    keys = jax.random.split(key, n_stages * layers_per_stage)
    stacked = jax.vmap(one)(keys)
    return jax.tree.map(
        lambda a: a.reshape((n_stages, layers_per_stage) + a.shape[1:]),
        stacked)


def gpipe_forward(params, x_mb, *, n_stages: int, axis: str = "stage"):
    """Run M microbatches through the pipe inside shard_map.

    ``params``: this stage's [Lps, ...] group (already sharded-in);
    ``x_mb``: [M, B/M, T, D] microbatches (replicated over the stage axis).
    Returns [M, B/M, T, D] outputs (valid on the LAST stage).
    """
    stage = jax.lax.axis_index(axis)
    M = x_mb.shape[0]

    def stage_apply(x):
        def body(x, lp):
            return mlp_block(lp, x), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    def step(carry, t):
        buf = carry           # [B/M, T, D] the slot flowing through me
        # inject a fresh microbatch at stage 0 while the schedule fills
        inject = jnp.where(t < M, t, M - 1)
        buf = jnp.where(stage == 0, x_mb[inject], buf)
        out = stage_apply(buf)
        # rotate stage s -> s+1 (last stage's output exits the pipe)
        nxt = jax.lax.ppermute(
            out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
        # the last stage banks its finished microbatch index t-(S-1)
        return nxt, out

    T_total = M + n_stages - 1            # GPipe bubble: S-1 extra ticks
    _, outs = jax.lax.scan(step, jnp.zeros_like(x_mb[0]),
                           jnp.arange(T_total))
    # on the last stage, outs[t] for t in [S-1, S-1+M) are the results;
    # zero elsewhere + psum replicates them across the pipe
    take = jax.lax.dynamic_slice_in_dim(outs, n_stages - 1, M, axis=0)
    take = jnp.where(stage == n_stages - 1, take, 0.0)
    return jax.lax.psum(take, axis)


def make_gpipe_fn(mesh: Mesh, *, n_stages: int, axis: str = "stage"):
    """shard_map-wrapped pipeline forward on ``mesh`` (must carry
    ``axis``)."""
    pspec = P(axis)                       # params: stage dim sharded
    xspec = P(None, "data", None, None) if "data" in mesh.axis_names \
        else P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pspec, {"w1": 0, "w2": 0, "w3": 0}),
                  xspec),
        out_specs=xspec, check_rep=False)
    def fn(params, x_mb):
        params = jax.tree.map(lambda a: a[0], params)  # my stage's group
        return gpipe_forward(params, x_mb, n_stages=n_stages, axis=axis)

    return fn
