"""Optimizers built in pure JAX (no external deps): AdamW and Adafactor.

Moment dtype is configurable (``cfg.opt_dtype``): the 480B-class MoE runs
bf16 moments so weights+optimizer fit the v5e HBM budget (EXPERIMENTS.md
§Dry-run fits-notes); everything else defaults to fp32.

State layout mirrors the param pytree so the sharding specs of a parameter
apply verbatim to its optimizer slots (ZeRO-style storage sharding comes
from the PartitionSpecs in sharding/specs.py, not from this module).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    moment_dtype: str = "float32"


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw(cfg: AdamWConfig = AdamWConfig()) -> Optimizer:
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        lr = _schedule(cfg, step)
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - cfg.b1 ** t
        c2 = 1.0 - cfg.b2 ** t

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32) * scale
            mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
            nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
            mhat = mu32 / c1
            vhat = nu32 / c2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        newp = jax.tree.map(lambda t3: t3[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t3: t3[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t3: t3[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"mu": mu, "nu": nu}

    return Optimizer(init=init, update=update)


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 100


def adafactor(cfg: AdafactorConfig = AdafactorConfig()) -> Optimizer:
    """Factored second moments: O(r+c) state per matrix instead of O(r·c)
    — the memory-saving alternative for the giant models (§Perf knob)."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def slot(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(slot, params,
                            is_leaf=lambda x: isinstance(x, jax.Array))

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        rho = 1.0 - t ** (-cfg.decay)
        warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
        lr = cfg.lr * warm

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + cfg.eps
            if _factored(p):
                vr = rho * s["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * s["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                # u = g / sqrt( (vr/mean(vr)) ⊗ vc )
                denom_r = vr / (jnp.mean(vr, axis=-1, keepdims=True) + 1e-30)
                u = g / (jnp.sqrt(denom_r + 1e-30)[..., None]
                         * jnp.sqrt(vc + 1e-30)[..., None, :])
                news = {"vr": vr, "vc": vc}
            else:
                v = rho * s["v"] + (1 - rho) * g2
                u = g / jnp.sqrt(v + 1e-30)
                news = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
            newp = (p.astype(jnp.float32) - lr * u
                    - lr * cfg.weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), news

        out = jax.tree.map(upd, params, grads, state)
        newp = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        news = jax.tree.map(lambda o: o[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return newp, news

    return Optimizer(init=init, update=update)


def make_optimizer(arch_cfg, kind: str = "adamw") -> Optimizer:
    if kind == "adafactor":
        return adafactor()
    return adamw(AdamWConfig(moment_dtype=arch_cfg.opt_dtype))
