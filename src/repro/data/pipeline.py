"""Deterministic synthetic data pipeline with a checkpointable cursor.

The stream is a pure function of (seed, cursor): after a crash+restore the
pipeline resumes from the manifest's cursor and reproduces the exact same
batches — required for the bitwise crash-equivalence tests of the
NVTraverse checkpoint layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    cursor: int = 0


class TokenPipeline:
    """Batches of next-token-prediction data: tokens[B, S+1]."""

    def __init__(self, cfg, shape, *, seed: int = 0,
                 microbatches: int = 1):
        self.cfg = cfg
        self.B = shape.global_batch
        self.S = shape.seq_len
        self.M = microbatches
        self.state = PipelineState(seed=seed)

    def _tokens(self, cursor: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, cursor]))
        t = rng.integers(0, self.cfg.vocab, size=(self.B, self.S + 1),
                         dtype=np.int64).astype(np.int32)
        return t

    def next_batch(self) -> dict:
        tokens = self._tokens(self.state.cursor)
        batch = {"tokens": tokens}
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed ^ 0xABCD,
                                    self.state.cursor]))
        if self.cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (self.B, self.cfg.enc_seq, self.cfg.d_model),
                dtype=np.float32)
        if self.cfg.family == "vlm":
            batch["vis"] = rng.standard_normal(
                (self.B, self.cfg.vis_tokens, self.cfg.d_model),
                dtype=np.float32)
        self.state.cursor += 1
        if self.M > 1:
            batch = {k: v.reshape((self.M, self.B // self.M) + v.shape[1:])
                     for k, v in batch.items()}
        return batch

    # -- checkpoint integration ------------------------------------------ #
    def snapshot(self) -> dict:
        return dataclasses.asdict(self.state)

    def restore(self, snap: Optional[dict]) -> None:
        if snap:
            self.state = PipelineState(**snap)
