"""NVTrace windowed telemetry: rolling p50/p99/throughput series.

A run-lifetime histogram answers "what was p99" — useless for *when*
and *why*.  :class:`WindowedHistogram` slices time into fixed epochs of
``window_us`` microseconds and keeps one :class:`repro.obs.metrics.
Histogram` per epoch (plus a lifetime aggregate), so the latency series
can be laid next to the event timeline (`repro.obs.timeline`) and a p99
excursion attributed to the snapshot/rebalance/recompile inside its
window.

Design points, all load-bearing for the tests:

* **Caller-supplied time.** ``record(v, t_us)`` takes the timestamp
  instead of reading a clock, so window membership is a pure function
  of its inputs: epoch ``e`` covers ``[e*window_us, (e+1)*window_us)``
  — a sample at exactly ``k*window_us`` lands in window ``k``.
* **Mergeable fixed-epoch snapshots.** Epochs are absolute (derived
  from ``t_us``, not from arrival order), so snapshots from shard
  subprocesses merge per-epoch by elementwise count addition —
  associative and commutative like the registry's histograms.
* **Bounded.** At most ``max_windows`` epochs are retained (oldest
  dropped first, ``dropped_epochs`` counts them); the lifetime
  aggregate never drops.

>>> w = WindowedHistogram(window_us=100.0, lo=1.0, hi=1e4, growth=2.0)
>>> for t, v in [(0, 5), (99.9, 7), (100, 20), (250, 30)]:
...     w.record(v, t_us=t)
>>> [s["epoch"] for s in w.series()]
[0, 1, 2]
>>> w.epoch_of(100.0)        # boundary sample opens window 1
1
>>> w.lifetime.count, w.merged().count
(4, 4)

Same-layout snapshots merge per epoch:

>>> import json
>>> snap = json.loads(json.dumps(w.snapshot()))
>>> twin = WindowedHistogram.from_snapshot(snap)
>>> twin.merge_snapshot(snap)
>>> [s["count"] for s in twin.series()]
[4, 2, 2]
"""
from __future__ import annotations

import math

from .metrics import Histogram


def _hist_snap(h: Histogram) -> dict:
    return {"counts": list(h.counts), "sum": h.sum,
            "min": (None if h.count == 0 else h.min),
            "max": (None if h.count == 0 else h.max)}


def _hist_merge_snap(h: Histogram, lo, hi, growth, snap: dict) -> None:
    other = Histogram(lo=lo, hi=hi, growth=growth)
    other.counts = list(snap["counts"])
    other.sum = float(snap["sum"])
    other.min = math.inf if snap["min"] is None else float(snap["min"])
    other.max = -math.inf if snap["max"] is None else float(snap["max"])
    h.merge(other)


class WindowedHistogram:
    """Per-epoch histograms over fixed ``window_us`` windows.

    ``lo``/``hi``/``growth`` are the bucket layout shared by every
    window and the lifetime aggregate (see
    `metrics.py:log_bounds`); quantiles inherit the bounded
    ``oracle <= q <= oracle*growth`` guarantee per window.
    """

    def __init__(self, window_us: float = 250_000.0, lo: float = 1.0,
                 hi: float = 1e7, growth: float = 1.25,
                 max_windows: int = 512):
        if window_us <= 0:
            raise ValueError("window_us must be > 0")
        if max_windows < 1:
            raise ValueError("max_windows must be >= 1")
        self.window_us = float(window_us)
        self.lo, self.hi, self.growth = float(lo), float(hi), float(growth)
        self.max_windows = int(max_windows)
        self.epochs = {}                  # int epoch -> Histogram
        self.lifetime = Histogram(lo=lo, hi=hi, growth=growth)
        self.dropped_epochs = 0

    def epoch_of(self, t_us: float) -> int:
        """Window index of timestamp ``t_us``: epoch ``e`` covers
        ``[e*window_us, (e+1)*window_us)``."""
        return int(math.floor(t_us / self.window_us))

    def record(self, v: float, t_us: float) -> None:
        e = self.epoch_of(t_us)
        h = self.epochs.get(e)
        if h is None:
            h = self.epochs[e] = Histogram(lo=self.lo, hi=self.hi,
                                           growth=self.growth)
            if len(self.epochs) > self.max_windows:
                del self.epochs[min(self.epochs)]
                self.dropped_epochs += 1
        h.record(v)
        self.lifetime.record(v)

    # -- views --------------------------------------------------------
    def window(self, epoch: int) -> Histogram | None:
        return self.epochs.get(epoch)

    def merged(self) -> Histogram:
        """Aggregate over *retained* windows (== ``lifetime`` exactly
        when nothing was dropped — the consistency invariant the tests
        pin)."""
        out = Histogram(lo=self.lo, hi=self.hi, growth=self.growth)
        for h in self.epochs.values():
            out.merge(h)
        return out

    def series(self, quantiles=(0.5, 0.99)) -> list:
        """Rolling series, one row per retained epoch in time order:
        ``{epoch, t_start_us, t_end_us, count, ops_s, p<q>_us...}``.
        ``ops_s`` is samples-per-second within the window — the
        throughput series for latency samples recorded once per op."""
        rows = []
        for e in sorted(self.epochs):
            h = self.epochs[e]
            row = {"epoch": e,
                   "t_start_us": e * self.window_us,
                   "t_end_us": (e + 1) * self.window_us,
                   "count": h.count,
                   "ops_s": h.count / (self.window_us / 1e6)}
            for q in quantiles:
                row[f"p{int(q * 100)}_us"] = h.quantile(q)
            rows.append(row)
        return rows

    # -- snapshots ----------------------------------------------------
    def snapshot(self) -> dict:
        return {"window_us": self.window_us, "lo": self.lo,
                "hi": self.hi, "growth": self.growth,
                "max_windows": self.max_windows,
                "dropped_epochs": self.dropped_epochs,
                "epochs": {str(e): _hist_snap(h)
                           for e, h in self.epochs.items()},
                "lifetime": _hist_snap(self.lifetime)}

    @classmethod
    def from_snapshot(cls, snap: dict) -> "WindowedHistogram":
        w = cls(window_us=snap["window_us"], lo=snap["lo"],
                hi=snap["hi"], growth=snap["growth"],
                max_windows=snap["max_windows"])
        w.merge_snapshot(snap)
        return w

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another snapshot in, per absolute epoch.  Layouts and
        ``window_us`` must match; epochs add elementwise, so merging is
        associative and commutative — shard order does not matter."""
        if (snap["window_us"] != self.window_us
                or snap["lo"] != self.lo or snap["hi"] != self.hi
                or snap["growth"] != self.growth):
            raise ValueError("cannot merge windowed histograms with "
                             "different window/bucket layouts")
        for es, hs in snap["epochs"].items():
            e = int(es)
            h = self.epochs.get(e)
            if h is None:
                h = self.epochs[e] = Histogram(
                    lo=self.lo, hi=self.hi, growth=self.growth)
            _hist_merge_snap(h, self.lo, self.hi, self.growth, hs)
        _hist_merge_snap(self.lifetime, self.lo, self.hi, self.growth,
                         snap["lifetime"])
        self.dropped_epochs += int(snap.get("dropped_epochs", 0))
        while len(self.epochs) > self.max_windows:
            del self.epochs[min(self.epochs)]
            self.dropped_epochs += 1


class WindowedCounter:
    """Per-epoch event counts over the same fixed-window scheme.

    For throughput of events that are *not* latency samples (rids
    committed, records parsed): ``inc(n, t_us)`` then ``series()`` of
    ``{epoch, count, per_s}``.

    >>> c = WindowedCounter(window_us=1000.0)
    >>> c.inc(3, t_us=0); c.inc(2, t_us=999.9); c.inc(5, t_us=1000.0)
    >>> [(s["epoch"], s["count"]) for s in c.series()]
    [(0, 5), (1, 5)]
    >>> c.total
    10
    """

    def __init__(self, window_us: float = 250_000.0,
                 max_windows: int = 512):
        if window_us <= 0:
            raise ValueError("window_us must be > 0")
        self.window_us = float(window_us)
        self.max_windows = int(max_windows)
        self.epochs = {}              # int epoch -> int count
        self.total = 0
        self.dropped_epochs = 0

    def epoch_of(self, t_us: float) -> int:
        return int(math.floor(t_us / self.window_us))

    def inc(self, n: int, t_us: float) -> None:
        if n < 0:
            raise ValueError("windowed counters are monotone")
        e = self.epoch_of(t_us)
        if e not in self.epochs and len(self.epochs) >= self.max_windows:
            del self.epochs[min(self.epochs)]
            self.dropped_epochs += 1
        self.epochs[e] = self.epochs.get(e, 0) + n
        self.total += n

    def series(self) -> list:
        return [{"epoch": e,
                 "t_start_us": e * self.window_us,
                 "t_end_us": (e + 1) * self.window_us,
                 "count": c,
                 "per_s": c / (self.window_us / 1e6)}
                for e, c in sorted(self.epochs.items())]

    def snapshot(self) -> dict:
        return {"window_us": self.window_us,
                "max_windows": self.max_windows,
                "dropped_epochs": self.dropped_epochs,
                "epochs": {str(e): c for e, c in self.epochs.items()},
                "total": self.total}

    def merge_snapshot(self, snap: dict) -> None:
        if snap["window_us"] != self.window_us:
            raise ValueError("cannot merge windowed counters with "
                             "different window_us")
        for es, c in snap["epochs"].items():
            self.epochs[int(es)] = self.epochs.get(int(es), 0) + int(c)
        self.total += int(snap["total"])
        self.dropped_epochs += int(snap.get("dropped_epochs", 0))
        while len(self.epochs) > self.max_windows:
            del self.epochs[min(self.epochs)]
            self.dropped_epochs += 1
