"""NVTrace spans: request-scoped phase timing that carries the
persistence-instruction bill of each phase.

A :class:`Tracer` maintains a stack of nested :class:`Span`s
(``route -> plan -> commit -> flush/fence -> publish -> snapshot`` in
the serving loop) and a bounded ring buffer of finished-span records
(JSONL via `Tracer.dump_jsonl`).  Every span reports wall time *and*
how many flush/fence/publish/write/trim instructions executed while it
was the innermost open span — and those counts come **free**: a
:class:`PersistListener` rides the same ``faults`` attach surface that
``CrashPlan``/``PersistTrace`` use (the PR 7 ``on_event`` hooks on
``PMem``/``StagedIO``), so no durable-layer code grows a single new
instrumentation site.  A traversal-phase span showing
``counts == {}`` next to a commit-phase span paying all the fences is
the paper's asymmetry, live.

:class:`FaultsTee` fans one ``faults`` slot out to several sinks
(e.g. a ``PersistTrace`` *and* a ``PersistListener`` on the same run),
which is how span-level counts are cross-validated against the trace
checker's event totals.
"""
from __future__ import annotations

import json
import time
from collections import deque


class Span:
    """One phase span; also its own context manager (a generator-based
    ``@contextmanager`` costs ~2x as much per enter/exit, and spans sit
    on the serving hot path)."""

    __slots__ = ("phase", "depth", "t0_ns", "dur_us", "counts", "meta",
                 "_tracer")

    def __init__(self, tracer, phase, depth, t0_ns, meta):
        self._tracer = tracer
        self.phase = phase
        self.depth = depth
        self.t0_ns = t0_ns
        self.dur_us = None
        self.counts = {}
        self.meta = meta

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        tr._stack.pop()
        self.dur_us = (time.perf_counter_ns() - self.t0_ns) / 1e3
        tr._ring.append(self)        # record dicts are built lazily
        if tr.on_span is not None:   # flight-recorder feed (rare)
            tr.on_span(self.to_record(tr.epoch_ns))
        cached = tr._hists.get(self.phase)
        if cached is None or cached[0] != tr.registry.gen:
            cached = (tr.registry.gen, tr.registry.histogram(
                "span_us", lo=0.1, hi=1e8, growth=1.25,
                phase=self.phase))
            tr._hists[self.phase] = cached
        cached[1].record(self.dur_us)
        if self.counts:
            sc = tr.span_counts
            for k, n in self.counts.items():
                sc[k] = sc.get(k, 0) + n
        return False

    def to_record(self, epoch_ns) -> dict:
        return {"span": self.phase, "depth": self.depth,
                "t_us": (self.t0_ns - epoch_ns) / 1e3,
                "dur_us": self.dur_us, "counts": self.counts,
                **({"meta": self.meta} if self.meta else {})}


class _DisabledSpan:
    """Shared no-op context manager for ``enabled=False`` tracers."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


_DISABLED = _DisabledSpan()


class Tracer:
    """Nested phase spans + ring-buffer trace sink.

    * ``span(phase)`` is a context manager; spans nest, and an event
      reported while several spans are open is charged to the
      **innermost** one only, so summing ``counts`` over all finished
      spans never double-counts an instruction.
    * finished spans land in a ring buffer (``maxlen=ring``) as plain
      dicts; ``totals`` accumulates per-kind event counts for the
      tracer's whole lifetime (ring overflow never loses totals).
    * per-span wall time is also recorded into the registry histogram
      ``span_us{phase=...}`` so p50/p99 per phase fall out of the
      ordinary metrics path.
    """

    def __init__(self, registry=None, ring: int = 2048,
                 enabled: bool = True):
        if registry is None:
            from .metrics import get_registry
            registry = get_registry()
        self.registry = registry
        self.enabled = enabled
        self.epoch_ns = time.perf_counter_ns()
        self._ring = deque(maxlen=ring)
        self._stack = []
        self._hists = {}        # phase -> (registry gen, histogram):
                                # skips the registry label lookup per
                                # span exit, invalidated by reset()
        self.totals = {}
        self.span_counts = {}   # per-kind sums over *finished* spans
        self.on_span = None     # optional callback(record) on span
                                # close — the FlightRecorder feed
                                # (`repro.obs.timeline`); one attr
                                # check per exit when unset

    # -- spans --------------------------------------------------------
    @property
    def current(self):
        return self._stack[-1] if self._stack else None

    def span(self, phase: str, **meta):
        """Open a phase span (use as ``with tracer.span("commit") as s``;
        ``s`` is None on a disabled tracer).  The span closes — and is
        recorded — when the ``with`` block exits."""
        if not self.enabled:
            return _DISABLED
        s = Span(self, phase, len(self._stack),
                 time.perf_counter_ns(), meta)
        self._stack.append(s)
        return s

    # -- event accounting (called by PersistListener) -----------------
    def count_event(self, kind: str, n: int = 1) -> None:
        self.totals[kind] = self.totals.get(kind, 0) + n
        if self._stack:
            s = self._stack[-1]
            s.counts[kind] = s.counts.get(kind, 0) + n

    # -- sinks --------------------------------------------------------
    def records(self) -> list:
        return [s.to_record(self.epoch_ns) for s in self._ring]

    def dump_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for s in self._ring:
                f.write(json.dumps(s.to_record(self.epoch_ns)) + "\n")


class PersistListener:
    """Metrics-emitting ``faults`` attachment for ``PMem``/``StagedIO``.

    Implements the crash-plan surface (``on_site`` — a no-op, it never
    fires — and ``on_event``) so it can sit in the ``faults`` slot that
    ``CrashPlan.attach`` uses.  Every persistence instruction becomes a
    registry counter ``persist_events_total{kind=...}`` and is charged
    to the tracer's innermost open span.
    """

    def __init__(self, tracer=None, registry=None):
        if registry is None and tracer is not None:
            registry = tracer.registry
        if registry is None:
            from .metrics import get_registry
            registry = get_registry()
        self.tracer = tracer
        self.registry = registry
        self.totals = {}
        self._counters = {}   # kind -> (registry gen, counter) hot cache

    def attach(self, *objs) -> "PersistListener":
        for o in objs:
            o.faults = self
        return self

    def on_site(self, kind: str, target: str) -> None:
        return None

    def on_event(self, kind: str, target: str = "", **meta) -> None:
        self.totals[kind] = self.totals.get(kind, 0) + 1
        cached = self._counters.get(kind)
        if cached is None or cached[0] != self.registry.gen:
            cached = (self.registry.gen, self.registry.counter(
                "persist_events_total", kind=kind))
            self._counters[kind] = cached
        cached[1].inc()
        if self.tracer is not None:
            self.tracer.count_event(kind)


class FaultsTee:
    """Fan one ``faults`` slot out to several sinks.

    ``on_site`` forwards to every sink that defines it (a sink that
    raises — a firing ``CrashPlan`` — propagates); ``on_event``
    likewise.  Used to run a ``PersistTrace`` and a
    :class:`PersistListener` over the *same* instruction stream, which
    is how the two observability layers cross-validate.
    """

    def __init__(self, *sinks):
        self.sinks = tuple(sinks)

    def attach(self, *objs) -> "FaultsTee":
        for o in objs:
            o.faults = self
        return self

    def on_site(self, kind: str, target: str) -> None:
        for s in self.sinks:
            fn = getattr(s, "on_site", None)
            if fn is not None:
                fn(kind, target)

    def on_event(self, kind: str, target: str = "", **meta) -> None:
        for s in self.sinks:
            fn = getattr(s, "on_event", None)
            if fn is not None:
                fn(kind, target, **meta)
