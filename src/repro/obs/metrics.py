"""NVTrace metrics: a process-local registry of counters, gauges and
fixed log-spaced-bucket histograms.

The paper's whole argument is an *accounting* one — traversal persists
nothing, so every microsecond and every fence concentrates at the
destination — and this module is the ledger that argument is read from
at runtime.  Three metric kinds, one registry:

* :class:`Counter` — monotone event totals (records parsed, flushes
  issued, migrations completed).
* :class:`Gauge` — last-written level (per-shard load, imbalance).
* :class:`Histogram` — fixed log-spaced buckets with an explicit
  overflow bucket.  Quantiles are *deterministic and bounded*: for any
  recorded distribution, ``oracle <= quantile(q) <= oracle * growth``
  (the bucket upper edge), so p50/p99/p999 are exact up to the
  configured bucket resolution — and two histograms with the same
  layout merge by elementwise count addition, which is what makes
  snapshots mergeable across shards and subprocesses.

Snapshots are plain JSON (`MetricsRegistry.snapshot` /
`MetricsRegistry.from_snapshot` / `MetricsRegistry.merge_snapshot`)
and export to Prometheus text (`MetricsRegistry.to_prometheus`);
``tools/metrics_dump.py`` is the CLI over both.

>>> reg = MetricsRegistry()
>>> reg.counter("ops_total", layer="log").inc(3)
>>> reg.counter("ops_total", layer="log").value
3
>>> h = reg.histogram("lat_us", lo=1.0, hi=1000.0, growth=2.0)
>>> for v in [1, 2, 3, 500]:
...     h.record(v)
>>> h.count, h.quantile(0.5), h.quantile(0.99)
(4, 2.0, 512.0)

Round-trip through JSON and merge — the cross-shard path:

>>> import json
>>> snap = json.loads(json.dumps(reg.snapshot()))
>>> twin = MetricsRegistry.from_snapshot(snap)
>>> twin.merge_snapshot(snap)          # two identical shards
>>> twin.counter("ops_total", layer="log").value
6
>>> twin.histogram("lat_us", lo=1.0, hi=1000.0, growth=2.0).count
8
"""
from __future__ import annotations

import json
import math
from bisect import bisect_left
from dataclasses import dataclass, field


class Counter:
    """Monotone counter.  ``inc`` only; negative increments raise."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters are monotone; inc(n >= 0)")
        self.value += n


class Gauge:
    """Last-written level (may go up or down)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


def log_bounds(lo: float, hi: float, growth: float) -> tuple:
    """Bucket upper edges ``lo * growth**i`` covering ``[0, hi]``.

    >>> log_bounds(1.0, 8.0, 2.0)
    (1.0, 2.0, 4.0, 8.0)
    """
    if not (lo > 0 and hi >= lo and growth > 1.0):
        raise ValueError("need lo > 0, hi >= lo, growth > 1")
    n = max(1, math.ceil(math.log(hi / lo) / math.log(growth) - 1e-9) + 1)
    return tuple(lo * growth ** i for i in range(n))


class Histogram:
    """Fixed log-spaced-bucket histogram with an overflow bucket.

    ``counts`` has ``len(bounds) + 1`` slots: bucket *i* holds values in
    ``(bounds[i-1], bounds[i]]`` (bucket 0 is ``[0, bounds[0]]``), the
    last slot holds everything past ``bounds[-1]``.  Quantiles return
    the containing bucket's upper edge — or the observed ``max`` for
    the overflow bucket — so they never under-report.
    """

    __slots__ = ("lo", "hi", "growth", "bounds", "counts",
                 "sum", "min", "max")

    def __init__(self, lo: float = 1.0, hi: float = 1e7,
                 growth: float = 1.25):
        self.lo, self.hi, self.growth = float(lo), float(hi), float(growth)
        self.bounds = log_bounds(lo, hi, growth)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def count(self) -> int:
        return sum(self.counts)

    def record(self, v: float) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding the ``q``-th observation.

        Bounded by construction: ``oracle <= quantile(q) <=
        oracle * growth`` for in-range data; overflow returns the
        observed max.  Returns ``nan`` when empty.
        """
        total = self.count
        if total == 0:
            return math.nan
        rank = min(max(1, math.ceil(q * total)), total)
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max  # pragma: no cover - rank <= total

    def merge(self, other: "Histogram") -> None:
        """Elementwise count addition; layouts must match exactly."""
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


@dataclass
class _Entry:
    kind: str
    name: str
    labels: dict
    obj: object = field(default=None)


class MetricsRegistry:
    """Name+labels → metric object; one kind per name.

    ``counter``/``gauge``/``histogram`` are get-or-create and memoized,
    so call sites just ask for the metric every time — no wiring phase.
    """

    def __init__(self):
        self._entries = {}   # (name, label_key) -> _Entry
        self._kinds = {}     # name -> kind
        self.gen = 0         # bumped by reset(): hot paths that cache a
                             # metric handle key it on (registry, gen)

    # -- get-or-create ------------------------------------------------
    def _get(self, kind, name, labels, factory):
        seen = self._kinds.get(name)
        if seen is not None and seen != kind:
            raise ValueError(f"metric {name!r} already registered "
                             f"as a {seen}, not a {kind}")
        key = (name, _label_key(labels))
        e = self._entries.get(key)
        if e is None:
            e = _Entry(kind, name, dict(labels), factory())
            self._entries[key] = e
            self._kinds[name] = kind
        return e.obj

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, lo: float = 1.0, hi: float = 1e7,
                  growth: float = 1.25, **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(lo=lo, hi=hi, growth=growth))

    # -- introspection ------------------------------------------------
    def entries(self):
        return list(self._entries.values())

    def reset(self) -> None:
        self._entries.clear()
        self._kinds.clear()
        self.gen += 1

    # -- snapshots ----------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able snapshot of every registered metric."""
        out = {"counters": [], "gauges": [], "histograms": []}
        for e in self._entries.values():
            if e.kind == "counter":
                out["counters"].append(
                    {"name": e.name, "labels": e.labels,
                     "value": e.obj.value})
            elif e.kind == "gauge":
                out["gauges"].append(
                    {"name": e.name, "labels": e.labels,
                     "value": e.obj.value})
            else:
                h = e.obj
                out["histograms"].append(
                    {"name": e.name, "labels": e.labels,
                     "lo": h.lo, "hi": h.hi, "growth": h.growth,
                     "counts": list(h.counts), "sum": h.sum,
                     "min": (None if h.count == 0 else h.min),
                     "max": (None if h.count == 0 else h.max)})
        return out

    @classmethod
    def from_snapshot(cls, snap: dict) -> "MetricsRegistry":
        reg = cls()
        reg.merge_snapshot(snap)
        return reg

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another snapshot in: counters/histograms add, gauges
        take the incoming value.  Associative and commutative for the
        additive kinds — shard order does not matter."""
        for c in snap.get("counters", ()):
            self.counter(c["name"], **c["labels"]).inc(int(c["value"]))
        for g in snap.get("gauges", ()):
            self.gauge(g["name"], **g["labels"]).set(g["value"])
        for hs in snap.get("histograms", ()):
            h = self.histogram(hs["name"], lo=hs["lo"], hi=hs["hi"],
                               growth=hs["growth"], **hs["labels"])
            other = Histogram(lo=hs["lo"], hi=hs["hi"],
                              growth=hs["growth"])
            other.counts = list(hs["counts"])
            other.sum = float(hs["sum"])
            other.min = math.inf if hs["min"] is None else float(hs["min"])
            other.max = -math.inf if hs["max"] is None else float(hs["max"])
            h.merge(other)

    # -- exporters ----------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition (histograms as cumulative
        ``_bucket{le=...}`` series plus ``_sum``/``_count``)."""
        lines = []
        typed = set()
        for e in sorted(self._entries.values(),
                        key=lambda e: (e.name, _label_key(e.labels))):
            if e.name not in typed:
                lines.append(f"# TYPE {e.name} {e.kind}")
                typed.add(e.name)
            if e.kind in ("counter", "gauge"):
                lines.append(f"{e.name}{_promlabels(e.labels)} "
                             f"{e.obj.value}")
            else:
                h = e.obj
                cum = 0
                for b, c in zip(h.bounds, h.counts):
                    cum += c
                    lines.append(
                        f"{e.name}_bucket"
                        f"{_promlabels(e.labels, le=repr(b))} {cum}")
                lines.append(f"{e.name}_bucket"
                             f"{_promlabels(e.labels, le='+Inf')} "
                             f"{h.count}")
                lines.append(f"{e.name}_sum{_promlabels(e.labels)} "
                             f"{h.sum}")
                lines.append(f"{e.name}_count{_promlabels(e.labels)} "
                             f"{h.count}")
        return "\n".join(lines) + "\n"

    def dump_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)


def _promlabels(labels: dict, **extra) -> str:
    items = dict(labels, **extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(items.items()))
    return "{" + body + "}"


REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-default registry (what the serving/core wiring
    writes to unless handed an explicit one)."""
    return REGISTRY
