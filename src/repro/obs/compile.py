"""NVTrace compile-event tracking: who paid for that recompile?

The durable-map stack has two jit seams where a shape change silently
buys a fresh XLA compile on the serving path:

* ``core/sharded.py`` — the ``shard_map`` update/lookup closures are
  cached per ``(n_shards, n_buckets, nb_max)``; a re-split that changes
  the **max range width** misses the cache and recompiles (the 315
  us/op ``rebalance_live`` tax on the ROADMAP).
* ``core/migrate.py`` — ``update_parallel`` is jitted with static
  ``n_buckets``; every capacity-ladder step (and every new padded
  batch width) retraces.

:class:`CompileTracker` wraps those seams.  Callers that *know why* a
compile is about to happen declare it with ``tracker.reason(...)``
(``"resplit_width_change"``, ``"capacity_ladder"``); any first call on
a never-seen ``(site, static-key, arg-shapes)`` signature is timed to
a blocking result and recorded as a :class:`CompileEvent` attributed
to the innermost active reason (``"steady"`` when none — i.e. a
cold-start compile, not a stall anyone caused).  Steady-state calls on
warm signatures pay one set lookup.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass
class CompileEvent:
    """One first-call stall on a fresh jit/shard_map signature."""
    site: str          # e.g. "sharded.update", "migrate.update_parallel"
    key: str           # static config part of the signature
    trigger: str       # "resplit_width_change" | "capacity_ladder" | ...
    stall_us: float

    def to_dict(self) -> dict:
        return {"site": self.site, "key": self.key,
                "trigger": self.trigger, "stall_us": self.stall_us}


def _shape_sig(args, kwargs):
    import jax
    leaves = jax.tree_util.tree_leaves((args, kwargs))
    return tuple((tuple(x.shape), str(x.dtype)) if hasattr(x, "shape")
                 else x if isinstance(x, (int, float, str, bool, type(None)))
                 else type(x).__name__
                 for x in leaves)


class CompileTracker:
    """First-call stall recorder with trigger attribution."""

    def __init__(self, registry=None):
        if registry is None:
            from .metrics import get_registry
            registry = get_registry()
        self.registry = registry
        self.enabled = True
        self.events = []
        self._seen = set()
        self._reasons = []

    # -- attribution --------------------------------------------------
    @property
    def current_reason(self) -> str:
        return self._reasons[-1] if self._reasons else "steady"

    @contextmanager
    def reason(self, trigger: str):
        """Attribute compiles inside the block to ``trigger``."""
        self._reasons.append(trigger)
        try:
            yield
        finally:
            self._reasons.pop()

    # -- recording ----------------------------------------------------
    def first_seen(self, site: str, key) -> bool:
        """True exactly once per (site, key); marks the pair seen."""
        sig = (site, key)
        if sig in self._seen:
            return False
        self._seen.add(sig)
        return True

    def record(self, site: str, key, stall_us: float,
               trigger: str = None) -> None:
        trigger = trigger if trigger is not None else self.current_reason
        ev = CompileEvent(site, str(key), trigger, float(stall_us))
        self.events.append(ev)
        self.registry.counter("compile_events_total",
                              site=site, trigger=trigger).inc()
        self.registry.counter("compile_stall_us_total",
                              site=site, trigger=trigger).inc(
                                  int(stall_us))

    def instrument(self, site: str, key, fn):
        """Wrap a jitted callable: the first call on each fresh
        ``(site, key, arg-shapes)`` signature is timed to a blocking
        result and recorded; warm calls pass straight through."""
        tracker = self

        def wrapped(*args, **kwargs):
            if not tracker.enabled:
                return fn(*args, **kwargs)
            sig = (site, key, _shape_sig(args, kwargs))
            if sig in tracker._seen:
                return fn(*args, **kwargs)
            tracker._seen.add(sig)
            import jax
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            out = jax.block_until_ready(out)
            tracker.record(site, key,
                           (time.perf_counter() - t0) * 1e6)
            return out

        wrapped.__wrapped__ = fn
        return wrapped

    # -- aggregation --------------------------------------------------
    def stats(self) -> dict:
        """Per-trigger totals: ``{trigger: {events, stall_us}}``."""
        out = {}
        for ev in self.events:
            d = out.setdefault(ev.trigger, {"events": 0, "stall_us": 0.0})
            d["events"] += 1
            d["stall_us"] += ev.stall_us
        return out

    def reset(self) -> None:
        self.events.clear()
        self._seen.clear()


TRACKER = CompileTracker()


def get_tracker() -> CompileTracker:
    """The process-default tracker (what the core seams record to)."""
    return TRACKER
