"""NVTrace: runtime observability for the serving + durable-map stack.

Three pieces, one theme — make the paper's phase asymmetry (traversal
persists nothing; every fence lands at the destination) *measurable on
a live process* instead of only provable by crash sweeps and lint:

* :mod:`repro.obs.metrics` — counters / gauges / log-bucket histograms
  in a mergeable, snapshottable registry.
* :mod:`repro.obs.spans` — nested phase spans whose per-span
  flush/fence/publish counts ride the existing ``faults`` hook surface.
* :mod:`repro.obs.compile` — first-call jit/shard_map stall tracking
  with trigger attribution (re-split width change, capacity ladder).
"""
from .compile import CompileEvent, CompileTracker, get_tracker
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .spans import FaultsTee, PersistListener, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "Span", "Tracer", "PersistListener", "FaultsTee",
    "CompileEvent", "CompileTracker", "get_tracker",
]
