"""NVTrace: runtime observability for the serving + durable-map stack.

Make the paper's phase asymmetry (traversal persists nothing; every
fence lands at the destination) *measurable on a live process* instead
of only provable by crash sweeps and lint — and, since LoadScope,
measurable *over time under load*:

* :mod:`repro.obs.metrics` — counters / gauges / log-bucket histograms
  in a mergeable, snapshottable registry.
* :mod:`repro.obs.spans` — nested phase spans whose per-span
  flush/fence/publish counts ride the existing ``faults`` hook surface.
* :mod:`repro.obs.compile` — first-call jit/shard_map stall tracking
  with trigger attribution (re-split width change, capacity ladder).
* :mod:`repro.obs.windows` — fixed-epoch windowed histograms/counters:
  the rolling p50/p99/throughput series.
* :mod:`repro.obs.timeline` — timestamped event annotations aligned
  with the latency series (excursion attribution) and a bounded
  flight recorder dumped on SLO breach or crash.
* :mod:`repro.obs.loadgen` — deterministic open/closed-loop workload
  driver that ties all of the above together against
  ``RequestLog``/``ServeEngine``.
"""
from .compile import CompileEvent, CompileTracker, get_tracker
from .loadgen import LoadHarness, LoadSpec, Schedule, make_schedule
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .spans import FaultsTee, PersistListener, Span, Tracer
from .timeline import EventTimeline, FlightRecorder, attribute_excursions
from .windows import WindowedCounter, WindowedHistogram

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "Span", "Tracer", "PersistListener", "FaultsTee",
    "CompileEvent", "CompileTracker", "get_tracker",
    "WindowedHistogram", "WindowedCounter",
    "EventTimeline", "FlightRecorder", "attribute_excursions",
    "LoadSpec", "Schedule", "make_schedule", "LoadHarness",
]
