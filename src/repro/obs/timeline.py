"""NVTrace event timeline + flight recorder: *why* latency moved.

Two consumers of the same clock as the windowed latency series
(`repro.obs.windows`):

* :class:`EventTimeline` — timestamped annotations (snapshot/truncate,
  migration rounds, rebalance triggers, compile stalls, crash/recovery
  boundaries).  Because annotations and latency samples share one
  ``t_us`` axis, `timeline.py:attribute_excursions` can hand
  each p99 excursion window the concrete events inside it.
* :class:`FlightRecorder` — a bounded ring of the last-N observability
  entries (finished spans, persistence instructions, annotations),
  dumped to JSON on SLO breach or injected crash.  The dump is the
  post-mortem: what the process was doing in the moments before the
  breach, plus — on the subsequent reload — the per-phase
  restart/recovery timing breakdown (`engine.py:RequestLog`
  ``restart_timing``).

Both take caller-supplied or shared-epoch time so they align with the
deterministic load schedules in `repro.obs.loadgen`.

>>> tl = EventTimeline(epoch_ns=0)
>>> _ = tl.annotate("snapshot", t_us=150.0, horizon=12)
>>> _ = tl.annotate("truncate", t_us=151.0, n_trimmed=3)
>>> [e["kind"] for e in tl.in_range(100.0, 200.0)]
['snapshot', 'truncate']

A window whose p99 towers over the median gets its events attached:

>>> series = [{"epoch": 0, "t_start_us": 0.0, "t_end_us": 100.0,
...            "count": 9, "p99_us": 10.0},
...           {"epoch": 1, "t_start_us": 100.0, "t_end_us": 200.0,
...            "count": 9, "p99_us": 80.0},
...           {"epoch": 2, "t_start_us": 200.0, "t_end_us": 300.0,
...            "count": 9, "p99_us": 10.0}]
>>> exc = attribute_excursions(series, tl, factor=3.0)
>>> [(e["epoch"], [v["kind"] for v in e["events"]]) for e in exc]
[(1, ['snapshot', 'truncate'])]
"""
from __future__ import annotations

import json
import time
from collections import deque
from statistics import median


class EventTimeline:
    """Append-only list of ``{t_us, kind, **meta}`` annotations.

    ``t_us`` is relative to ``epoch_ns`` (defaults to construction
    time); pass a tracer's ``epoch_ns`` so spans, annotations and
    latency windows share one axis.  ``annotate`` without ``t_us``
    stamps *now*; explicit ``t_us`` keeps tests deterministic.
    """

    def __init__(self, epoch_ns: int | None = None, recorder=None):
        self.epoch_ns = (time.perf_counter_ns()
                         if epoch_ns is None else epoch_ns)
        self.events = []
        self.recorder = recorder    # optional FlightRecorder mirror

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self.epoch_ns) / 1e3

    def annotate(self, kind: str, t_us: float | None = None,
                 **meta) -> dict:
        e = {"t_us": self.now_us() if t_us is None else float(t_us),
             "kind": str(kind), **meta}
        self.events.append(e)
        if self.recorder is not None:
            self.recorder.note("annotation", e)
        return e

    def in_range(self, t0_us: float, t1_us: float) -> list:
        """Annotations with ``t0_us <= t_us < t1_us`` (same half-open
        convention as the latency windows)."""
        return [e for e in self.events if t0_us <= e["t_us"] < t1_us]

    def to_list(self) -> list:
        return list(self.events)


def attribute_excursions(series, timeline, factor: float = 2.0,
                         quantile_key: str = "p99_us",
                         min_count: int = 1,
                         slack_us: float = 0.0) -> list:
    """Attach timeline events to latency-excursion windows.

    A window is an *excursion* when its ``quantile_key`` value is at
    least ``factor`` times the median of that value across all windows
    with ``count >= min_count``.  Each excursion row carries the
    annotations whose ``t_us`` falls inside the window (widened by
    ``slack_us`` on the left, so an event logged just before the
    boundary — e.g. a snapshot whose cost lands on the next sample —
    still attributes).

    Returns ``[{epoch, t_start_us, t_end_us, <quantile_key>,
    baseline_us, count, events}]`` sorted by epoch; windows with no
    matching events still appear (``events == []``) so "unexplained
    excursion" is a visible state, not a silent drop.
    """
    rows = [r for r in series if r.get("count", 0) >= min_count
            and r.get(quantile_key) == r.get(quantile_key)]  # drop NaN
    if not rows:
        return []
    baseline = median(r[quantile_key] for r in rows)
    out = []
    for r in rows:
        if baseline > 0 and r[quantile_key] >= factor * baseline:
            out.append({
                "epoch": r["epoch"],
                "t_start_us": r["t_start_us"],
                "t_end_us": r["t_end_us"],
                quantile_key: r[quantile_key],
                "baseline_us": baseline,
                "count": r["count"],
                "events": timeline.in_range(
                    r["t_start_us"] - slack_us, r["t_end_us"]),
            })
    return out


class FlightRecorder:
    """Bounded ring of the last-``capacity`` observability entries.

    Three entry types, all ``{"type", "t_us", ...}``:

    * ``"span"`` — finished spans, fed via ``Tracer.on_span``
      (`spans.py:Tracer`);
    * ``"persist"`` — persistence instructions, fed by sitting in a
      ``faults`` slot (tee alongside the normal listener with
      `spans.py:FaultsTee`);
    * ``"annotation"`` — timeline events, mirrored when the timeline
      is built with ``recorder=``.

    ``dump()`` freezes the ring to a JSON-able dict (optionally written
    to a file) stamped with a reason (``"slo_breach"`` /
    ``"injected_crash"`` / ...) and, when supplied, the per-phase
    restart timing of the post-crash reload.  Dumps are cheap and the
    ring keeps recording afterwards.

    >>> fr = FlightRecorder(capacity=2, clock=lambda: 42.0)
    >>> for i in range(3):
    ...     fr.note("annotation", {"kind": "snapshot", "i": i})
    >>> [e["i"] for e in fr.entries()]      # ring keeps the last 2
    [1, 2]
    >>> d = fr.dump("slo_breach")
    >>> d["reason"], d["n_entries"], d["dropped"]
    ('slo_breach', 2, 1)
    """

    def __init__(self, capacity: int = 512, clock=None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring = deque(maxlen=self.capacity)
        self._clock = clock
        self._epoch_ns = time.perf_counter_ns()
        self.seen = 0            # entries ever noted (ring may drop)
        self.dumps = []          # reasons, in order

    def now_us(self) -> float:
        if self._clock is not None:
            return float(self._clock())
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    # -- feeds --------------------------------------------------------
    def note(self, typ: str, entry: dict) -> None:
        e = dict(entry)
        e["type"] = typ
        e.setdefault("t_us", self.now_us())
        self._ring.append(e)
        self.seen += 1

    def on_span(self, record: dict) -> None:
        """``Tracer.on_span`` callback: record is ``Span.to_record``
        output (already carries ``t_us`` on the tracer's epoch)."""
        self.note("span", record)

    # faults-slot surface (sit behind a FaultsTee):
    def on_site(self, kind: str, target: str) -> None:
        return None

    def on_event(self, kind: str, target: str = "", **meta) -> None:
        self.note("persist", {"kind": kind, "target": target, **meta})

    # -- dump ---------------------------------------------------------
    def entries(self) -> list:
        return list(self._ring)

    def dump(self, reason: str, path=None, restart_timing=None,
             extra=None) -> dict:
        doc = {"reason": reason,
               "t_us": self.now_us(),
               "capacity": self.capacity,
               "n_entries": len(self._ring),
               "seen": self.seen,
               "dropped": self.seen - len(self._ring),
               "entries": self.entries()}
        if restart_timing is not None:
            doc["restart_timing"] = dict(restart_timing)
        if extra:
            doc.update(extra)
        self.dumps.append(reason)
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
        return doc
