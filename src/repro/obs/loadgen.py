"""LoadScope: deterministic open/closed-loop load against the serving
stack, with windowed telemetry, an event timeline and a flight recorder.

The ROADMAP's serving tier is judged under *sustained* load — p50/p99
over time, not one lifetime aggregate — and the paper's own evaluation
is exactly that shape (throughput under concurrent load, §6).  This
module is the driver:

* **Deterministic schedules.** :func:`make_schedule` turns a
  :class:`LoadSpec` into plain numpy arrays — op kind (read/update),
  key-popularity rank (zipf or uniform), open-loop arrival offsets —
  seeded and free of wall-clock randomness: same spec ⇒ bit-identical
  schedule (``Schedule.fingerprint``).  Only the *execution* reads a
  clock.
* **Open vs closed loop.** Closed loop issues the next op the moment
  the previous completes (measures service capacity); open loop paces
  ops by the precomputed arrival times and measures latency from
  *scheduled arrival* to completion, so a stall shows up as queueing
  delay instead of silently back-pressuring the generator.
* **The three LoadScope layers** ride along: latency samples land in a
  :class:`repro.obs.windows.WindowedHistogram` (rolling p50/p99 +
  ops/s), the :class:`repro.obs.timeline.EventTimeline` collects
  snapshot/truncate/compile/crash/recovery annotations on the same
  clock, and a :class:`repro.obs.timeline.FlightRecorder` rings the
  last-N spans + persistence instructions, dumping on SLO breach or
  injected crash (with the per-phase restart breakdown after the
  reload).

Two executors: :class:`LoadHarness` drives a ``RequestLog`` directly
(update = durable batch commit, read = ``took_effect`` probe) and
— via ``engine=`` — a full ``ServeEngine`` (update = model traversal +
commit, read = dedup-hit serve).

>>> import numpy as np
>>> s = make_schedule(LoadSpec(n_ops=4, seed=7, mode="open",
...                            rate_ops_s=1000.0))
>>> t = make_schedule(LoadSpec(n_ops=4, seed=7, mode="open",
...                            rate_ops_s=1000.0))
>>> s.fingerprint() == t.fingerprint()      # same seed, same schedule
True
>>> bool(np.all(np.diff(s.arrival_us) > 0))  # arrivals strictly ordered
True
>>> u = make_schedule(LoadSpec(n_ops=4, seed=8, mode="open",
...                            rate_ops_s=1000.0))
>>> s.fingerprint() == u.fingerprint()
False
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import asdict, dataclass
from typing import Optional

import numpy as np

from .compile import get_tracker
from .metrics import MetricsRegistry
from .spans import FaultsTee, Tracer
from .timeline import EventTimeline, FlightRecorder, attribute_excursions
from .windows import WindowedCounter, WindowedHistogram


@dataclass(frozen=True)
class LoadSpec:
    """One load run, fully determined (schedule-wise) by its fields.

    ``mode``: ``"closed"`` (issue-on-completion) or ``"open"``
    (seeded-exponential arrivals at ``rate_ops_s``).  ``dist``:
    ``"zipf"`` (popularity rank ~ zipf(``skew``), skew > 1) or
    ``"uniform"`` over the retention window.  Reads probe
    ``took_effect`` on committed rids by popularity rank
    (rank 1 = newest); updates commit a fresh ``batch`` of rids and
    evict past the ``retain`` window.  Every ``snapshot_every``-th
    commit publishes a truncating snapshot *inside* the measured op —
    that is the excursion the timeline must attribute.
    """
    n_ops: int = 200
    seed: int = 0
    mode: str = "closed"
    dist: str = "zipf"
    skew: float = 1.2
    update_frac: float = 0.6
    batch: int = 4
    rate_ops_s: float = 400.0
    window_us: float = 20_000.0
    max_windows: int = 4096
    retain: int = 128
    snapshot_every: Optional[int] = 25
    warmup_ops: int = 8
    payload_len: int = 4
    excursion_factor: float = 2.0
    slo_p99_us: Optional[float] = None
    crash_at_op: Optional[int] = None
    crash_evict: str = "torn"
    shards: Optional[int] = None
    rebalance: bool = False
    capacity: int = 1 << 12
    ring: int = 512


@dataclass
class Schedule:
    """Precomputed per-op decisions; arrays all length ``n_ops``."""
    spec: LoadSpec
    is_update: np.ndarray       # bool: commit batch vs took_effect probe
    rank: np.ndarray            # int >= 1: popularity rank for reads
    arrival_us: np.ndarray      # float: open-loop arrival offsets (0s closed)

    def fingerprint(self) -> str:
        h = hashlib.sha256()
        h.update(json.dumps(asdict(self.spec), sort_keys=True).encode())
        for a in (self.is_update, self.rank, self.arrival_us):
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()[:16]


def make_schedule(spec: LoadSpec) -> Schedule:
    """Deterministic schedule from the spec alone — no wall clock, no
    global RNG.  Zipf ranks are clipped to the retention window (the
    tail of an unclipped zipf aims past any finite committed set)."""
    if spec.mode not in ("closed", "open"):
        raise ValueError(f"unknown mode {spec.mode!r}")
    if spec.dist not in ("zipf", "uniform"):
        raise ValueError(f"unknown dist {spec.dist!r}")
    rng = np.random.default_rng(spec.seed)
    n = int(spec.n_ops)
    is_update = rng.random(n) < spec.update_frac
    if spec.dist == "zipf":
        if not spec.skew > 1.0:
            raise ValueError("zipf needs skew > 1")
        rank = np.minimum(rng.zipf(spec.skew, n), spec.retain)
    else:
        rank = rng.integers(1, max(2, spec.retain + 1), n)
    if spec.mode == "open":
        if not spec.rate_ops_s > 0:
            raise ValueError("open loop needs rate_ops_s > 0")
        gaps = rng.exponential(1e6 / spec.rate_ops_s, n)
        arrival_us = np.cumsum(gaps)
    else:
        arrival_us = np.zeros(n)
    return Schedule(spec=spec, is_update=is_update,
                    rank=rank.astype(np.int64), arrival_us=arrival_us)


def _wait_until(now_us, target_us: float) -> None:
    """Sleep-then-spin to the open-loop release point: coarse sleep to
    ~200us short of the target, then spin out the remainder (a bare
    ``time.sleep`` overshoots by the scheduler quantum)."""
    while True:
        dt = target_us - now_us()
        if dt <= 0:
            return
        if dt > 500.0:
            time.sleep((dt - 200.0) / 1e6)


class LoadHarness:
    """Run one :class:`LoadSpec` against a ``RequestLog`` (default) or
    a ``ServeEngine`` and return the LoadScope report.

    ``flight_path`` (optional) is where the flight-recorder dump is
    written when an SLO breach or the injected crash fires; the report
    always carries the dump inline too.  With ``engine=`` a factory
    ``lambda registry, timeline: ServeEngine(...)`` supplies the
    engine; updates serve fresh rids (traversal + commit), reads
    re-serve committed rids (dedup hits).
    """

    def __init__(self, root, spec: LoadSpec, flight_path=None,
                 engine=None):
        self.root = root
        self.spec = spec
        self.flight_path = flight_path
        self.engine_factory = engine

    # -- wiring -------------------------------------------------------
    def _tee_recorder(self, io) -> None:
        # ride the recorder alongside the normal persistence listener
        sinks = [s for s in (io.faults, self.recorder) if s is not None]
        FaultsTee(*sinks).attach(io)

    def _open_log(self):
        from ..serving.engine import RequestLog
        sp = self.spec
        log = RequestLog(self.root, capacity=sp.capacity,
                         shards=sp.shards, rebalance=sp.rebalance,
                         registry=self.registry, tracer=self.tracer,
                         timeline=self.timeline)
        self._tee_recorder(log.io)
        return log

    def _open_engine(self):
        eng = self.engine_factory(registry=self.registry,
                                  timeline=self.timeline)
        self._tee_recorder(eng.log.io)
        return eng

    # -- the run ------------------------------------------------------
    def run(self) -> dict:
        sp = self.spec
        sched = make_schedule(sp)
        self.registry = MetricsRegistry()
        self.tracer = Tracer(registry=self.registry, ring=sp.ring)
        self.timeline = EventTimeline(epoch_ns=self.tracer.epoch_ns)
        self.recorder = FlightRecorder(capacity=sp.ring,
                                       clock=self.timeline.now_us)
        self.timeline.recorder = self.recorder
        self.tracer.on_span = self.recorder.on_span
        engine_mode = self.engine_factory is not None
        eng = self._open_engine() if engine_mode else None
        log = eng.log if engine_mode else self._open_log()
        tracker = get_tracker()
        n_compile_seen = len(tracker.events)

        rng = np.random.default_rng(sp.seed ^ 0x10ad)
        acked: list = []          # rids committed by this run, in order
        next_rid = 0
        crash_report = None
        breach_dumped = False

        def _payload(r):
            return [int(r) & 0xFF] * sp.payload_len

        def _commit(rids):
            nonlocal eng, log
            if engine_mode:
                prompts = {int(r): self._prompt(rng, r) for r in rids}
                eng.serve(prompts, n_new=2)
            else:
                evict = log.expired_rids(sp.retain)
                log.commit({int(r): _payload(r) for r in rids},
                           evict=evict)
            acked.extend(int(r) for r in rids)

        def _read(rank):
            start = max(0, len(acked) - int(rank) - sp.batch + 1)
            probe = acked[start:start + sp.batch] or [0]
            if engine_mode:
                prompts = {int(r): self._prompt(rng, r) for r in probe}
                eng.serve(prompts, n_new=2)    # dedup hits
            else:
                log.took_effect(probe)

        # warmup (unmeasured): first durable write, first dedup-map
        # jit compile, first probe — so the measured series starts on
        # the steady state and compile stalls during the run are *news*
        for _ in range(max(1, sp.warmup_ops)):
            _commit(range(next_rid, next_rid + sp.batch))
            next_rid += sp.batch
            _read(1)

        win = WindowedHistogram(window_us=sp.window_us, lo=1.0, hi=1e8,
                                growth=1.25, max_windows=sp.max_windows)
        thr = WindowedCounter(window_us=sp.window_us,
                              max_windows=sp.max_windows)
        now_us = self.timeline.now_us
        t_run0 = now_us()
        commits = 0
        last_epoch = None
        for i in range(sp.n_ops):
            if sp.mode == "open":
                target = t_run0 + float(sched.arrival_us[i])
                _wait_until(now_us, target)
                t_issue = target      # latency includes queueing delay
            else:
                t_issue = now_us()
            if sched.is_update[i]:
                _commit(range(next_rid, next_rid + sp.batch))
                next_rid += sp.batch
                commits += 1
                if (not engine_mode and sp.snapshot_every
                        and commits % sp.snapshot_every == 0):
                    log.snapshot()    # timeline: snapshot + truncate
            else:
                _read(sched.rank[i])
            t_done = now_us()
            win.record(t_done - t_issue, t_us=t_done)
            thr.inc(sp.batch, t_us=t_done)
            # surface fresh compile stalls as timeline annotations
            while n_compile_seen < len(tracker.events):
                ev = tracker.events[n_compile_seen]
                n_compile_seen += 1
                self.timeline.annotate("compile_stall", t_us=t_done,
                                       trigger=ev.trigger, site=ev.site,
                                       stall_us=ev.stall_us)
            # SLO check once per completed window
            e = win.epoch_of(t_done)
            if (sp.slo_p99_us and last_epoch is not None
                    and e != last_epoch and not breach_dumped):
                h = win.window(last_epoch)
                if h is not None and h.count \
                        and h.quantile(0.99) > sp.slo_p99_us:
                    self.timeline.annotate("slo_breach", t_us=t_done,
                                           epoch=last_epoch,
                                           p99_us=h.quantile(0.99))
                    self.recorder.dump("slo_breach",
                                       path=self.flight_path,
                                       extra={"epoch": last_epoch})
                    breach_dumped = True
            last_epoch = e
            if sp.crash_at_op is not None and i == sp.crash_at_op \
                    and not engine_mode:
                log, crash_report = self._crash_and_recover(log, acked)
        wall_s = max(1e-9, (now_us() - t_run0) / 1e6)

        series = win.series()
        excursions = attribute_excursions(
            series, self.timeline, factor=sp.excursion_factor,
            slack_us=sp.window_us * 0.25)
        report = {
            "spec": asdict(sp),
            "target": "engine" if engine_mode else "log",
            "schedule_fingerprint": sched.fingerprint(),
            "wall_s": wall_s,
            "ops": int(sp.n_ops),
            "rids_processed": int(sp.n_ops) * sp.batch,
            "sustained_ops_s": int(sp.n_ops) * sp.batch / wall_s,
            "p50_us": win.lifetime.quantile(0.5),
            "p99_us": win.lifetime.quantile(0.99),
            "mean_us": (win.lifetime.sum / win.lifetime.count
                        if win.lifetime.count else float("nan")),
            "series": series,
            "throughput": thr.series(),
            "timeline": self.timeline.to_list(),
            "excursions": excursions,
            "n_excursions": len(excursions),
            "n_attributed_excursions": sum(
                1 for x in excursions if x["events"]),
            "flight": {"capacity": self.recorder.capacity,
                       "seen": self.recorder.seen,
                       "dumps": list(self.recorder.dumps)},
            "counters": {
                "commits": self.registry.counter(
                    "serving_commits_total").value,
                "snapshots": self.registry.counter(
                    "serving_snapshots_total").value,
                "records_parsed": self.registry.counter(
                    "serving_records_parsed_total").value,
            },
        }
        if crash_report is not None:
            report["crash"] = crash_report
        return report

    @staticmethod
    def _prompt(rng, rid: int, length: int = 6):
        del rng  # prompts are a pure function of the rid: replayable
        return (np.arange(length, dtype=np.int32) + int(rid)) % 97

    def _crash_and_recover(self, log, acked):
        """Injected crash mid-commit: stage a record, flush it, crash
        with the spec's eviction mode (``"torn"`` leaves a partial
        payload on disk), dump the flight ring, reopen, and verify no
        acked op was lost.  Returns (new log, crash report)."""
        sp = self.spec
        # stage-but-never-fence one record so the adversary has a
        # victim; its rids are *not* acked (commit never returned)
        victim = log._claim_slot()
        log.io.write(victim, json.dumps(
            {str(1 << 40): [0] * sp.payload_len}).encode())
        log.io.flush(victim)
        self.timeline.annotate("crash", evict=sp.crash_evict)
        log.io.crash(evict=sp.crash_evict)
        t0 = self.timeline.now_us()
        self.timeline.annotate("recovery_begin")
        log = self._open_log()       # fresh instance, same obs wiring
        t1 = self.timeline.now_us()
        self.timeline.annotate("recovery_end",
                               total_us=log.restart_timing["total_us"])
        probe = acked[-min(len(acked), 4 * sp.batch):]
        no_acked_lost = bool(np.all(log.took_effect(probe))) \
            if probe else True
        dump = self.recorder.dump(
            "injected_crash", path=self.flight_path,
            restart_timing=log.restart_timing,
            extra={"no_acked_lost": no_acked_lost,
                   "recovery_wall_us": t1 - t0})
        return log, {
            "evict": sp.crash_evict,
            "no_acked_lost": no_acked_lost,
            "restart_timing": dict(log.restart_timing),
            "recovery_wall_us": t1 - t0,
            "flight_dump": {k: dump[k] for k in
                            ("reason", "n_entries", "seen", "dropped",
                             "no_acked_lost", "restart_timing")},
        }
