"""Pure-jnp oracle for nvt_probe + converter from the chain-format map."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mix32_np(x):
    x = np.asarray(x, np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def mix32(x):
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def probe_ref(keys_tile, vals_tile, queries):
    """Vectorized jnp reference: gather each query's bucket row, compare."""
    NB = keys_tile.shape[0]
    b = (mix32(queries) % jnp.uint32(NB)).astype(jnp.int32)
    rows_k = keys_tile[b]                               # [Q, cap]
    rows_v = vals_tile[b]
    q = queries[:, None]
    hit = rows_k == q
    found = hit.any(axis=1).astype(jnp.int32)
    vals = jnp.where(hit, rows_v, 0).sum(axis=1).astype(jnp.int32)
    return found, vals


def tiles_from_keys(keys, n_buckets: int, cap: int, val_mult: int = 3):
    """Build dense bucket tiles directly from a key array (first-fit per
    bucket, overflowing keys dropped); vals are ``key * val_mult``.
    Shared by the kernel tests and benchmarks."""
    keys = np.asarray(keys, np.int32)
    b = (mix32_np(keys) % np.uint32(n_buckets)).astype(np.int64)
    kt = np.zeros((n_buckets, cap), np.int32)
    vt = np.zeros((n_buckets, cap), np.int32)
    slots = np.zeros(n_buckets, np.int64)
    for k, bb in zip(keys, b):
        if slots[bb] < cap:
            kt[bb, slots[bb]] = k
            vt[bb, slots[bb]] = k * val_mult
            slots[bb] += 1
    return jnp.asarray(kt), jnp.asarray(vt)


def tiles_from_hashmap(state, n_buckets: int, cap: int):
    """Convert a core.batched.HashMapState chain map into bucket tiles
    (the TPU-native dense layout) — used to cross-check the kernel against
    the chain-walking structure on identical contents."""
    keys = np.asarray(state.key)
    vals = np.asarray(state.val)
    nxt = np.asarray(state.nxt)
    live = np.asarray(state.live)
    head = np.asarray(state.head)
    kt = np.zeros((n_buckets, cap), np.int32)
    vt = np.zeros((n_buckets, cap), np.int32)
    for b in range(n_buckets):
        node, slot = head[b], 0
        while node >= 0:       # links end at batched.NIL (-1)
            if live[node]:
                assert slot < cap, "bucket overflow in tile conversion"
                kt[b, slot] = keys[node]
                vt[b, slot] = vals[node]
                slot += 1
            node = nxt[node]
    return jnp.asarray(kt), jnp.asarray(vt)
