"""jit'd wrapper for the NVTraverse probe kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import nvt_probe_kernel
from .ref import probe_ref


@partial(jax.jit, static_argnames=("impl", "interpret", "block_q",
                                   "block_nb"))
def nvt_probe(keys_tile, vals_tile, queries, *, impl: str = "pallas",
              interpret: bool = False, block_q: int = 128,
              block_nb: int = 512):
    """Batched read-only probe (the journey).  Returns (found, vals).

    ``block_nb`` sets the bucket-tile block streamed through VMEM per
    grid step — tables larger than VMEM stream in ``NB/block_nb``
    tiles (see kernel.py)."""
    Q = queries.shape[0]
    pad = (-Q) % block_q
    q = jnp.pad(queries.astype(jnp.int32), (0, pad),
                constant_values=-1)
    if impl == "xla":
        found, vals = probe_ref(keys_tile, vals_tile, q)
    else:
        found, vals = nvt_probe_kernel(keys_tile, vals_tile, q,
                                       block_q=block_q, block_nb=block_nb,
                                       interpret=interpret)
    return found[:Q], vals[:Q]
