"""NVTraverse batched hash-probe Pallas TPU kernel — the paper's hot loop.

The paper's traversal is pointer-chasing over bucket chains; its entire
point is that the journey does *zero* persistence work.  The TPU-native
adaptation (DESIGN.md §2): pointer-chasing gathers are hostile to the VPU,
so buckets are laid out as dense fixed-capacity rows ("bucket tiles") and
the journey becomes a vectorized key-compare over a VMEM-resident tile —
same read-only semantics, MXU/VPU-friendly layout.  The critical phase
(CAS + flush + fence) stays on the host commit path (core/batched.py);
this kernel is the read side of the split the paper formalizes.

Inputs:
  keys_tile [n_buckets, cap] int32 — bucket rows (0 = empty slot)
  vals_tile [n_buckets, cap] int32
  queries   [Q] int32
Outputs:
  found [Q] int32 (0/1), vals [Q] int32

Grid: ``(Q/block_q, n_buckets/block_nb)`` — the second dimension
*streams* bucket-tile blocks through VMEM, so the table no longer has to
fit on chip (the old kernel pinned the whole table, capping it at ~2 MB).
The bucket axis is the innermost (sequential) grid dimension and the
output block index depends only on the query-block index, so the output
stays resident in VMEM across the sweep and accumulates.

Per (query-block, bucket-tile) step the whole query block is processed at
once — hash all queries, mask those whose bucket falls outside this tile,
gather their bucket rows with one vectorized take, and compare — no
scalar per-query loop.  Each query's bucket lives in exactly one tile, so
sum-accumulation across tiles is exact (bit-identical to ``probe_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix32(x):
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def _kernel(keys_ref, vals_ref, q_ref, found_ref, val_ref, *,
            n_buckets: int, block_nb: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        found_ref[...] = jnp.zeros_like(found_ref)
        val_ref[...] = jnp.zeros_like(val_ref)

    qs = q_ref[...]                                    # [block_q]
    b = (_mix32(qs) % jnp.uint32(n_buckets)).astype(jnp.int32)
    local = b - j * block_nb
    in_tile = (local >= 0) & (local < block_nb)        # bucket in this tile?
    safe = jnp.where(in_tile, local, 0)
    rows_k = jnp.take(keys_ref[...], safe, axis=0)     # [block_q, cap] gather
    rows_v = jnp.take(vals_ref[...], safe, axis=0)
    hit = (rows_k == qs[:, None]) & in_tile[:, None]   # vectorized compare
    found_ref[...] += hit.any(axis=1).astype(jnp.int32)
    val_ref[...] += jnp.where(hit, rows_v, 0).sum(axis=1).astype(jnp.int32)


def nvt_probe_kernel(keys_tile, vals_tile, queries, *, block_q: int = 128,
                     block_nb: int = 512, interpret: bool = False):
    NB, cap = keys_tile.shape
    Q = queries.shape[0]
    block_q = min(block_q, Q)
    assert Q % block_q == 0
    block_nb = min(block_nb, NB)
    pad_nb = (-NB) % block_nb
    if pad_nb:
        # padded rows are empty buckets no query hashes to (b < NB always)
        keys_tile = jnp.pad(keys_tile, ((0, pad_nb), (0, 0)))
        vals_tile = jnp.pad(vals_tile, ((0, pad_nb), (0, 0)))
    n_tiles = (NB + pad_nb) // block_nb
    kernel = functools.partial(_kernel, n_buckets=NB, block_nb=block_nb)
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q, n_tiles),
        in_specs=[
            pl.BlockSpec((block_nb, cap), lambda i, j: (j, 0)),  # streamed
            pl.BlockSpec((block_nb, cap), lambda i, j: (j, 0)),
            pl.BlockSpec((block_q,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i, j: (i,)),  # VMEM-resident
            pl.BlockSpec((block_q,), lambda i, j: (i,)),  # across the sweep
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q,), jnp.int32),
            jax.ShapeDtypeStruct((Q,), jnp.int32),
        ],
        interpret=interpret,
    )(keys_tile, vals_tile, queries)
