"""NVTraverse batched hash-probe Pallas TPU kernel — the paper's hot loop.

The paper's traversal is pointer-chasing over bucket chains; its entire
point is that the journey does *zero* persistence work.  The TPU-native
adaptation (DESIGN.md §2): pointer-chasing gathers are hostile to the VPU,
so buckets are laid out as dense fixed-capacity rows ("bucket tiles") and
the journey becomes a vectorized key-compare over a VMEM-resident tile —
same read-only semantics, MXU/VPU-friendly layout.  The critical phase
(CAS + flush + fence) stays on the host commit path (core/batched.py);
this kernel is the read side of the split the paper formalizes.

Inputs:
  keys_tile [n_buckets, cap] int32 — bucket rows (0 = empty slot)
  vals_tile [n_buckets, cap] int32
  queries   [Q] int32
Outputs:
  found [Q] int32 (0/1), vals [Q] int32

Grid: (Q/block_q,).  The whole bucket table is pinned in VMEM (the sizes
the paper benchmarks fit comfortably: 4096 buckets × 128 slots × 4 B =
2 MB); each program loads its query block, hashes in-kernel, and walks the
tile row with dynamic-slice loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix32(x):
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def _kernel(keys_ref, vals_ref, q_ref, found_ref, val_ref, *,
            n_buckets: int, block_q: int):
    qs = q_ref[...]                                    # [block_q]

    def body(i, _):
        q = qs[i]
        b = (_mix32(q) % jnp.uint32(n_buckets)).astype(jnp.int32)
        row_k = pl.load(keys_ref, (pl.dslice(b, 1), slice(None)))  # [1,cap]
        row_v = pl.load(vals_ref, (pl.dslice(b, 1), slice(None)))
        hit = row_k == q                               # vectorized compare
        found_ref[i] = hit.any().astype(jnp.int32)
        val_ref[i] = jnp.where(hit, row_v, 0).sum().astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, block_q, body, 0)


def nvt_probe_kernel(keys_tile, vals_tile, queries, *, block_q: int = 128,
                     interpret: bool = False):
    NB, cap = keys_tile.shape
    Q = queries.shape[0]
    block_q = min(block_q, Q)
    assert Q % block_q == 0
    kernel = functools.partial(_kernel, n_buckets=NB, block_q=block_q)
    return pl.pallas_call(
        kernel,
        grid=(Q // block_q,),
        in_specs=[
            pl.BlockSpec((NB, cap), lambda i: (0, 0)),   # whole table, VMEM
            pl.BlockSpec((NB, cap), lambda i: (0, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q,), jnp.int32),
            jax.ShapeDtypeStruct((Q,), jnp.int32),
        ],
        interpret=interpret,
    )(keys_tile, vals_tile, queries)
