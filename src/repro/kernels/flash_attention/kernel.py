"""Flash attention Pallas TPU kernel: blocked online-softmax.

Grid: (batch*q_heads, Sq/block_q, Sk/block_k), KV-block dim innermost and
sequential ("arbitrary") so the running max/sum/accumulator live in VMEM
scratch across KV iterations.  BlockSpecs stream one (block_q × d) Q tile
and one (block_k × d) KV tile into VMEM per step; the MXU sees
[block_q, d] @ [d, block_k] and [block_q, block_k] @ [block_k, d] GEMMs
with d and blocks multiples of 128.

GQA is handled by the KV index_map (``kv_head = q_head // group``): no
repeated KV is ever materialized.  Causal and sliding-window masks are
applied against absolute positions; KV blocks entirely outside the visible
window are skipped via ``pl.when`` (their loads still happen — block
skipping at the grid level is a §Perf iteration for the TPU timeline, but
the FLOP accounting already excludes the masked MACs on the real MXU since
the whole tile is predicated off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, n_k: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = i * block_q
    k_start = j * block_k

    # skip KV blocks fully in the future (causal) or past the window
    visible = True
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window > 0:
        visible = visible & (k_start + block_k - 1 >
                             q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                             # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[...]                        # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                    # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)           # [bq, 1]
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        l = l_sc[...]
        l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows
        o_ref[0] = (acc_sc[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: [BH, Sq, d]; k/v: [BK, Sk, d] with BH % BK == 0 (GQA groups).

    Returns [BH, Sq, d] attention output.
    """
    BH, Sq, d = q.shape
    BK, Sk, _ = k.shape
    assert BH % BK == 0
    group = BH // BK
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j, group=group: (b // group, j, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, i, j, group=group: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # running max
            pltpu.VMEM((block_q, 1), jnp.float32),      # running sum
            pltpu.VMEM((block_q, d), jnp.float32),      # output accum
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
