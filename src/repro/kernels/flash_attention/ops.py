"""jit'd public wrapper: model-layout in/out, kernel or XLA-ref dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "impl", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "pallas", interpret: bool = False,
                    block_q: int = 128, block_k: int = 128):
    """Model-layout flash attention.

    q: [B, Sq, H, dh]; k/v: [B, Sk, K, dh] (GQA).  Returns [B, Sq, H, dh].
    ``impl='pallas'`` uses the TPU kernel (``interpret=True`` for CPU
    validation); ``impl='xla'`` runs the pure-jnp oracle.
    """
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, dh)
    if impl == "xla":
        out = attention_ref(qh, kh, vh, causal=causal, window=window)
    else:
        out = flash_attention_kernel(qh, kh, vh, causal=causal,
                                     window=window, block_q=block_q,
                                     block_k=block_k, interpret=interpret)
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)
