"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [BH, Sq, d]; k/v: [BK, Sk, d]; GQA via BH % BK groups."""
    BH, Sq, d = q.shape
    BK, Sk, _ = k.shape
    group = BH // BK
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    # rows with no visible keys: zero output (kernel does the same)
    any_visible = mask.any(axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32))
    out = jnp.where(any_visible[None, :, None], out, 0.0)
    return out.astype(q.dtype)
