"""jit'd wrapper: model layout ↔ kernel layout + impl dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel
from .ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd_scan(xh, dt, A, Bm, Cm, *, chunk: int = 128, impl: str = "pallas",
             interpret: bool = False):
    """Model layout: xh [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (<0);
    Bm/Cm [B,S,N] (one group, broadcast across heads).  Returns [B,S,H,P].
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    C = Sp // chunk

    # [B,S,H,P] -> [B,H,S,P] -> [BH, C, Q, P]
    xk = xh.transpose(0, 2, 1, 3).reshape(B * H, C, chunk, P)
    dtk = dt.transpose(0, 2, 1).reshape(B * H, C, chunk)
    # per-program head decay: programs are ordered b*H + h, so tile A B times
    dAk = dtk * jnp.tile(A, (B,))[:, None, None]
    bk = jnp.repeat(Bm[:, None], H, axis=1).reshape(B * H, C, chunk, N)
    ck = jnp.repeat(Cm[:, None], H, axis=1).reshape(B * H, C, chunk, N)

    if impl == "xla":
        y = ssd_ref(xk, dtk, dAk, bk, ck)
    else:
        y = ssd_scan_kernel(xk, dtk, dAk, bk, ck, interpret=interpret)
    y = y.reshape(B, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
    return y
