"""Mamba2 SSD chunk-scan Pallas TPU kernel.

The TPU-native formulation of the selective scan (DESIGN.md §2 hardware
adaptation): instead of the CUDA per-timestep recurrence, each Q-token
chunk is computed as dense [Q,Q]/[Q,N]/[Q,P] GEMMs on the MXU, and only a
tiny [P,N] state crosses chunks.

Grid: (batch*heads, n_chunks), chunk dim sequential ("arbitrary") — the
carried state lives in a VMEM scratch accumulator.  Per program the VMEM
working set is x[Q,P], dA/dt[Q], B/C[Q,N], L[Q,Q], state[P,N]; with
Q=P=N=128 everything is MXU-aligned.

Inputs (pre-arranged by ops.py):
  xh  [BH, C, Q, P]   head channels
  dt  [BH, C, Q]      softplus(dt + bias)
  dA  [BH, C, Q]      dt * A  (A negative, per head)
  Bm  [BH, C, Q, N]   input projection (group-broadcast per head)
  Cm  [BH, C, Q, N]   output projection
Output:
  y   [BH, C, Q, P]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, state_sc, *,
            chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_sc[...] = jnp.zeros_like(state_sc)

    x = x_ref[0, 0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [Q]
    dA = dA_ref[0, 0].astype(jnp.float32)      # [Q]
    Bm = b_ref[0, 0].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)       # [Q, N]

    cum = jnp.cumsum(dA)                       # [Q] inclusive
    # intra-chunk: masked decay kernel L[i,j] = exp(cum_i - cum_j), j <= i
    diff = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    L = jnp.where(tri, jnp.exp(diff), 0.0)     # [Q, Q]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * L * dt[None, :]              # [Q, Q]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    state = state_sc[...]                      # [P, N]
    y_inter = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y + y_inter * jnp.exp(cum)[:, None]

    # state update: S <- exp(cum_last) * S + X^T diag(w) B,  w = dt*decay
    w = (jnp.exp(cum[-1] - cum) * dt)[:, None]           # [Q,1]
    s_local = jax.lax.dot_general(x * w, Bm, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_sc[...] = state * jnp.exp(cum[-1]) + s_local
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan_kernel(xh, dt, dA, Bm, Cm, *, interpret: bool = False):
    """xh: [BH, C, Q, P]; dt/dA: [BH, C, Q]; Bm/Cm: [BH, C, Q, N]."""
    BH, C, Q, P = xh.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(BH, C),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, C, Q, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dt, dA, Bm, Cm)
