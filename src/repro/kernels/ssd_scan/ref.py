"""Pure-jnp oracle for the SSD chunk-scan kernel: the sequential
state-space recurrence (the definitionally-correct form).

    s_t = exp(dA_t) * s_{t-1} + dt_t * B_t ⊗ x_t
    y_t = C_t · s_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xh, dt, dA, Bm, Cm):
    """Same layout as the kernel: xh [BH,C,Q,P], dt/dA [BH,C,Q],
    Bm/Cm [BH,C,Q,N] → y [BH,C,Q,P]."""
    BH, C, Q, P = xh.shape
    N = Bm.shape[-1]
    x = xh.reshape(BH, C * Q, P).astype(jnp.float32)
    dt_ = dt.reshape(BH, C * Q).astype(jnp.float32)
    dA_ = dA.reshape(BH, C * Q).astype(jnp.float32)
    B_ = Bm.reshape(BH, C * Q, N).astype(jnp.float32)
    C_ = Cm.reshape(BH, C * Q, N).astype(jnp.float32)

    def step(s, inp):
        xt, dtt, dat, bt, ct = inp
        s = jnp.exp(dat)[:, None, None] * s + \
            dtt[:, None, None] * (xt[:, :, None] * bt[:, None, :])
        y = jnp.einsum("bn,bpn->bp", ct, s)
        return s, y

    s0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, s0,
                         (x.swapaxes(0, 1), dt_.T, dA_.T,
                          B_.swapaxes(0, 1), C_.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(BH, C, Q, P)
    return y.astype(xh.dtype)
