"""Version-compat shims shared by the Pallas kernels."""
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases
COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if COMPILER_PARAMS is None:  # fail at import, not at kernel call
    raise ImportError("jax.experimental.pallas.tpu has neither "
                      "CompilerParams nor TPUCompilerParams")
