"""Systematic crash-fault injection across the durable layers.

The paper's durability claims are all of the form "crash anywhere, and
recovery lands on a linearized prefix".  The repo's hand-written crash
tests pick a few interesting boundaries (journal frontiers, torn log
records); this module makes the claim *mechanical*: a
:class:`CrashPlan` instruments every persistence instruction a scenario
issues through :class:`repro.persistence.manifest.StagedIO` and/or
:class:`repro.core.pmem.PMem` — flush, fence, publish (rename/CAS) and
trim — as a numbered **crash site**, and can

  * **enumerate** the sites of a deterministic scenario (no crash),
  * **crash deterministically** at the N-th site (the site's own
    instruction never executes — crash-*before* semantics, so sweeping
    every site plus the no-crash run covers every boundary), or
  * **fuzz** sites with a seeded coin (``p_crash``),

combined with the shared seedable eviction adversary
(:func:`repro.core.pmem.evicted_mask`) applied to whatever was staged
at the crash.  :func:`sweep` drives a scenario crash-at-every-site ×
eviction-mode and runs the scenario's recovery checks after each crash:
**no acknowledged op lost**, **prefix durability**, and **oracle
equivalence** (an independent host-side replay of the durable bytes
matches the recovered object).

Six scenarios cover the durable layers (the :data:`SCENARIOS`
registry): the serving :class:`~repro.serving.engine.RequestLog`
(commit/evict/snapshot/truncate), two such logs *live concurrently* on
one dir (``log2`` — interleaved commits, recovery metrics checked
against the durable bytes), the
:class:`~repro.persistence.checkpoint.CheckpointManager` (save/gc), the
:class:`~repro.core.migrate.MigratingMap` migration window, the
:class:`~repro.core.rebalance.RebalancingShardedMap` rebalance window,
and the :class:`~repro.core.ordered.DurableOrderedMap` batch journal
(``ordered`` — sorted-prefix durability plus volatile-tower-rebuild
identity).  ``tools/crash_sweep.py`` is the CLI over the same
machinery.

>>> s = CrashSite(3, "publish", "mig_0001/state.json")
>>> s.index, s.kind
(3, 'publish')
"""
from __future__ import annotations

import dataclasses
import json
import tempfile
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import KINDS


@dataclasses.dataclass(frozen=True)
class CrashSite:
    """One persistence instruction: the ``index``-th site the scenario
    reached, of ``kind`` (flush/fence/publish/trim), acting on
    ``target`` (a staged-file rel path, a cache line, or "" for a
    fence)."""
    index: int
    kind: str
    target: str


class CrashPoint(Exception):
    """Raised by a firing :class:`CrashPlan` — the simulated kill.  By
    the time it propagates, every attached IO/PMem has already executed
    its crash (staging lost, eviction adversary applied), so the
    scenario's recovery path sees exactly the post-crash durable
    state."""

    def __init__(self, site: CrashSite):
        super().__init__(f"injected crash at site {site.index} "
                         f"({site.kind} {site.target})")
        self.site = site


class CrashPlan:
    """A shared, seedable crash schedule over every attached IO object.

    ``crash_at`` fires deterministically at that site index;
    ``p_crash`` > 0 instead flips a seeded coin at every site (fuzz
    mode — the same seed replays the same crash).  Leave both unset to
    *enumerate*: the scenario runs to completion and :attr:`sites`
    holds every site it visited.  ``evict``/``p_evict`` select the
    shared eviction adversary (:func:`repro.core.pmem.evicted_mask`)
    applied by each attached object's own ``crash()`` when the plan
    fires.

    The crash is whole-process: *all* attached objects crash together,
    then :class:`CrashPoint` unwinds the scenario.  The site's own
    instruction never executes (crash-before semantics), and a fired
    plan goes inert — recovery code constructing fresh IO objects runs
    unobserved.
    """

    def __init__(self, crash_at: Optional[int] = None, *,
                 evict: str = "none", p_evict: float = 0.5,
                 p_crash: float = 0.0, seed: int = 0):
        self.crash_at = crash_at
        self.evict = evict
        self.p_evict = p_evict
        self.p_crash = p_crash
        self._rng = np.random.default_rng(seed)
        self.sites: List[CrashSite] = []
        self.fired_at: Optional[CrashSite] = None
        self._attached: list = []

    def attach(self, *objs) -> "CrashPlan":
        """Instrument IO objects (StagedIO and/or PMem): every
        persistence instruction they execute from now on reports a
        site, and all of them crash together when the plan fires."""
        for obj in objs:
            obj.faults = self
            if not any(o is obj for o in self._attached):
                self._attached.append(obj)
        return self

    def on_site(self, kind: str, target: str = "") -> None:
        """Called by instrumented IO before executing one persistence
        instruction; fires the crash when the schedule says so."""
        if self.fired_at is not None:
            return                       # already crashed: inert
        assert kind in KINDS, f"unknown site kind {kind!r}"
        site = CrashSite(len(self.sites), kind, target)
        self.sites.append(site)
        fire = site.index == self.crash_at or (
            self.p_crash > 0 and self._rng.random() < self.p_crash)
        if fire:
            self.fired_at = site
            for obj in self._attached:
                obj.crash(evict=self.evict, p_evict=self.p_evict)
            raise CrashPoint(site)

    def completed_sites(self) -> List[CrashSite]:
        """Sites whose instruction actually executed: everything before
        the fired site (whose instruction was replaced by the crash) —
        the ground truth for "was this publish acknowledged?"."""
        if self.fired_at is None:
            return list(self.sites)
        return self.sites[:self.fired_at.index]


# --------------------------------------------------------------------- #
# scenario helpers                                                       #
# --------------------------------------------------------------------- #
def _acked_publishes(plan: CrashPlan, match: Callable[[str], bool]) -> int:
    """Count executed publish instructions whose target matches."""
    return sum(1 for s in plan.completed_sites()
               if s.kind == "publish" and match(s.target))


def _replay_rounds(new_items: dict, rounds: Sequence[dict]) -> None:
    """Independent dict-model replay of journaled rounds, with the
    engine's op semantics (batch order; an insert lands iff the key is
    not live, a delete iff it is; a dead node keeps its last value)."""
    for rec in rounds:
        for o, k, v in zip(rec["ops"], rec["ks"], rec["vs"]):
            k, v = int(k), int(v)
            live, old_v = new_items.get(k, (False, 0))
            if int(o) == 0:                       # OP_INSERT
                if not live:
                    new_items[k] = (True, v)
            else:                                 # OP_DELETE
                if live:
                    new_items[k] = (False, old_v)


def _live(items: dict) -> dict:
    """Abstract live content {key: val} of a {key: (live, val)} dict."""
    return {k: v for k, (alive, v) in items.items() if alive}


def _journal_invariants(root: Path, plan: CrashPlan, prefix: str):
    """Shared RoundJournal checks for the migrate/rebalance layers.

    Returns ``(dirname, header bytes, snapshot, rounds)`` of the newest
    published journal after asserting *no acked round lost* (every
    executed ``round_*.npz`` publish is on disk) and *prefix
    durability* (round files are contiguous from 0 — the journal can
    only roll back to a round boundary, never skip one).  Returns None
    — after asserting no header publish had executed — when no journal
    was ever published."""
    from ..core.migrate import RoundJournal

    d = RoundJournal.newest_dir(root, prefix)
    acked_rounds = _acked_publishes(
        plan, lambda t: t.startswith(f"{prefix}_") and "/round_" in t)
    acked_headers = _acked_publishes(
        plan, lambda t: t.startswith(f"{prefix}_")
        and t.endswith("state.json"))
    if d is None:
        assert acked_headers == 0, \
            f"published {prefix} header lost after crash"
        assert acked_rounds == 0, \
            f"acked {prefix} rounds lost with their journal"
        return None
    hdr, snap, rounds = RoundJournal.read(root, d)
    k = len(rounds)
    assert k >= acked_rounds, \
        f"acked rounds lost: journal has {k}, {acked_rounds} were acked"
    names = sorted(p.name for p in (Path(root) / d).glob("round_*.npz"))
    assert names == [f"round_{i:06d}.npz" for i in range(k)], \
        f"round files not a contiguous prefix: {names}"
    return d, hdr, snap, rounds


# --------------------------------------------------------------------- #
# the durable-layer scenarios                                            #
# --------------------------------------------------------------------- #
class RequestLogScenario:
    """Serving request log under commit + evict + snapshot/truncate
    traffic.  Acked ground truth is tracked at the API boundary (a
    commit() that returned was acknowledged); the oracle is an
    independent host-side replay of the surviving snapshot + record
    files."""

    layer = "log"
    N_BATCHES = 6
    BATCH = 3
    RETAIN = 6
    SNAP_EVERY = 2          # snapshot()+truncate after every 2 commits

    def __init__(self, root, plan: CrashPlan,
                 shards: Optional[int] = None):
        """``shards`` runs the identical schedule with the dedup index
        on the bucket-range-sharded durable-map backend (needs that
        many devices — the CI faultinject lane forces 2 host devices);
        the invariants are shard-count-independent."""
        self.root = Path(root)
        self.plan = plan
        self.shards = shards
        self.issued: Dict[int, list] = {}   # every commit attempted
        self.issued_evict: set = set()
        self.acked: Dict[int, list] = {}    # commit() returned
        self.acked_evict: set = set()

    def run(self) -> None:
        from ..serving.engine import RequestLog
        log = RequestLog(self.root, capacity=1024, shards=self.shards)
        self.plan.attach(log.io)
        rid = 0
        for b in range(self.N_BATCHES):
            results = {rid + i: [b, i, rid + i]
                       for i in range(self.BATCH)}
            rid += self.BATCH
            evict = log.expired_rids(self.RETAIN)
            self.issued.update(results)
            self.issued_evict.update(evict)
            log.commit(results, evict=evict)
            self.acked.update(results)
            self.acked_evict.update(evict)
            if (b + 1) % self.SNAP_EVERY == 0:
                log.snapshot()

    def _disk_oracle(self) -> Dict[int, list]:
        """Independent replay of the durable bytes: newest valid
        snapshot, then every whole record at/past its horizon in slot
        order."""
        snaps = sorted(p.name for p in self.root.glob("snap_*.json"))
        results: Dict[int, list] = {}
        horizon = 0
        for name in reversed(snaps):
            try:
                data = json.loads((self.root / name).read_text())
                results = {int(k): list(v)
                           for k, v in data["results"].items()}
                horizon = int(data["horizon"])
                break
            except (json.JSONDecodeError, KeyError, ValueError):
                continue
        for p in sorted(self.root.glob("log_*.json")):
            try:
                idx = int(p.name[4:-5])
            except ValueError:
                continue
            if idx < horizon:
                continue
            try:
                data = json.loads(p.read_text())
            except (json.JSONDecodeError, UnicodeDecodeError):
                continue    # torn record (truncated or garbled): trimmed
            if "results" in data and set(data) <= {"results", "evict"}:
                rec = {int(k): list(v)
                       for k, v in data["results"].items()}
                ev = [int(r) for r in data.get("evict", [])]
            else:
                rec = {int(k): list(v) for k, v in data.items()}
                ev = []
            results.update(rec)
            for r in ev:
                results.pop(r, None)
        return results

    def check(self) -> None:
        from ..serving.engine import RequestLog
        oracle = self._disk_oracle()         # before restart trims
        log = RequestLog(self.root, capacity=1024, shards=self.shards)
        committed = log.committed()
        # oracle equivalence: recovery == independent durable replay
        assert committed == oracle, \
            "recovered state diverges from the durable-bytes oracle"
        # no acknowledged op lost: an acked rid answers with its exact
        # payload unless some *issued* evicting record became durable
        for r, res in self.acked.items():
            if r in committed:
                assert committed[r] == res, f"payload of rid {r} changed"
            else:
                assert r in self.issued_evict, f"acked rid {r} lost"
        # prefix/atomicity: nothing outside the issued stream survives,
        # and what survives carries the exact issued payload
        for r, res in committed.items():
            assert self.issued.get(r) == res, \
                f"rid {r} recovered with a payload never issued"
        # detectability: took_effect answers match, without record
        # parsing beyond the restart suffix
        rids = sorted(self.issued)
        want = np.asarray([r in committed for r in rids])
        assert np.array_equal(log.took_effect(rids), want)


class ConcurrentLogScenario(RequestLogScenario):
    """Two *live* RequestLog instances sharing one log dir, committing
    interleaved batches (slot claims via O_EXCL keep them from ever
    colliding) while instance A periodically snapshots/truncates.  Both
    IOs ride the same whole-process crash plan.  On top of the
    single-log invariants (inherited: disk-oracle equivalence, no acked
    op lost, issued-payload atomicity, detectable recovery), the check
    recovers *two* fresh instances — each on its own NVTrace metrics
    registry — and asserts their observability is consistent with the
    durable bytes: ``records_parsed`` (shim and registry counter alike)
    equals the number of durable post-horizon record files the restart
    actually had to replay, both recoveries agree with each other, and
    their ``took_effect`` answers match rid-for-rid."""

    layer = "log2"
    N_ROUNDS = 4
    BATCH = 2
    RETAIN = 8
    SNAP_EVERY = 2          # A snapshots after every 2 interleaved rounds

    def run(self) -> None:
        from ..obs.metrics import MetricsRegistry
        from ..serving.engine import RequestLog
        a = RequestLog(self.root, capacity=1024, shards=self.shards,
                       registry=MetricsRegistry())
        b = RequestLog(self.root, seed=1, capacity=1024,
                       shards=self.shards, registry=MetricsRegistry())
        self.plan.attach(a.io, b.io)
        rid = 0
        for rnd in range(self.N_ROUNDS):
            for log in (a, b):
                results = {rid + i: [rnd, i, rid + i]
                           for i in range(self.BATCH)}
                rid += self.BATCH
                log.refresh()        # adopt the peer's commits first
                evict = log.expired_rids(self.RETAIN)
                self.issued.update(results)
                self.issued_evict.update(evict)
                log.commit(results, evict=evict)
                self.acked.update(results)
                self.acked_evict.update(evict)
            if (rnd + 1) % self.SNAP_EVERY == 0:
                a.snapshot()

    def _replay_expect(self) -> int:
        """How many record files a fresh restart must parse right now:
        every ``log_*.json`` at/past the newest *valid* snapshot's
        horizon (torn records cost exactly one parse attempt too)."""
        horizon = 0
        for name in sorted((p.name for p in self.root.glob("snap_*.json")),
                           reverse=True):
            try:
                horizon = int(json.loads(
                    (self.root / name).read_text())["horizon"])
                break
            except (json.JSONDecodeError, KeyError, ValueError):
                continue
        return sum(1 for p in self.root.glob("log_*.json")
                   if (i := self._log_idx(p.name)) is not None
                   and i >= horizon)

    @staticmethod
    def _log_idx(name: str) -> Optional[int]:
        try:
            return int(name[len("log_"):-len(".json")])
        except ValueError:
            return None

    def _recover_one(self):
        """One fresh recovered instance on a private registry, plus the
        replay size its restart was facing (computed from the durable
        bytes *before* construction — a restart trims torn/stale files,
        so the expectation must be re-read per instance)."""
        from ..obs.metrics import MetricsRegistry
        from ..serving.engine import RequestLog
        expect = self._replay_expect()
        reg = MetricsRegistry()
        log = RequestLog(self.root, capacity=1024, shards=self.shards,
                         registry=reg)
        return log, reg, expect

    def check(self) -> None:
        oracle = self._disk_oracle()         # before restart trims
        log1, reg1, expect1 = self._recover_one()
        committed = log1.committed()
        assert committed == oracle, \
            "recovered state diverges from the durable-bytes oracle"
        for r, res in self.acked.items():
            if r in committed:
                assert committed[r] == res, f"payload of rid {r} changed"
            else:
                assert r in self.issued_evict, f"acked rid {r} lost"
        for r, res in committed.items():
            assert self.issued.get(r) == res, \
                f"rid {r} recovered with a payload never issued"
        # metrics/durable-bytes consistency, instance 1: the restart
        # parsed exactly the durable post-horizon suffix, and the shim
        # and the registry counter tell the same story
        assert log1.records_parsed == expect1, \
            (f"instance 1 parsed {log1.records_parsed} records, durable "
             f"suffix holds {expect1}")
        assert reg1.counter("serving_records_parsed_total").value \
            == expect1, \
            "registry counter diverges from the records_parsed shim"
        # second fresh instance: expectation re-read after instance 1's
        # restart trimmed torn/stale leftovers
        log2, reg2, expect2 = self._recover_one()
        assert log2.records_parsed == expect2, \
            (f"instance 2 parsed {log2.records_parsed} records, durable "
             f"suffix holds {expect2}")
        assert reg2.counter("serving_records_parsed_total").value \
            == expect2, \
            "registry counter diverges from the records_parsed shim"
        # both recoveries agree with each other and with the oracle
        assert log2.committed() == committed, \
            "two fresh recoveries disagree on the committed state"
        rids = sorted(self.issued)
        want = np.asarray([r in committed for r in rids])
        assert np.array_equal(log1.took_effect(rids), want)
        assert np.array_equal(log2.took_effect(rids), want), \
            "took_effect answers diverge between concurrent recoveries"


class CheckpointScenario:
    """Checkpoint save/gc chain.  The manifest publish rename is the
    only commit point: after any crash, recovery must land on exactly
    the last acked step, restore its exact tree (delta references
    included), and never resurrect an unpublished commit."""

    layer = "checkpoint"
    STEPS = (1, 2, 3, 4)
    GC_AT = 3               # gc(keep=2) right after saving step 3

    def __init__(self, root, plan: CrashPlan):
        self.root = Path(root)
        self.plan = plan
        self.acked: List[int] = []

    @staticmethod
    def _tree(step: int) -> dict:
        # "w" changes every step; "b" settles at step 2 — steps 3+
        # delta-reference step 2's copy (gc must keep it alive), while
        # step 1 really dies at gc time (a genuine trim crash site)
        return {"w": np.arange(6, dtype=np.float64).reshape(2, 3) + step,
                "b": np.full(3, float(min(step, 2)))}

    def run(self) -> None:
        from ..persistence.checkpoint import CheckpointManager
        mgr = CheckpointManager(self.root, faults=self.plan)
        for s in self.STEPS:
            mgr.save(s, self._tree(s), aux={"step": s})
            self.acked.append(s)
            if s == self.GC_AT:
                mgr.gc(keep=2)

    def check(self) -> None:
        from ..persistence.checkpoint import CheckpointManager
        man = CheckpointManager(self.root).recover()
        if not self.acked:
            assert man is None, \
                "a never-acked save resurrected after recovery"
            return
        assert man is not None, "all acked checkpoints lost"
        assert man.step == self.acked[-1], \
            f"recovered head {man.step} != last acked {self.acked[-1]}"
        man2, tree = CheckpointManager(self.root).restore(self._tree(0))
        assert man2.step == self.acked[-1]
        want = self._tree(man2.step)
        np.testing.assert_array_equal(np.asarray(tree["w"]), want["w"])
        np.testing.assert_array_equal(np.asarray(tree["b"]), want["b"])


class MigrateScenario:
    """Single-device map growth window: the journaled rounds are the
    durable surface (steady-state batches outside a migration are
    volatile by design — the paper's journey).  Acked ground truth is
    derived from the plan's executed publish sites."""

    layer = "migrate"

    def __init__(self, root, plan: CrashPlan):
        self.root = Path(root)
        self.plan = plan

    def run(self) -> None:
        from ..core.migrate import MigratingMap
        from ..core import batched as B
        m = MigratingMap(capacity=16, n_buckets=4, root=self.root,
                         buckets_per_round=1, rounds_per_update=1)
        self.plan.attach(m.io)
        m.insert(np.arange(1, 11, dtype=np.int32),
                 np.arange(1, 11, dtype=np.int32) * 3)
        m.delete(np.asarray([2, 5], np.int32))
        # does not fit the 16-slot pool: opens the journaled migration
        m.insert(np.arange(11, 19, dtype=np.int32),
                 np.arange(11, 19, dtype=np.int32) * 3)
        # mixed user traffic while the drain is in flight
        m.update(np.asarray([B.OP_DELETE, B.OP_INSERT, B.OP_INSERT],
                            np.int32),
                 np.asarray([3, 2, 30], np.int32),
                 np.asarray([0, 222, 330], np.int32))
        while m.migrating:
            m.migrate_round()

    def check(self) -> None:
        from ..core.migrate import (MigratingMap, MigrationState,
                                    items_of_host)
        out = _journal_invariants(self.root, self.plan, "mig")
        m2 = MigratingMap.recover(self.root)
        if out is None:
            assert m2.items() == {}, \
                "recovered content from a never-published journal"
            return
        _, hdr_bytes, snap, rounds = out
        hdr = MigrationState.from_bytes(hdr_bytes)
        acked_headers = _acked_publishes(
            self.plan, lambda t: t.endswith("state.json"))
        if acked_headers >= 2:       # start + done both executed
            assert hdr.phase == "done", "acked done-header lost"
        # oracle equivalence: snapshot + round replay through an
        # independent dict model == the recovered map's live content
        new_items: dict = {}
        _replay_rounds(new_items, rounds)
        merged = dict(items_of_host(snap))
        merged.update(new_items)
        want = _live(merged)
        assert _live(m2.items()) == want, \
            "recovered live content diverges from the journal oracle"
        # and the recovered map can finish the window without moving
        # the abstract content
        if m2.migrating:
            m2.run_migration()
            assert _live(m2.items()) == want, \
                "finishing the recovered migration changed content"


class RebalanceScenario:
    """Sharded map re-split window (n_shards=1 runs on a single CPU
    device — the journal protocol is identical; CI's multi-device lane
    sweeps n_shards=2)."""

    layer = "rebalance"

    def __init__(self, root, plan: CrashPlan, n_shards: int = 1):
        self.root = Path(root)
        self.plan = plan
        self.n_shards = n_shards

    def run(self) -> None:
        from ..core.rebalance import RebalancingShardedMap
        from ..core import batched as B
        rm = RebalancingShardedMap(self.n_shards, capacity=64,
                                   n_buckets=8, root=self.root,
                                   buckets_per_round=2,
                                   rounds_per_update=1)
        self.plan.attach(rm.io)
        ks = np.arange(1, 21, dtype=np.int32)
        rm.insert(ks, ks * 7)
        rm.delete(np.asarray([4, 9], np.int32))
        nb = rm.n_buckets
        if self.n_shards == 1:
            splits = (0, nb)          # a compaction re-split
        else:
            # skew shard 0 down to 2 buckets, spread the rest evenly
            step = max(1, (nb - 2) // (self.n_shards - 1))
            splits = (0, *[2 + i * step
                           for i in range(self.n_shards - 1)], nb)
        rm.start_rebalance(splits)
        rm.update(np.asarray([B.OP_DELETE, B.OP_INSERT, B.OP_INSERT],
                             np.int32),
                  np.asarray([7, 4, 40], np.int32),
                  np.asarray([0, 444, 400], np.int32))
        while rm.rebalancing:
            rm.rebalance_round()

    def check(self) -> None:
        from ..core.migrate import items_of_host
        from ..core.rebalance import RebalancingShardedMap, RebalanceState
        out = _journal_invariants(self.root, self.plan, "reb")
        if out is None:
            return       # recover() requires a published journal
        _, hdr_bytes, snap, rounds = out
        hdr = RebalanceState.from_bytes(hdr_bytes)
        acked_headers = _acked_publishes(
            self.plan, lambda t: t.endswith("state.json"))
        if acked_headers >= 2:
            assert hdr.phase == "done", "acked done-header lost"
        m2 = RebalancingShardedMap.recover(self.root, self.n_shards)
        fields = ("key", "val", "nxt", "live", "head", "cursor",
                  "flushes", "fences")
        merged: dict = {}
        for s in range(self.n_shards):
            merged.update(items_of_host(
                {f: np.asarray(snap[f][s]) for f in fields}))
        new_items: dict = {}
        _replay_rounds(new_items, rounds)
        merged.update(new_items)
        want = _live(merged)
        assert _live(m2.items()) == want, \
            "recovered live content diverges from the journal oracle"
        if m2.rebalancing:
            m2.run_rebalance()
            assert _live(m2.items()) == want, \
                "finishing the recovered rebalance changed content"


class OrderedScenario:
    """The batch-parallel durable *ordered* map
    (:class:`~repro.core.ordered.DurableOrderedMap`): mixed
    insert/delete batches with duplicate keys journaled round-by-round
    (write → flush → fence → publish), a mid-schedule snapshot +
    round/snapshot trims, then recovery checked four ways:

      * **oracle equivalence** — an independent host-side replay of the
        durable bytes (newest whole snapshot walked as a raw chain +
        surviving whole rounds through the same dict model as
        :func:`_replay_rounds`) equals the recovered map's content;
      * **no acked batch lost** — every ``update()`` that returned has
        its round durable with the exact issued payload (rounds publish
        before the engine applies, so acked == durable exactly under
        crash-before semantics), and surviving rounds are a contiguous
        suffix from the snapshot horizon;
      * **sorted-prefix durability** — the recovered bottom list is
        strictly ascending, cycle-free, and threads every allocated
        node (:func:`repro.core.ordered.check_sorted`);
      * **tower-rebuild identity** — the volatile index rebuilt from
        the recovered bottom list is *bit-identical* to an independent
        expectation built per-key from the seed skiplist's scalar
        :func:`repro.core.skiplist.tower_height`, and the recovered
        state arrays equal a fresh in-memory engine replaying the
        durable rounds (Property 2, mechanically).
    """

    layer = "ordered"
    N_BATCHES = 6
    CAPACITY = 96
    SNAP_AFTER = 3          # snapshot()+trim after the 4th batch

    def __init__(self, root, plan: CrashPlan):
        self.root = Path(root)
        self.plan = plan
        self.issued: List[dict] = []     # every update() attempted
        self.acked: List[dict] = []      # update() returned

    @staticmethod
    def _batch(b: int):
        """Deterministic mixed batch ``b``: clustered keys (duplicate
        key groups and shared predecessors on purpose), a few deletes
        of earlier keys, every batch a different size."""
        rng = np.random.default_rng(4242 + b)
        n = 6 + b * 2
        ops = rng.integers(0, 2, n).astype(np.int32)
        ks = rng.integers(0, 24, n).astype(np.int32)
        vs = (100 * b + np.arange(n)).astype(np.int32)
        return ops, ks, vs

    def run(self) -> None:
        from ..core.ordered import DurableOrderedMap
        m = DurableOrderedMap(self.root, capacity=self.CAPACITY)
        self.plan.attach(m.io)
        for b in range(self.N_BATCHES):
            ops, ks, vs = self._batch(b)
            rec = {"ops": ops.tolist(), "ks": ks.tolist(),
                   "vs": vs.tolist()}
            self.issued.append(rec)
            m.update(ops, ks, vs)
            self.acked.append(rec)
            if b == self.SNAP_AFTER:
                m.snapshot()

    # -- independent durable-bytes oracle ------------------------------ #
    def _disk_rounds(self) -> Tuple[Optional[dict], List[dict]]:
        """(newest whole snapshot payload or None, whole rounds at/past
        its horizon in index order) — raw file parsing only."""
        snap = None
        horizon = 0
        for p in sorted(self.root.glob("osnap_*.json"), reverse=True):
            try:
                snap = json.loads(p.read_text())
                horizon = int(snap["horizon"])
                break
            except (json.JSONDecodeError, KeyError, ValueError):
                continue             # torn snapshot: older one wins
        rounds = []
        for p in sorted(self.root.glob("ord_*.json")):
            try:
                idx = int(p.name[4:-5])
            except ValueError:
                continue
            if idx < horizon:
                continue             # covered by snapshot (trim raced)
            try:
                rounds.append((idx, json.loads(p.read_text())))
            except (json.JSONDecodeError, UnicodeDecodeError):
                continue             # torn round: never published whole
        return snap, [r for _, r in sorted(rounds)]

    @staticmethod
    def _walk_snapshot(snap: dict) -> dict:
        """Raw chain walk of a snapshot's arrays: {key: (live, val)}."""
        out: dict = {}
        node = int(snap["nxt"][0])
        hops = 0
        while node != -1:
            out[int(snap["key"][node])] = (bool(snap["live"][node]),
                                           int(snap["val"][node]))
            node = int(snap["nxt"][node])
            hops += 1
            assert hops <= len(snap["key"]), "cycle in snapshot chain"
        return out

    def check(self) -> None:
        from ..core.ordered import (DurableOrderedMap, build_towers,
                                    check_sorted, make_ordered,
                                    update_parallel_ordered)
        from ..core.skiplist import tower_height

        snap, rounds = self._disk_rounds()
        # no acked batch lost: rounds publish before the engine applies
        # and crash-before semantics never half-execute a publish, so
        # the durable stream is exactly the acked stream
        horizon = int(snap["horizon"]) if snap else 0
        n_durable = horizon + len(rounds)
        assert n_durable == len(self.acked), \
            f"{len(self.acked)} batches acked, {n_durable} durable"
        for rec, want in zip(rounds, self.issued[horizon:]):
            assert rec == want, "durable round payload differs from issued"

        m2 = DurableOrderedMap(self.root, capacity=self.CAPACITY)
        # oracle equivalence: snapshot walk + dict-model round replay
        items = self._walk_snapshot(snap) if snap else {}
        _replay_rounds(items, rounds)
        assert m2.items() == items, \
            "recovered content diverges from the durable-bytes oracle"
        # sorted-prefix durability
        check_sorted(m2.state)
        # engine bit-identity: a fresh in-memory engine replaying the
        # durable stream reproduces the recovered arrays exactly
        st = make_ordered(self.CAPACITY)
        for rec in (self.issued[:horizon] + rounds):
            st, _, _ = update_parallel_ordered(
                st, np.asarray(rec["ops"], np.int32),
                np.asarray(rec["ks"], np.int32),
                np.asarray(rec["vs"], np.int32))
        for f in st._fields:
            assert np.array_equal(np.asarray(getattr(st, f)),
                                  np.asarray(getattr(m2.state, f))), \
                f"recovered state field {f} not bit-identical to replay"
        # tower-rebuild identity vs the scalar seed promotion
        tw = build_towers(m2.state, m2.max_level)
        ks = np.asarray(m2.state.key)
        live = np.asarray(m2.state.live)
        by_level: Dict[int, list] = {l: [] for l in
                                     range(2, m2.max_level + 1)}
        for nid in np.nonzero(live)[0]:
            for l in range(2, tower_height(int(ks[nid]),
                                           m2.max_level) + 1):
                by_level[l].append((int(ks[nid]), int(nid)))
        for l in range(2, m2.max_level + 1):
            want = sorted(by_level[l])
            row_k = np.asarray(tw.keys[l - 2])
            row_a = np.asarray(tw.addr[l - 2])
            got = [(int(row_k[i]), int(row_a[i]))
                   for i in range(len(want))]
            assert got == want, f"tower level {l} diverges from scalar"
            assert (row_k[len(want):] == 2 ** 31 - 1).all(), \
                f"tower level {l} padding corrupt"
        # and the rebuild is idempotent (same state -> same towers)
        tw2 = build_towers(m2.state, m2.max_level)
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(tw, tw2)), "tower rebuild not stable"


SCENARIOS = {
    "log": RequestLogScenario,
    "log2": ConcurrentLogScenario,
    "checkpoint": CheckpointScenario,
    "migrate": MigrateScenario,
    "rebalance": RebalanceScenario,
    "ordered": OrderedScenario,
}


# --------------------------------------------------------------------- #
# sweep driver                                                           #
# --------------------------------------------------------------------- #
def _run_once(scenario_cls, plan: CrashPlan,
              scenario_kw: Optional[dict] = None) -> Optional[CrashSite]:
    """One fresh-tmpdir scenario run under ``plan``; returns the fired
    site (None for a clean run) and always runs the recovery checks."""
    with tempfile.TemporaryDirectory() as d:
        sc = scenario_cls(Path(d), plan, **(scenario_kw or {}))
        try:
            sc.run()
            fired = None
        except CrashPoint as cp:
            fired = cp.site
        sc.check()
        return fired


def enumerate_sites(scenario_cls,
                    scenario_kw: Optional[dict] = None
                    ) -> List[CrashSite]:
    """Run the scenario once with no crash, returning every persistence
    site it visits (and sanity-checking its invariants crash-free)."""
    plan = CrashPlan()
    fired = _run_once(scenario_cls, plan, scenario_kw)
    assert fired is None
    return plan.sites


def _budget_indices(n: int, budget: Optional[int]) -> List[int]:
    """All sites, or an evenly spaced subset always containing the
    first and last site."""
    if budget is None or budget >= n:
        return list(range(n))
    return sorted({int(i) for i in
                   np.linspace(0, n - 1, max(2, budget)).round()})


def sweep(scenario_cls, *, budget: Optional[int] = None,
          evict_modes: Sequence[str] = ("none", "random"),
          seed: int = 0,
          scenario_kw: Optional[dict] = None) -> dict:
    """Crash-at-every-site sweep of one scenario: enumerate, then for
    each (site × eviction mode) crash there, recover, and run the
    scenario's invariant checks.  ``budget`` bounds the number of sites
    tested (evenly spaced, first and last always included).
    ``evict_modes`` may include ``"torn"`` — the partial-write
    adversary of :meth:`repro.persistence.manifest.StagedIO.crash`,
    which lands *torn* payloads (truncated or garbled) instead of whole
    files; every scenario's recovery must treat those exactly like torn
    records.  Returns a JSON-able report; ``report["failures"]`` is
    empty iff every recovery held every invariant."""
    sites = enumerate_sites(scenario_cls, scenario_kw)
    idxs = _budget_indices(len(sites), budget)
    failures = []
    runs = 0
    for i in idxs:
        for evict in evict_modes:
            plan = CrashPlan(crash_at=i, evict=evict,
                             seed=seed + 1009 * i)
            runs += 1
            try:
                fired = _run_once(scenario_cls, plan, scenario_kw)
                assert fired is not None and fired.index == i, \
                    "scenario is not deterministic: planned site not hit"
            except AssertionError as e:
                failures.append({
                    "site": i, "kind": sites[i].kind,
                    "target": sites[i].target, "evict": evict,
                    "error": str(e) or repr(e)})
    return {
        "layer": getattr(scenario_cls, "layer", scenario_cls.__name__),
        "n_sites": len(sites),
        "tested_sites": idxs,
        "runs": runs,
        "evict_modes": list(evict_modes),
        "sites": [dataclasses.asdict(s) for s in sites],
        "failures": failures,
    }
