"""Systematic crash-fault injection for the durable layers.

:data:`KINDS` is **the** crash-site kind registry: every persistence
instruction an instrumented IO object can report — to a
:class:`~repro.robustness.faultinject.CrashPlan` (crash injection) or a
:class:`~repro.analysis.trace.PersistTrace` (ordering analysis) — must
carry one of these kinds.  Both consumers validate against this one
tuple, so an unknown kind fails loudly everywhere instead of silently
registering an un-sweepable site.
"""

#: The shared crash-site kind registry (defined here, *before* the
#: faultinject import below, so ``from . import KINDS`` inside the
#: submodule resolves against this partially-initialized package).
KINDS = ("flush", "fence", "publish", "trim")

from .faultinject import (CrashPlan, CrashPoint, CrashSite,  # noqa: E402
                          SCENARIOS, enumerate_sites, sweep)

__all__ = ["KINDS", "CrashPlan", "CrashPoint", "CrashSite", "SCENARIOS",
           "enumerate_sites", "sweep"]
