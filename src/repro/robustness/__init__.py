"""Systematic crash-fault injection for the durable layers."""
from .faultinject import (CrashPlan, CrashPoint, CrashSite, SCENARIOS,
                          enumerate_sites, sweep)

__all__ = ["CrashPlan", "CrashPoint", "CrashSite", "SCENARIOS",
           "enumerate_sites", "sweep"]
