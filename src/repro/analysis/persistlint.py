"""PersistLint static pass: AST lint of the flush/fence/publish discipline.

Four rules over ``src/repro`` (rule ids are what waivers name):

* ``raw-durable-io`` — a module that imports
  :class:`~repro.persistence.manifest.StagedIO` is a *durable layer*;
  inside one, every byte bound for disk must go through StagedIO's
  write→flush→fence→publish path.  Raw mutations (``os.replace`` /
  ``os.rename`` / ``os.open`` / ``Path.write_*`` / ``.unlink`` /
  ``shutil.*`` / ``open(..., "w")``) bypass the staged crash model —
  they are flagged unless the receiver is the ``io`` object itself.
  ``persistence/manifest.py`` is exempt: it *is* the blessed
  implementation.
* ``publish-needs-fence`` — every ``.publish(...)`` call site must be
  preceded, in the same function, by a ``.fence()`` with no intervening
  durable ``.write(...)``: the rename must never make unfenced bytes
  visible.  ``.cas(...)`` publishes are exempt inside traversal-DS
  classes (ones defining ``critical``/``traverse``/``find_entry``),
  where the fence is issued by the policy driver
  (:meth:`repro.core.policies.NVTraversePolicy.before_mod`), and inside
  ``core/instr.py``/``core/pmem.py`` (the instrumented instruction
  itself); anywhere else a cas needs a lexically preceding fence.
* ``traverse-phase-persistence`` — the journey persists nothing:
  methods named ``traverse``/``find_entry`` must contain no
  flush/fence/write/cas calls, and in any function the statements
  between ``ctx.enter(Phase.TRAVERSE)`` and ``ctx.enter(Phase.
  CRITICAL)`` must not flush or fence.
* ``crash-site-kinds`` — every literal kind passed to ``.on_site(...)``
  or ``CrashSite(...)`` must come from the shared registry
  :data:`repro.robustness.KINDS`.

A finding is waived by annotating the flagged line (or the line above)
with ``# persistlint: waive(<rule>) — <why>``; waivers are counted and
reported, never silent.

>>> [v.rule for v in lint_source("x.py", "from repro.persistence."
...     "manifest import StagedIO\\nimport os\\nos.replace('a', 'b')\\n")]
['raw-durable-io']
>>> sorted(_waivers_in("x = 1  # persistlint: waive(raw-durable-io) — ok")
...        .items())
[(1, {'raw-durable-io'})]
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from ..robustness import KINDS

RULES = ("raw-durable-io", "publish-needs-fence",
         "traverse-phase-persistence", "crash-site-kinds")

#: raw filesystem mutations that bypass the staged crash model
_RAW_OS = {"replace", "rename", "remove", "unlink", "rmdir", "truncate",
           "open"}
_RAW_SHUTIL = {"move", "rmtree", "copy", "copyfile", "copy2", "copytree"}
_RAW_METHODS = {"write_text", "write_bytes", "touch", "unlink", "rename",
                "replace", "rmdir"}
#: persistence-relevant instructions banned in traversal phases
_PERSIST_CALLS = {"flush", "fence", "write", "write_local", "cas"}
#: modules that ARE the blessed IO implementation / instruction set
_RAW_IO_EXEMPT = ("persistence/manifest.py",)
_CAS_EXEMPT_FILES = ("core/instr.py", "core/pmem.py")

_WAIVE_RE = re.compile(r"#\s*persistlint:\s*waive\(([a-z-]+)\)")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    file: str
    line: int
    msg: str
    waived: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class StaticReport:
    n_files: int
    violations: List[Violation]          # unwaived: fatal
    waived: List[Violation]              # annotated, counted

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {"n_files": self.n_files, "ok": self.ok,
                "n_waived": len(self.waived),
                "violations": [v.to_dict() for v in self.violations],
                "waived": [v.to_dict() for v in self.waived]}


def _waivers_in(source: str) -> Dict[int, Set[str]]:
    """Map line number -> rule ids waived on that line."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        for m in _WAIVE_RE.finditer(text):
            out.setdefault(i, set()).add(m.group(1))
    return out


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _receiver_is_io(call: ast.Call) -> bool:
    """True for ``io.x(...)`` / ``self.io.x(...)`` / ``m.io.x(...)``."""
    f = call.func
    if not isinstance(f, ast.Attribute):
        return False
    v = f.value
    if isinstance(v, ast.Name):
        return v.id == "io"
    if isinstance(v, ast.Attribute):
        return v.attr == "io"
    return False


def _module_receiver(call: ast.Call) -> Optional[str]:
    """``os.replace(...)`` -> "os"; None for anything else."""
    f = call.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        return f.value.id
    return None


def _open_mode(call: ast.Call) -> Optional[str]:
    """Literal mode of a builtin ``open`` call, if recoverable."""
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant) \
            and isinstance(call.args[1].value, str):
        return call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            return kw.value.value
    return None


def _calls_in(node: ast.AST) -> List[ast.Call]:
    """Call nodes lexically inside ``node``, source order, excluding
    nested function/class/lambda bodies (they run elsewhere)."""
    calls: List[ast.Call] = []

    def visit(n: ast.AST) -> None:
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                continue
            if isinstance(child, ast.Call):
                calls.append(child)
            visit(child)

    visit(node)
    calls.sort(key=lambda c: (c.lineno, c.col_offset))
    return calls


def _imports_staged_io(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if any(a.name == "StagedIO" for a in node.names):
                return True
    return False


def _enter_phase(call: ast.Call) -> Optional[str]:
    """``ctx.enter(Phase.TRAVERSE)`` -> "TRAVERSE"."""
    if _call_name(call) != "enter" or not call.args:
        return None
    a = call.args[0]
    if isinstance(a, ast.Attribute):
        return a.attr
    return None


def _literal_kind(node: ast.AST) -> Tuple[bool, Optional[str]]:
    """(is_literal, value) of a candidate kind argument."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True, node.value
    return False, None


def lint_source(rel: str, source: str) -> List[Violation]:
    """Lint one module's source; ``rel`` is its repo-relative path
    (used for display and for the per-module exemptions)."""
    tree = ast.parse(source, filename=rel)
    waivers = _waivers_in(source)
    out: List[Violation] = []

    def emit(rule: str, line: int, msg: str) -> None:
        waived = rule in waivers.get(line, ()) \
            or rule in waivers.get(line - 1, ())
        out.append(Violation(rule, rel, line, msg, waived))

    durable = _imports_staged_io(tree) and not rel.endswith(_RAW_IO_EXEMPT)
    cas_exempt_file = rel.endswith(_CAS_EXEMPT_FILES)

    # ---- global walk: raw-durable-io + crash-site-kinds ---------------- #
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        name = _call_name(call)
        mod = _module_receiver(call)
        if durable:
            if mod == "os" and name in _RAW_OS:
                emit("raw-durable-io", call.lineno,
                     f"os.{name} in a durable layer bypasses StagedIO")
            elif mod == "shutil" and name in _RAW_SHUTIL:
                emit("raw-durable-io", call.lineno,
                     f"shutil.{name} in a durable layer bypasses StagedIO")
            elif isinstance(call.func, ast.Name) and name == "open":
                mode = _open_mode(call)
                if mode and any(c in mode for c in "wax+"):
                    emit("raw-durable-io", call.lineno,
                         f"bare open(..., {mode!r}) in a durable layer "
                         f"bypasses StagedIO")
            elif name in _RAW_METHODS and mod not in ("os", "shutil") \
                    and not _receiver_is_io(call) \
                    and not (name in ("replace", "rename")
                             and len(call.args) != 1):
                # Path.replace/rename take exactly one arg; two args is
                # str.replace, which is not filesystem IO at all
                emit("raw-durable-io", call.lineno,
                     f".{name}() on a non-StagedIO receiver in a "
                     f"durable layer bypasses the staged crash model")
        if name == "on_site" and call.args:
            lit, kind = _literal_kind(call.args[0])
            if lit and kind not in KINDS:
                emit("crash-site-kinds", call.lineno,
                     f"on_site kind {kind!r} not in the shared "
                     f"registry {KINDS}")
        if name == "CrashSite" and len(call.args) >= 2:
            lit, kind = _literal_kind(call.args[1])
            if lit and kind not in KINDS:
                emit("crash-site-kinds", call.lineno,
                     f"CrashSite kind {kind!r} not in the shared "
                     f"registry {KINDS}")

    # ---- scoped walk: publish domination + traversal purity ------------ #
    # map each method to its enclosing class, and each class to whether
    # it is a traversal DS (policy driver supplies the cas fences)
    method_class: Dict[ast.FunctionDef, Optional[ast.ClassDef]] = {}
    traversal_classes: Set[ast.ClassDef] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            methods = [c for c in node.body
                       if isinstance(c, ast.FunctionDef)]
            if any(m.name in ("critical", "traverse", "find_entry")
                   for m in methods):
                traversal_classes.add(node)
            for m in methods:
                method_class[m] = node

    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        cls = method_class.get(fn)
        in_traverse_method = fn.name in ("traverse", "find_entry") \
            and cls is not None
        calls = _calls_in(fn)
        last_fence: Optional[int] = None          # index into calls
        window = False                            # inside TRAVERSE..CRITICAL
        for i, call in enumerate(calls):
            name = _call_name(call)
            phase = _enter_phase(call)
            if phase is not None:
                window = phase == "TRAVERSE"
                continue
            if name == "fence":
                last_fence = i
            if (window or in_traverse_method) and name in _PERSIST_CALLS:
                where = (f"method {fn.name!r}" if in_traverse_method
                         else "the TRAVERSE phase window")
                emit("traverse-phase-persistence", call.lineno,
                     f"{name}() inside {where} — the journey must "
                     f"persist nothing")
            if name == "publish":
                if last_fence is None:
                    emit("publish-needs-fence", call.lineno,
                         "publish with no preceding fence() in this "
                         "function — unfenced bytes would become visible")
                elif any(_call_name(c) in ("write", "write_text",
                                           "write_bytes")
                         for c in calls[last_fence + 1:i]):
                    emit("publish-needs-fence", call.lineno,
                         "durable write between the last fence() and "
                         "this publish — the rename may expose it")
            if name == "cas" and not cas_exempt_file \
                    and (cls is None or cls not in traversal_classes) \
                    and last_fence is None:
                emit("publish-needs-fence", call.lineno,
                     "cas publish outside a traversal-DS class with no "
                     "preceding fence()")
    return out


def iter_lint_files(root: Path) -> List[Path]:
    return sorted(p for p in Path(root).rglob("*.py"))


def run_static(root: Optional[Path] = None,
               files: Optional[List[Path]] = None) -> StaticReport:
    """Lint ``files``, or every ``*.py`` under ``root`` (default: the
    installed ``src/repro`` tree this module lives in)."""
    if files is None:
        root = Path(root) if root else Path(__file__).resolve().parents[1]
        files = iter_lint_files(root)
        rel_of = {p: str(p.relative_to(root)) for p in files}
    else:
        files = [Path(p) for p in files]
        rel_of = {p: p.name for p in files}
    violations: List[Violation] = []
    waived: List[Violation] = []
    for p in files:
        for v in lint_source(rel_of[p], p.read_text()):
            (waived if v.waived else violations).append(v)
    return StaticReport(n_files=len(files), violations=violations,
                        waived=waived)
