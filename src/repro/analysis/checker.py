"""Ordering-rule replay over a recorded persistence trace.

The checker runs a per-target state machine over a
:class:`~repro.analysis.trace.PersistEvent` stream:

``(clean) --write--> dirty --flush--> flushed --fence--> (clean)``

A *write* to a flushed-but-unfenced target invalidates the earlier
flush (the deliberately strict hardware model: a ``clwb`` does not
cover bytes written after it, even though the forgiving ``StagedIO``
simulator would persist the newest bytes at the fence).  Against that
model the rules are:

**Fatal violations** (the discipline is broken):

* ``missing-flush`` — a write the layer relies on durably was never
  carried to a fence: a publish whose payload source is still dirty, or
  a dirty/unfenced target left at end of trace (``end_check``).  Such
  bytes reach NVRAM only by eviction luck.
* ``publish-before-persist`` — a publish whose payload was flushed but
  not yet fenced: the rename/CAS can become visible before its payload
  is durable.
* ``traversal-phase-persistence`` — any flush/fence carrying
  ``in_traverse=True``: the paper's core claim is that the journey
  persists nothing.

**Non-fatal diagnostics** (correct but wasteful):

* ``redundant-flush`` — flushing a target already in the flushed state
  with no intervening write.
* ``fence-with-nothing-pending`` — a fence with no flushed target to
  persist.

An event kind outside :data:`~repro.analysis.trace.EVENT_KINDS` raises
— the shared registry fails loudly here exactly as it does in
``CrashPlan.on_site``.

>>> from repro.analysis.trace import PersistEvent as E
>>> good = [E(0, "write", "a.tmp"), E(1, "flush", "a.tmp"),
...         E(2, "fence", ""), E(3, "publish", "a", src="a.tmp")]
>>> check_events(good).ok
True
>>> no_fence = [good[0], good[1], good[3]]      # fence deleted
>>> [f.rule for f in check_events(no_fence).violations]
['publish-before-persist']
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List

from .trace import EVENT_KINDS, PersistEvent

FATAL_RULES = ("missing-flush", "publish-before-persist",
               "traversal-phase-persistence")
DIAG_RULES = ("redundant-flush", "fence-with-nothing-pending")

_DIRTY, _FLUSHED = "dirty", "flushed"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule hit: ``rule`` at event ``index`` on ``target``."""
    rule: str
    index: int          # event index (-1 for end-of-trace findings)
    target: str
    detail: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TraceReport:
    n_events: int
    violations: List[Finding]       # fatal: discipline broken
    diagnostics: List[Finding]      # non-fatal: correct but wasteful

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {"n_events": self.n_events, "ok": self.ok,
                "violations": [f.to_dict() for f in self.violations],
                "diagnostics": [f.to_dict() for f in self.diagnostics]}


def check_events(events: Iterable[PersistEvent], *,
                 end_check: bool = True) -> TraceReport:
    """Replay ``events`` against the ordering rules.

    ``end_check=True`` (the file layers: every surviving write is part
    of the durable contract) reports targets still dirty or unfenced at
    end of trace as ``missing-flush``.  Use ``end_check=False`` for
    PMem structure traces, where volatile auxiliary state (the paper's
    Property 2) may legitimately stay unpersisted.
    """
    state: dict = {}                # target -> _DIRTY | _FLUSHED
    violations: List[Finding] = []
    diagnostics: List[Finding] = []
    n = 0
    for ev in events:
        n += 1
        if ev.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {ev.kind!r} "
                             f"(registry: {EVENT_KINDS})")
        if ev.in_traverse and ev.kind in ("flush", "fence"):
            violations.append(Finding(
                "traversal-phase-persistence", ev.index, ev.target,
                f"{ev.kind} issued during a traversal phase — the "
                f"journey must persist nothing"))
        if ev.kind == "write":
            # a write after a flush re-dirties: the flush no longer
            # covers the newest bytes
            state[ev.target] = _DIRTY
        elif ev.kind == "flush":
            if state.get(ev.target) == _FLUSHED:
                diagnostics.append(Finding(
                    "redundant-flush", ev.index, ev.target,
                    "flushed again with no intervening write"))
            else:
                # flushing a clean/unseen target is a valid marking
                # (e.g. persisting lines read during the critical phase)
                state[ev.target] = _FLUSHED
        elif ev.kind == "fence":
            pending = [t for t, s in state.items() if s == _FLUSHED]
            if not pending:
                diagnostics.append(Finding(
                    "fence-with-nothing-pending", ev.index, "",
                    "fence with no flushed target to persist"))
            for t in pending:
                del state[t]
        elif ev.kind == "publish":
            if ev.src is not None:
                st = state.get(ev.src)
                if st == _DIRTY:
                    violations.append(Finding(
                        "missing-flush", ev.index, ev.src,
                        f"publish of {ev.target!r} from a payload that "
                        f"was written but never flushed"))
                elif st == _FLUSHED:
                    violations.append(Finding(
                        "publish-before-persist", ev.index, ev.src,
                        f"publish of {ev.target!r} from a payload "
                        f"flushed but not yet fenced"))
                state.pop(ev.src, None)
            # the published name now holds durable bytes
            state.pop(ev.target, None)
        elif ev.kind == "trim":
            # unlink / remove_tree: the target (and, for a tree, every
            # name under it) leaves the durable contract
            state.pop(ev.target, None)
            prefix = ev.target.rstrip("/") + "/"
            for t in [t for t in state if t.startswith(prefix)]:
                del state[t]
    if end_check:
        for t, s in sorted(state.items()):
            what = ("written but never flushed" if s == _DIRTY
                    else "flushed but never fenced")
            violations.append(Finding(
                "missing-flush", -1, t,
                f"end of trace: {what} — durable only by eviction luck"))
    return TraceReport(n_events=n, violations=violations,
                       diagnostics=diagnostics)
