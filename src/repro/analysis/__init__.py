"""PersistLint: static + trace-based persistence-ordering analysis.

Two cooperating passes over the NVTraverse flush/fence/publish
discipline that the rest of the repo implements and docs/durability.md
argues in prose:

* :mod:`repro.analysis.persistlint` — AST-based **static lint** over
  ``src/repro``: durable layers must not bypass
  :class:`repro.persistence.manifest.StagedIO`, every publish must be
  fence-dominated with no intervening durable write, traversal-phase
  code must contain no persistence instructions, and every crash-site
  kind must come from the shared :data:`repro.robustness.KINDS`
  registry.
* :mod:`repro.analysis.trace` + :mod:`repro.analysis.checker` —
  **dynamic trace checking**: a :class:`~repro.analysis.trace.
  PersistTrace` records the full instruction stream through the same
  attach surface :class:`~repro.robustness.faultinject.CrashPlan` uses,
  and the checker replays it against the ordering rules
  (missing-flush, publish-before-persist, traversal-phase persistence;
  redundant-flush / fence-with-nothing-pending as diagnostics).

``tools/persist_lint.py`` is the CLI over both passes.
"""
from .checker import TraceReport, check_events
from .persistlint import StaticReport, Violation, run_static
from .trace import EVENT_KINDS, PersistEvent, PersistTrace, trace_scenario

__all__ = [
    "EVENT_KINDS", "PersistEvent", "PersistTrace", "trace_scenario",
    "TraceReport", "check_events",
    "StaticReport", "Violation", "run_static",
]
