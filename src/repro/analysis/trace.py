"""Persistence-instruction trace recording.

:class:`PersistTrace` is a :class:`~repro.robustness.faultinject.
CrashPlan` that never fires: attached to a
:class:`~repro.persistence.manifest.StagedIO` or
:class:`~repro.core.pmem.PMem` through the exact surface the crash
sweep uses (``plan.attach(obj)`` → ``obj.faults``), it records the
**full** executed instruction stream — writes included, which crash
sites deliberately omit — as a list of :class:`PersistEvent`.  The
stream is what :func:`repro.analysis.checker.check_events` replays
against the ordering rules.

Event kinds are the shared crash-site registry
:data:`repro.robustness.KINDS` plus ``"write"`` (a staged write is not
a crash site — crashing "before" a volatile write is the same crash as
before the next site — but the checker needs it to know what each
flush/fence/publish covers).  An unknown kind raises, mirroring
``CrashPlan.on_site``.
"""
from __future__ import annotations

import dataclasses
import tempfile
from pathlib import Path
from typing import List, Optional

from ..robustness import KINDS
from ..robustness.faultinject import SCENARIOS, CrashPlan

#: every kind a :class:`PersistEvent` may carry: the crash-site
#: registry plus the volatile ``"write"`` instruction.
EVENT_KINDS = ("write",) + KINDS


@dataclasses.dataclass(frozen=True)
class PersistEvent:
    """One executed persistence-relevant instruction.

    ``target`` is a staged-file rel path (StagedIO), a cache line
    (``line:N``) or CAS address (``addr:N``) for PMem, or ``""`` for a
    fence.  ``src`` is set only on file publishes: the staged tmp name
    whose bytes the rename makes visible.  ``in_traverse`` marks
    flush/fence instructions issued during an operation's traversal
    phase (must never happen for NVTraverse structures).
    """
    index: int
    kind: str
    target: str
    src: Optional[str] = None
    in_traverse: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class PersistTrace(CrashPlan):
    """A no-crash :class:`CrashPlan` that records the full stream.

    Inherits the site numbering (``sites`` / ``completed_sites``), so a
    scenario's own ``check()`` still works; additionally every
    instrumented instruction lands in :attr:`events` via the optional
    ``on_event`` hook the IO substrates call when present.
    """

    def __init__(self):
        super().__init__()          # crash_at=None, p_crash=0: never fires
        self.events: List[PersistEvent] = []

    def on_event(self, kind: str, target: str = "", *,
                 src: Optional[str] = None,
                 in_traverse: bool = False) -> None:
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r} "
                             f"(registry: {EVENT_KINDS})")
        self.events.append(PersistEvent(len(self.events), kind, target,
                                        src, in_traverse))


def trace_scenario(layer: str, scenario_kw: Optional[dict] = None
                   ) -> PersistTrace:
    """Run one faultinject scenario (``log`` / ``checkpoint`` /
    ``migrate`` / ``rebalance``) in no-crash mode under a
    :class:`PersistTrace` and return the populated trace.  The
    scenario's own recovery invariants are checked too — a trace of a
    broken run would prove nothing."""
    scenario_cls = SCENARIOS[layer]
    trace = PersistTrace()
    with tempfile.TemporaryDirectory() as d:
        sc = scenario_cls(Path(d), trace, **(scenario_kw or {}))
        sc.run()
        sc.check()
    return trace
