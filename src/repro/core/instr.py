"""Instruction layer: per-operation execution context over :class:`PMem`.

Every traversal data structure in this package accesses shared memory only
through an :class:`OpContext` — the enforcement point for

  * the three-phase operation layout of Algorithm 1 (findEntry → traverse →
    critical), tracked as ``ctx.phase``;
  * Property 4(1): *the traverse method does not modify shared memory* —
    writes/CAS during the traverse phase raise;
  * policy hooks (:mod:`repro.core.policies`) that inject flush/fence
    instructions per the NVTraverse Protocols 1–2 or per the Izraelevitz
    baseline transformation;
  * the interleaving scheduler: ``step_hook`` is invoked before every shared
    instruction, letting the linearizability harness preempt the operation
    or inject a crash at any instruction boundary.

Pointer/mark packing (Harris-style): a pointer word is ``(addr << 1) | mark``
with ``addr == 0`` reserved as null, so a marked pointer differs from its
unmarked form only in bit 0 — "we consider a 'marking' of a node to be a
non-pointer value modification, even though some algorithms place the mark
physically on the pointer field" (§3.1).
"""
from __future__ import annotations

import enum
from typing import Callable, Optional

from .pmem import PMem

NULLPTR = 0  # packed null (address 0 is reserved, never allocated)


def pack(addr: int, mark: int = 0) -> int:
    return (addr << 1) | mark


def unpack(word: int) -> tuple[int, int]:
    return word >> 1, word & 1


def is_marked(word: int) -> bool:
    return bool(word & 1)


def with_mark(word: int) -> int:
    return word | 1


class Phase(enum.Enum):
    ENTRY = "entry"
    TRAVERSE = "traverse"
    CRITICAL = "critical"


class CrashInterrupt(Exception):
    """Raised inside an operation thread when the scheduler injects a crash."""


class TraversalWriteError(RuntimeError):
    """Property 4(1) violation: traverse attempted to modify shared memory."""


class OpContext:
    def __init__(self, mem: PMem, policy, *,
                 step_hook: Optional[Callable[[str], None]] = None,
                 opid: int = 0):
        self.mem = mem
        self.policy = policy
        self.step_hook = step_hook or (lambda kind: None)
        self.opid = opid
        self.phase = Phase.ENTRY

    # -- phase management (driven by traversal.run_operation) ----------- #
    def enter(self, phase: Phase) -> None:
        self.phase = phase

    @property
    def in_traverse(self) -> bool:
        return self.phase is Phase.TRAVERSE

    # -- shared instructions -------------------------------------------- #
    def read(self, addr: int, *, immutable: bool = False) -> int:
        self.step_hook("read")
        val = self.mem.read(addr)
        self.policy.after_read(self, addr, immutable=immutable)
        return val

    def write(self, addr: int, value: int) -> None:
        if self.in_traverse:
            raise TraversalWriteError("write during traverse phase")
        self.step_hook("write")
        self.policy.before_mod(self, addr)
        self.mem.write(addr, value)
        self.policy.after_mod(self, addr)

    def cas(self, addr: int, expected: int, new: int) -> bool:
        if self.in_traverse:
            raise TraversalWriteError("CAS during traverse phase")
        self.step_hook("cas")
        self.policy.before_mod(self, addr)
        ok = self.mem.cas(addr, expected, new)
        self.policy.after_mod(self, addr)
        return ok

    # -- node initialization (pre-publication, process-local) ----------- #
    def write_local(self, addr: int, value: int) -> None:
        """Initializing write to a not-yet-published node.

        Protocol 2 note: "when initializing a node, a process executes
        flushes after initializing each field, but only needs to fence once
        before atomically inserting the new node".
        """
        self.step_hook("write_local")
        self.mem.write(addr, value)
        self.policy.after_local_write(self, addr)

    def alloc(self, n_words: int) -> int:
        return self.mem.alloc(n_words)

    # -- raw persistence instructions (issued by policies) --------------- #
    def flush(self, addr: int) -> None:
        self.step_hook("flush")
        self.mem.flush(addr, in_traverse=self.in_traverse)

    def fence(self) -> None:
        self.step_hook("fence")
        self.mem.fence(in_traverse=self.in_traverse)

    # -- return boundary -------------------------------------------------#
    def before_return(self) -> None:
        self.policy.before_return(self)
