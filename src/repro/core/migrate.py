"""Online migration engine: dynamic resize/rehash with NVTraverse-correct
migration commits.

The bump-allocator durable map (:mod:`repro.core.batched`) has a fixed
node pool and a fixed bucket count.  This module grows both *online*: a
migration is a sequence of **bounded rounds**, each of which drains a
contiguous bucket range from the old table and commits it into a larger
new table as one plan/commit batch — the same ``update_parallel`` engine
user traffic runs on, so every migrated key pays exactly the paper's
O(1) flushes + 2 fences at its destination and nothing on the journey.

Invariants (the migration protocol):

* **The old table is frozen.**  From ``start_migration`` on, every user
  update commits into the *new* table only; the old table is never
  written again.  Its pre-migration snapshot is therefore a stable drain
  source for every round.
* **New is authoritative per key.**  Once a key has *any* node in the
  new table — live or dead — the new table's word is final.  A dead node
  in the new table means "deleted during migration", and must never be
  resurrected from the old table's stale copy; drains filter on
  :func:`repro.core.batched.probe`'s ``exists``, not on insert success.
* **Lookups are new-then-old, deterministically**: if the key has a node
  in the new table, answer from it; otherwise answer from the old table.
  (The frontier makes the old consult redundant for drained buckets —
  their live keys all exist in the new table — so the rule needs no
  frontier check and cannot race one.)
* **User updates pull first.**  A user batch during migration is
  committed as one *mixed* ``update_parallel`` round of
  ``[pull-inserts; user ops]``: each distinct user key that is live in
  the old table and absent from the new is first pulled over with its
  old value, after which the user's inserts/deletes see exactly the
  merged map's liveness.  Pulls are ordinary inserts — same accounting,
  same conflict resolution.
* **The frontier is durable.**  Each round — drain or user — is
  journaled (``round_NNNNNN.npz``: op codes, keys, values, frontier
  after) with flush → fence → atomic publish, and the
  :class:`MigrationState` header (phase, frontier, old/new pool handles)
  is published at start and at finish.  A crash between rounds recovers
  by replaying the journal over the old-table snapshot: the engine is
  deterministic, so the recovered state is *bit-identical* to the
  pre-round or post-round state — never a torn mix — and migration
  resumes from the recovered frontier.

:class:`MigratingMap` wraps all of this behind the ordinary
insert/delete/update/lookup API and grows itself automatically: an
update batch that would not fit triggers ``start_migration`` and each
subsequent update advances ``rounds_per_update`` migration rounds, so a
map seeded at capacity C absorbs an unbounded key stream under live
mixed traffic.  :func:`migrate_state` is the journal-free functional
core (used by :class:`repro.persistence.index.MembershipIndex` growth
and the sharded layer's rebalancing).
"""
from __future__ import annotations

import io as _io
import json
import time
from pathlib import Path
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import batched as B
from ..obs.compile import get_tracker
from ..obs.metrics import get_registry

_NIL = int(B.NIL)


class MigrationState(NamedTuple):
    """The durable migration header — small enough to publish atomically.

    ``old``/``new`` are *pool handles*: (capacity, n_buckets) pairs that,
    with the journaled rounds, fully determine both tables.  ``phase``
    is ``"migrating"`` until the last drain round publishes, then
    ``"done"``.  ``frontier``/``n_rounds`` are snapshots *as of the
    header's publish* (0 at start; final values in the ``done``
    header) — live progress is derived from the published round files
    themselves on recovery, never from a stale header.

    >>> h = MigrationState(phase="migrating", frontier=3, old=(128, 8),
    ...                    new=(512, 16), buckets_per_round=2, n_rounds=5)
    >>> MigrationState.from_bytes(h.to_bytes()) == h
    True
    """
    phase: str
    frontier: int          # global old-bucket drain frontier
    old: Tuple[int, int]   # (capacity, n_buckets) of the frozen old pool
    new: Tuple[int, int]   # (capacity, n_buckets) of the growing new pool
    buckets_per_round: int
    n_rounds: int          # journaled rounds (drain + user)

    def to_bytes(self) -> bytes:
        return json.dumps(self._asdict(), sort_keys=True).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "MigrationState":
        d = json.loads(b.decode())
        return MigrationState(phase=d["phase"], frontier=d["frontier"],
                              old=tuple(d["old"]), new=tuple(d["new"]),
                              buckets_per_round=d["buckets_per_round"],
                              n_rounds=d["n_rounds"])


class MigrationReport(NamedTuple):
    rounds: int            # drain rounds run
    migrated: int          # live keys drained into the new table
    skipped: int           # drained keys already owned by the new table
    max_round_batch: int   # largest drain batch (bounded-round proof)


# --------------------------------------------------------------------- #
# durable round machinery (shared by every bounded-round migration)      #
# --------------------------------------------------------------------- #
class RoundJournal:
    """Range/mesh-generic durable round journal.

    Every bounded-round migration in this codebase — the single-device
    resize/rehash (``mig_NNNN/``, :class:`MigratingMap`) and the live
    mesh rebalance (``reb_NNNN/``,
    :class:`repro.core.rebalance.RebalancingShardedMap`) — persists the
    same three artifacts through a
    :class:`repro.persistence.manifest.StagedIO`:

    * a frozen-source **snapshot** (``old.npz``), flushed once at start;
    * a small JSON **header** (``state.json``), published atomically at
      start and at finish;
    * numbered **round records** (``round_NNNNNN.npz``), one per
      committed round, each written flush → fence → atomic publish —
      the rename is the commit point, so a crash mid-round rolls the
      journal back to exactly the previous round.

    The journal never interprets the arrays it stores; callers replay
    them through their own (deterministic) engine on recovery, which is
    what makes the recovered state bit-identical to a round boundary.
    """

    def __init__(self, io, dirname: str):
        self.io = io
        self.d = dirname
        self.n_rounds = 0

    def write_snapshot(self, arrays: dict, name: str = "old.npz") -> None:
        """Flush the frozen drain source (no publish of its own: the
        header's atomic publish commits the whole start)."""
        buf = _io.BytesIO()
        np.savez(buf, **arrays)
        self.io.write(f"{self.d}/{name}", buf.getvalue())
        self.io.flush(f"{self.d}/{name}")

    def publish_header(self, payload: bytes) -> None:
        """flush(header) → fence → atomic publish of ``state.json``."""
        self.io.write(f"{self.d}/state.tmp", payload)
        self.io.flush(f"{self.d}/state.tmp")
        self.io.fence()
        self.io.publish(f"{self.d}/state.tmp", f"{self.d}/state.json")

    def append(self, **arrays) -> None:
        """Durably commit one round: flush(record) → fence → publish
        (the atomic rename is the CAS; a crash before it leaves the
        journal at the previous round — pre-round state exactly)."""
        buf = _io.BytesIO()
        np.savez(buf, **arrays)
        tmp = f"{self.d}/round.tmp"
        self.io.write(tmp, buf.getvalue())
        self.io.flush(tmp)
        self.io.fence()
        self.io.publish(tmp, f"{self.d}/round_{self.n_rounds:06d}.npz")
        self.n_rounds += 1

    @staticmethod
    def newest_dir(root, prefix: str) -> Optional[str]:
        """Newest journal dir (``<prefix>_NNNN``) with a published
        header, or None — crash recovery's entry point."""
        digs = sorted(p.name for p in Path(root).glob(f"{prefix}_*")
                      if (p / "state.json").exists())
        return digs[-1] if digs else None

    @staticmethod
    def read(root, dirname: str, snapshot: str = "old.npz"):
        """Load one journal: ``(header bytes, snapshot dict, rounds)``,
        rounds as dicts in publish order (the replay order)."""
        root = Path(root)
        hdr = (root / dirname / "state.json").read_bytes()
        snap_npz = np.load(
            _io.BytesIO((root / dirname / snapshot).read_bytes()))
        snap = {k: np.asarray(snap_npz[k]) for k in snap_npz.files}
        rounds = []
        for rp in sorted((root / dirname).glob("round_*.npz")):
            rec = np.load(_io.BytesIO(rp.read_bytes()))
            rounds.append({k: np.asarray(rec[k]) for k in rec.files})
        return hdr, snap, rounds


# --------------------------------------------------------------------- #
# host-side helpers                                                      #
# --------------------------------------------------------------------- #
def _pad_pow2(*arrs, n=None):
    """Pad arrays to the next power of two (valid-masked), capping jit
    retraces at one per log2 size.  Returns (padded jnp arrays, valid)."""
    n = arrs[0].shape[0] if n is None else n
    total = max(1, 1 << (n - 1).bit_length())
    out = [jnp.asarray(np.concatenate(
        [a, np.zeros(total - n, a.dtype)])) for a in arrs]
    return out, jnp.asarray(np.arange(total) < n)


def _probe_np(state, ks: np.ndarray, n_buckets: int):
    """Host-facing :func:`repro.core.batched.probe` (padded, trimmed)."""
    n = ks.shape[0]
    if n == 0:
        z = np.zeros(0, np.bool_)
        return z, z, np.zeros(0, np.int32)
    (pk,), _ = _pad_pow2(ks)
    ex, live, vals = B.probe(state, pk, n_buckets)
    return (np.asarray(ex)[:n], np.asarray(live)[:n],
            np.asarray(vals)[:n])


def host_state(state) -> dict:
    """One device_get of every field → plain numpy dict (the frozen old
    table is read this way once per migration, then sliced per round)."""
    import jax
    st = jax.device_get(state)
    return {f: np.asarray(getattr(st, f)) for f in st._fields}


def drain_range(old: dict, lo: int, hi: int):
    """Canonical drain order of old buckets ``[lo, hi)``: bucket
    ascending, chain head→tail (newest-first) within a bucket, live
    nodes only.  Deterministic, so replaying the drained sequence
    through either engine rebuilds the same table bit for bit."""
    ks, vs = [], []
    head, nxt = old["head"], old["nxt"]
    key, val, live = old["key"], old["val"], old["live"]
    for b in range(lo, hi):
        node = int(head[b])
        while node != _NIL:
            if live[node]:
                ks.append(key[node])
                vs.append(val[node])
            node = int(nxt[node])
    return (np.asarray(ks, np.int32), np.asarray(vs, np.int32))


def items_of_host(old: dict) -> dict:
    """``{key: (live, val)}`` over allocated nodes of a host-side map."""
    c = int(old["cursor"])
    return {int(k): (bool(l), int(v)) for k, l, v in
            zip(old["key"][1:c], old["live"][1:c], old["val"][1:c])}


def _run_batch(state, ops, ks, vs, n_buckets: int):
    """One padded plan/commit round; returns (state', ok, stats).

    This is the capacity-ladder jit seam: ``update_parallel`` retraces
    on every fresh (pool capacity, n_buckets, padded batch width)
    signature, so the NVTrace compile tracker times the first call per
    signature and attributes the stall to the active reason (a
    ``MigratingMap`` growth step declares ``capacity_ladder``)."""
    n = ks.shape[0]
    if n == 0:
        return state, np.zeros(0, np.bool_), None
    (po, pk, pv), valid = _pad_pow2(ops, ks, vs)
    trk = get_tracker()
    sig = (int(state.key.shape[0]), n_buckets, int(po.shape[0]))
    if trk.enabled and trk.first_seen("migrate.update_parallel", sig):
        t0 = time.perf_counter()
        state, ok, stats = B.update_parallel(state, po, pk, pv,
                                             n_buckets, valid=valid)
        ok.block_until_ready()
        trk.record("migrate.update_parallel",
                   f"cap={sig[0]},nb={n_buckets},n={sig[2]}",
                   (time.perf_counter() - t0) * 1e6)
    else:
        state, ok, stats = B.update_parallel(state, po, pk, pv,
                                             n_buckets, valid=valid)
    return state, np.asarray(ok)[:n], stats


def migrate_state(state, n_buckets: int, new_capacity: int,
                  new_n_buckets: Optional[int] = None,
                  buckets_per_round: Optional[int] = None):
    """Journal-free full migration: drain ``state`` into a fresh
    ``(new_capacity, new_n_buckets)`` table in bounded rounds of
    ``buckets_per_round`` old buckets each.  Returns
    ``(new_state, MigrationReport)``.  Every drained insert must land —
    the caller sizes the new pool — so a capacity failure here raises
    instead of silently dropping keys."""
    nb_new = new_n_buckets or 2 * n_buckets
    bpr = buckets_per_round or max(1, n_buckets // 16)
    old = host_state(state)
    new = B.make_state(new_capacity, nb_new)
    rounds = migrated = max_batch = 0
    for lo in range(0, n_buckets, bpr):
        ks, vs = drain_range(old, lo, min(lo + bpr, n_buckets))
        ops = np.zeros(ks.shape[0], np.int32)       # all OP_INSERT
        new, ok, _ = _run_batch(new, ops, ks, vs, nb_new)
        if not ok.all():
            raise RuntimeError(
                f"migration drain overflowed the new pool "
                f"(capacity {new_capacity}) at bucket {lo}")
        rounds += 1
        migrated += ks.shape[0]
        max_batch = max(max_batch, int(ks.shape[0]))
    return new, MigrationReport(rounds=rounds, migrated=migrated,
                                skipped=0, max_round_batch=max_batch)


# --------------------------------------------------------------------- #
# the online map                                                         #
# --------------------------------------------------------------------- #
class MigratingMap:
    """Durable map with online capacity growth + rehash.

    Steady state it is a thin host wrapper over the plan/commit engine.
    When an update batch would not fit, it opens a migration to a table
    of ``2×`` the pool (and ``2×`` the buckets — a true rehash, halving
    the load factor), then amortizes the drain over subsequent traffic:
    every ``update()`` first advances ``rounds_per_update`` migration
    rounds, then commits the user batch into the new table (pull-first,
    see module docstring).  ``root`` (optional) makes the migration
    durable: the :class:`MigrationState` header and every round are
    journaled through a :class:`repro.persistence.manifest.StagedIO`
    with flush → fence → atomic publish, and :meth:`recover` rebuilds a
    bit-identical map from the journal after a crash."""

    def __init__(self, capacity: int = 4096, n_buckets: int = 128, *,
                 root=None, buckets_per_round: Optional[int] = None,
                 rounds_per_update: int = 1, seed: int = 0):
        self.capacity = capacity
        self.n_buckets = n_buckets
        self.state = B.make_state(capacity, n_buckets)
        self.buckets_per_round = buckets_per_round
        self.rounds_per_update = rounds_per_update
        self.io = None
        if root is not None:
            from ..persistence.manifest import StagedIO
            self.io = StagedIO(Path(root), seed=seed)
        self._mig = None           # in-flight migration bookkeeping
        self._journal = None       # RoundJournal of the in-flight migration
        self._mig_seq = 0          # completed+started migrations (dir name)
        self.migrations_completed = 0
        self.rounds_total = 0
        self.migrated_total = 0
        self.pulls_total = 0
        self.last_stats = None

    # ---------------- steady-state + migrating op API ----------------- #
    def update(self, ops, ks, vs) -> np.ndarray:
        """One mixed plan/commit round in batch order; grows the map (via
        migration rounds) whenever the batch would not fit.  Returns
        per-op ``ok`` exactly as the engine would on an unbounded pool —
        growth is invisible to callers."""
        ops = np.asarray(ops, np.int32)
        ks = np.asarray(ks, np.int32)
        vs = np.asarray(vs, np.int32)
        if self._mig is None:
            if self._fits(self.state, self.capacity, self.n_buckets,
                          ops, ks):
                self.state, ok, self.last_stats = _run_batch(
                    self.state, ops, ks, vs, self.n_buckets)
                return ok
            self.start_migration(
                new_capacity=self._grown_capacity(ops, ks))
        for _ in range(self.rounds_per_update):
            if self._mig is not None:
                self.migrate_round()
        if self._mig is None:
            return self.update(ops, ks, vs)     # finished mid-call
        return self._commit_migrating(ops, ks, vs)

    def insert(self, ks, vs) -> np.ndarray:
        ks = np.asarray(ks, np.int32)
        return self.update(np.full(ks.shape, B.OP_INSERT, np.int32),
                           ks, vs)

    def delete(self, ks) -> np.ndarray:
        ks = np.asarray(ks, np.int32)
        return self.update(np.full(ks.shape, B.OP_DELETE, np.int32),
                           ks, np.zeros_like(ks))

    def lookup(self, ks) -> Tuple[np.ndarray, np.ndarray]:
        """New-then-old: a key with any node in the new table is answered
        from it (its dead nodes veto the old table's stale copy);
        otherwise the old table answers.  Zero persistence work."""
        ks = np.asarray(ks, np.int32)
        if self._mig is None:
            n = ks.shape[0]
            if n == 0:
                return np.zeros(0, np.bool_), np.zeros(0, np.int32)
            (pk,), _ = _pad_pow2(ks)
            f, v = B.lookup(self.state, pk, self.n_buckets)
            return np.asarray(f)[:n], np.asarray(v)[:n]
        m = self._mig
        ex_new, live_new, val_new = _probe_np(m["new"], ks, m["nb_new"])
        _, live_old, val_old = _probe_np(self.state, ks, self.n_buckets)
        return B.merge_new_old(ex_new, live_new, val_new,
                               live_old, val_old)

    def items(self) -> dict:
        """Abstract content ``{key: (live, val)}``, new-authoritative."""
        out = items_of_host(host_state(self.state))
        if self._mig is not None:
            out.update(items_of_host(host_state(self._mig["new"])))
        return out

    @property
    def migrating(self) -> bool:
        return self._mig is not None

    @property
    def frontier(self) -> Optional[int]:
        return None if self._mig is None else self._mig["frontier"]

    @property
    def flushes(self) -> int:
        f = int(self.state.flushes)
        if self._mig is not None:
            f += int(self._mig["new"].flushes)
        return f

    @property
    def fences(self) -> int:
        f = int(self.state.fences)
        if self._mig is not None:
            f += int(self._mig["new"].fences)
        return f

    # ---------------- capacity planning -------------------------------- #
    def _fits(self, state, capacity, n_buckets, ops, ks,
              reserve: int = 0) -> bool:
        """Exact fit check: the batch allocates one node per distinct
        absent key that has at least one insert op (resurrects and
        deletes never allocate).  The probe (a device round-trip) only
        runs when the batch-size upper bound does not already prove
        fitness — steady state costs one int comparison."""
        if int(state.cursor) + ks.shape[0] + reserve <= capacity:
            return True
        ins = np.unique(ks[ops == B.OP_INSERT])
        if ins.size:
            ex, _, _ = _probe_np(state, ins, n_buckets)
            n_fresh = int((~ex).sum())
        else:
            n_fresh = 0
        return int(state.cursor) + n_fresh + reserve <= capacity

    def _grown_capacity(self, ops, ks) -> int:
        live = int(np.asarray(self.state.live).sum())
        need = 1 + live + ks.shape[0]
        cap = max(2 * self.capacity, 2 * need)
        return cap

    # ---------------- migration control -------------------------------- #
    def start_migration(self, new_capacity: Optional[int] = None,
                        new_n_buckets: Optional[int] = None,
                        buckets_per_round: Optional[int] = None) -> None:
        """Freeze the current table as the drain source, open an empty
        larger table, and durably publish the :class:`MigrationState`
        header (phase=migrating, frontier=0) plus the old-pool snapshot."""
        assert self._mig is None, "migration already in flight"
        cap_new = new_capacity or 2 * self.capacity
        nb_new = new_n_buckets or 2 * self.n_buckets
        bpr = (buckets_per_round or self.buckets_per_round
               or max(1, self.n_buckets // 16))
        old_host = host_state(self.state)
        live_old = int(old_host["live"].sum())
        self._mig = {
            "new": B.make_state(cap_new, nb_new),
            "cap_new": cap_new, "nb_new": nb_new, "bpr": bpr,
            "frontier": 0, "n_rounds": 0,
            "old_host": old_host,            # frozen: one device_get
            "remaining_live": live_old,      # drain upper bound (reserve)
            "migrated": 0, "skipped": 0,
        }
        self._mig_seq += 1
        if self.io is not None:
            self._journal = RoundJournal(self.io, self._mig_dir())
            self._journal.write_snapshot(old_host)
            self._publish_header("migrating")

    def _mig_dir(self) -> str:
        return f"mig_{self._mig_seq:04d}"

    def _header(self, phase: str) -> MigrationState:
        m = self._mig
        return MigrationState(
            phase=phase, frontier=m["frontier"],
            old=(self.capacity, self.n_buckets),
            new=(m["cap_new"], m["nb_new"]),
            buckets_per_round=m["bpr"], n_rounds=m["n_rounds"])

    def _publish_header(self, phase: str) -> None:
        self._journal.publish_header(self._header(phase).to_bytes())

    def _journal_round(self, ops, ks, vs, frontier_after: int) -> None:
        """Durably commit one round through the shared
        :class:`RoundJournal` (flush → fence → atomic publish)."""
        m = self._mig
        if self._journal is None:
            m["n_rounds"] += 1
            return
        self._journal.append(ops=ops, ks=ks, vs=vs,
                             frontier=np.int32(frontier_after))
        m["n_rounds"] = self._journal.n_rounds

    def migrate_round(self) -> bool:
        """Drain the next ``buckets_per_round`` old buckets into the new
        table as one plan/commit batch, journal it, and advance the
        frontier.  Returns True when the migration completed (the last
        round also swaps the tables)."""
        m = self._mig
        assert m is not None, "no migration in flight"
        lo = m["frontier"]
        hi = min(lo + m["bpr"], self.n_buckets)
        ks, vs = drain_range(m["old_host"], lo, hi)
        n_live = ks.shape[0]
        if n_live:
            # new-authoritative filter: keys user traffic already pulled
            # (or re-inserted, or deleted) must not be re-migrated
            ex, _, _ = _probe_np(m["new"], ks, m["nb_new"])
            ks, vs = ks[~ex], vs[~ex]
        ops = np.zeros(ks.shape[0], np.int32)
        with get_tracker().reason("capacity_ladder"):
            m["new"], ok, _ = _run_batch(m["new"], ops, ks, vs,
                                         m["nb_new"])
        if not ok.all():      # not assert: must survive python -O too
            raise RuntimeError(
                "migration drain dropped keys (new pool undersized: "
                f"capacity {m['cap_new']}, frontier {lo})")
        self._journal_round(ops, ks, vs, hi)
        m["frontier"] = hi
        m["migrated"] += int(ks.shape[0])
        m["skipped"] += int(n_live - ks.shape[0])
        m["remaining_live"] -= n_live
        self.rounds_total += 1     # per-instance shims; registry mirror:
        self.migrated_total += int(ks.shape[0])
        get_registry().counter("map_migration_rounds_total").inc()
        get_registry().counter("map_migrated_keys_total").inc(
            int(ks.shape[0]))
        if hi >= self.n_buckets:
            self._finish_migration()
            return True
        return False

    def run_migration(self) -> MigrationReport:
        """Drive the in-flight migration to completion (blocking)."""
        assert self._mig is not None
        m = self._mig
        mx = 0
        r0, g0, s0 = self.rounds_total, self.migrated_total, m["skipped"]
        while self._mig is not None:
            before = self.migrated_total
            self.migrate_round()
            mx = max(mx, self.migrated_total - before)
        return MigrationReport(rounds=self.rounds_total - r0,
                               migrated=self.migrated_total - g0,
                               skipped=m["skipped"] - s0,
                               max_round_batch=mx)

    def _finish_migration(self) -> None:
        m = self._mig
        if self.io is not None:
            self._publish_header("done")
            if self._mig_seq > 1:      # previous migration's journal is
                self.io.remove_tree(   # superseded: stop the geometric
                    f"mig_{self._mig_seq - 1:04d}")   # disk growth
        # carry the frozen old table's persistence accounting into the
        # adopted state so the public flushes/fences counters stay
        # monotone across growth events (they summed old+new during the
        # migration; dropping the old half would step them backwards)
        self.state = m["new"]._replace(
            flushes=m["new"].flushes + self.state.flushes,
            fences=m["new"].fences + self.state.fences)
        self.capacity, self.n_buckets = m["cap_new"], m["nb_new"]
        self._mig = None
        self._journal = None
        self.migrations_completed += 1   # shim; registry mirror:
        get_registry().counter("map_migrations_total").inc()

    def _commit_migrating(self, ops, ks, vs) -> np.ndarray:
        """Commit a user batch into the new table as one mixed round of
        ``[pull-inserts; user ops]`` (pull-first, see module docstring)."""
        m = self._mig
        uniq = np.unique(ks)
        ex_new, _, _ = _probe_np(m["new"], uniq, m["nb_new"])
        cand = uniq[~ex_new]
        _, live_old, val_old = _probe_np(self.state, cand, self.n_buckets)
        pull_ks = cand[live_old]
        pull_vs = val_old[live_old].astype(np.int32)
        # every pull and every fresh user insert allocates at worst one
        # node; the un-drained remainder must still fit behind them
        fresh_cand = cand[~live_old]     # absent from new AND old: only
        n_fresh = int(pull_ks.size) + int(   # user inserts can alloc them
            np.isin(np.unique(ks[ops == B.OP_INSERT]), fresh_cand,
                    assume_unique=True).sum())
        fits = (int(m["new"].cursor) + n_fresh + m["remaining_live"]
                <= m["cap_new"])
        if not fits:
            # the new pool cannot take this batch plus the un-drained
            # remainder: finish the migration now (the reserve guarantees
            # the drains fit) and let the steady-state path grow again
            self.run_migration()
            return self.update(ops, ks, vs)
        bops = np.concatenate(
            [np.full(pull_ks.size, B.OP_INSERT, np.int32), ops])
        bks = np.concatenate([pull_ks, ks])
        bvs = np.concatenate([pull_vs, vs])
        with get_tracker().reason("capacity_ladder"):
            m["new"], ok, self.last_stats = _run_batch(
                m["new"], bops, bks, bvs, m["nb_new"])
        if not ok[:pull_ks.size].all():   # not assert: survive python -O
            raise RuntimeError("migration pull dropped keys "
                               "(reserve accounting bug)")
        self._journal_round(bops, bks, bvs, m["frontier"])
        self.pulls_total += int(pull_ks.size)   # shim; registry mirror:
        get_registry().counter("map_pulls_total").inc(int(pull_ks.size))
        return ok[pull_ks.size:]

    # ---------------- crash recovery ----------------------------------- #
    def crash(self, evict: str = "none", p_evict: float = 0.5) -> None:
        """Simulate a process kill: the staging area is lost (any
        unfenced journal bytes with it) and the in-memory tables are
        dropped.  ``evict`` selects the shared implicit-eviction
        adversary (:func:`repro.core.pmem.evicted_mask`) over the
        staged journal files.  Use :meth:`recover` on the same root
        afterwards."""
        assert self.io is not None, "crash() needs a durable root"
        self.io.crash(evict=evict, p_evict=p_evict)
        self.state = None
        self._mig = None
        self._journal = None

    @classmethod
    def recover(cls, root, *, rounds_per_update: int = 1,
                seed: int = 0) -> "MigratingMap":
        """Rebuild from the journal: load the newest migration's header +
        old-pool snapshot, replay the published rounds in order through
        the plan/commit engine (deterministic → bit-identical), and
        resume from the recovered frontier.  A ``done`` header recovers
        the completed table; no migration dir recovers an empty map."""
        root = Path(root)
        d = RoundJournal.newest_dir(root, "mig")
        m = cls(rounds_per_update=rounds_per_update, root=root, seed=seed)
        if d is None:
            return m
        hdr_bytes, old_host, rounds = RoundJournal.read(root, d)
        hdr = MigrationState.from_bytes(hdr_bytes)
        m._mig_seq = int(d.split("_")[1])
        m.capacity, m.n_buckets = hdr.old
        cap_new, nb_new = hdr.new
        new = B.make_state(cap_new, nb_new)
        frontier = 0
        n_rounds = 0
        for rec in rounds:
            new, ok, _ = _run_batch(new, rec["ops"], rec["ks"],
                                    rec["vs"], nb_new)
            frontier = max(frontier, int(rec["frontier"]))
            n_rounds += 1
        if hdr.phase == "done":
            # same accounting carry as _finish_migration, so a recovered
            # completed table is bit-identical to the live one's
            m.state = new._replace(
                flushes=new.flushes + jnp.int32(int(old_host["flushes"])),
                fences=new.fences + jnp.int32(int(old_host["fences"])))
            m.capacity, m.n_buckets = cap_new, nb_new
            m.migrations_completed = 1
            return m
        # resume mid-migration: rebuild the frozen old table + reserve
        m.state = B.HashMapState(**{k: jnp.asarray(v)
                                    for k, v in old_host.items()})
        drained = sum(1 for b in range(frontier)
                      for _ in _iter_chain(old_host, b))
        m._mig = {
            "new": new, "cap_new": cap_new, "nb_new": nb_new,
            "bpr": hdr.buckets_per_round, "frontier": frontier,
            "n_rounds": n_rounds, "old_host": old_host,
            "remaining_live": int(old_host["live"].sum()) - drained,
            "migrated": 0, "skipped": 0,
        }
        m._journal = RoundJournal(m.io, d)
        m._journal.n_rounds = n_rounds       # resume the round numbering
        return m


def _iter_chain(old: dict, b: int):
    """Yield the live node ids of old bucket ``b`` in chain order."""
    node = int(old["head"][b])
    while node != _NIL:
        if old["live"][node]:
            yield node
        node = int(old["nxt"][node])
