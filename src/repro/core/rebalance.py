"""Live cross-shard rebalancing: re-split a sharded durable map under
routed user traffic.

:meth:`repro.core.sharded.ShardedDurableMap.rebalance` moves a live
map's bucket-range boundaries, but it is *blocking*: no user operation
can commit while its drain rounds run.  This module lifts the
single-device online-migration protocol (:mod:`repro.core.migrate`) to
the mesh, so a skewed load can be re-split while the map keeps serving —
the last sharding gap in the ROADMAP.

The protocol is the migration protocol, shard-aware:

* **The old map is frozen.**  ``start_rebalance`` snapshots the current
  :class:`~repro.core.sharded.ShardedDurableMap` (one ``device_get``);
  from then on every user update commits into the *new* map only, routed
  by the **new** splits.  The frozen snapshot is a stable drain source
  for every round.
* **New is authoritative per key.**  Once a key has any node in the new
  map — live or dead — the new map's word is final; a dead node there
  means "deleted during the rebalance" and vetoes the old map's stale
  live copy.  Drains filter on :meth:`~ShardedDurableMap.probe`'s
  ``exists``, lookups compose both probes with
  :func:`repro.core.batched.merge_new_old` (new-then-old).
* **Drain rounds are ordinary routed updates.**  Each round drains a
  bounded contiguous *global* bucket range from the frozen snapshot
  (bucket-ascending, chain head→tail, live nodes only — the canonical
  order of :func:`repro.core.migrate.drain_range`) and inserts it into
  the new map through the existing all_to_all + per-shard plan/commit
  engine, so every migrated key pays O(1) flushes + 2 fences *in its new
  owner shard* and ``foreign_ops``/``bucket_flushes`` prove it.
* **User batches pull first.**  A user batch during the rebalance
  commits as one mixed ``[pull-inserts; user ops]`` round on the new
  map: each distinct user key live in the old map and node-less in the
  new is pulled over with its old value first, after which the user's
  inserts/deletes see exactly the merged map's liveness — identical
  semantics (ok flags, final content) to running the blocking rebalance
  first and the same batches after.
* **Every round is durable.**  With a ``root``, the
  :class:`RebalanceState` header, the frozen snapshot, and every round
  (drain *and* user) go through the shared
  :class:`repro.core.migrate.RoundJournal` (``reb_NNNN/``) with
  flush → fence → atomic publish.  A crash between rounds recovers by
  deterministic replay to *exactly* the pre- or post-round state —
  bit-identical arrays, never a torn mix — and the rebalance resumes
  from the recovered frontier.

:class:`AutoRebalancePolicy` closes the loop: the map accumulates the
per-bucket flush counters (``CommitStats.bucket_flushes``) every round,
and when the hottest shard's share of that load exceeds the policy
threshold, :func:`repro.launch.mesh.replan_splits` derives
load-quantile boundaries and a rebalance starts by itself — skewed
(zipf) streams re-split under live traffic with no operator call.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np

from . import batched as B
from ..obs.compile import get_tracker
from ..obs.metrics import get_registry
from .migrate import RoundJournal, drain_range
from .sharded import RebalanceReport, ShardedDurableMap


class RebalanceState(NamedTuple):
    """The durable rebalance header — small enough to publish atomically.

    Together with the frozen old-map snapshot and the journaled rounds it
    fully determines both maps: the engine is deterministic, so replay
    recovers bit-identical state.  ``frontier``/``n_rounds`` are
    snapshots as of the header's publish (0 at start; final values in
    the ``done`` header) — live progress is derived from the published
    round files on recovery, never from a stale header.

    >>> h = RebalanceState(phase="rebalancing", frontier=8, n_buckets=64,
    ...                    capacity_old=4096, capacity_new=4096,
    ...                    splits_old=(0, 32, 64), splits_new=(0, 8, 64),
    ...                    buckets_per_round=8, n_rounds=1)
    >>> RebalanceState.from_bytes(h.to_bytes()) == h
    True
    """
    phase: str                    # "rebalancing" | "done"
    frontier: int                 # global old-bucket drain frontier
    n_buckets: int
    capacity_old: int
    capacity_new: int
    splits_old: Tuple[int, ...]
    splits_new: Tuple[int, ...]
    buckets_per_round: int
    n_rounds: int                 # journaled rounds (drain + user)

    def to_bytes(self) -> bytes:
        return json.dumps(self._asdict(), sort_keys=True).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "RebalanceState":
        d = json.loads(b.decode())
        d["splits_old"] = tuple(d["splits_old"])
        d["splits_new"] = tuple(d["splits_new"])
        return RebalanceState(**d)


class AutoRebalancePolicy(NamedTuple):
    """When to re-split without an operator call.

    Every committed round's ``bucket_flushes`` accumulates into the
    map's per-global-bucket load counters; every ``check_every``-th
    steady-state update the policy evaluates them.  A rebalance starts
    when at least ``min_load`` flushes have accumulated since the last
    rebalance AND the hottest shard carries more than ``threshold`` ×
    the mean per-shard load AND the load-quantile re-plan
    (:func:`repro.launch.mesh.replan_splits`) actually moves a boundary
    (a single ultra-hot bucket cannot be split further — the re-plan
    reproducing the current boundaries suppresses the trigger instead of
    thrashing)."""
    threshold: float = 1.5
    min_load: int = 2048
    check_every: int = 4
    buckets_per_round: Optional[int] = None


def _pending_per_shard(shard_host, splits_old, frontier: int,
                       new_map: ShardedDurableMap) -> np.ndarray:
    """Per-*new*-shard count of live old keys not yet drained (global
    bucket ≥ ``frontier``) — the allocation reserve the fits check holds
    against user traffic so the remaining drains can never overflow."""
    remaining = np.zeros(new_map.n_shards, np.int64)
    for s, (a0, b0) in enumerate(zip(splits_old, splits_old[1:])):
        a = max(frontier, a0)
        if a >= b0:
            continue
        ks, _ = drain_range(shard_host[s], a - a0, b0 - a0)
        if ks.size:
            remaining += np.bincount(new_map.owners_of(ks),
                                     minlength=new_map.n_shards)
    return remaining


class RebalancingShardedMap:
    """A :class:`~repro.core.sharded.ShardedDurableMap` that re-splits
    its bucket ranges *under live traffic* — and, given a policy, by
    itself.

    Steady state it is a thin wrapper (same
    ``update``/``insert``/``delete``/``lookup``/``probe`` contracts).
    During a rebalance, user batches route by the **new** splits and
    commit pull-first into the new map, lookups are new-then-old, and
    every ``update()`` first advances ``rounds_per_update`` drain
    rounds, amortizing the re-split over traffic exactly as
    :class:`repro.core.migrate.MigratingMap` amortizes growth.

    On completion the new map is adopted as-is — the same contract as
    the blocking :meth:`~ShardedDurableMap.rebalance` (the frozen old
    map's flush/fence counters are dropped with it), so a quiescent
    live rebalance is state-identical to the blocking one.  ``root``
    makes the rebalance window durable: header + snapshot + every round
    journaled via :class:`repro.core.migrate.RoundJournal`, and
    :meth:`recover` rebuilds bit-identical state from a crash between
    rounds and resumes from the frontier.
    """

    def __init__(self, n_shards: Optional[int] = None, *,
                 capacity: int = 1 << 16, n_buckets: int = 1024,
                 mesh=None, splits: Optional[Sequence[int]] = None,
                 root=None, seed: int = 0,
                 buckets_per_round: Optional[int] = None,
                 rounds_per_update: int = 1,
                 policy: Optional[AutoRebalancePolicy] = None):
        self.map = ShardedDurableMap(n_shards, capacity=capacity,
                                     n_buckets=n_buckets, mesh=mesh,
                                     splits=splits)
        self.buckets_per_round = buckets_per_round
        self.rounds_per_update = rounds_per_update
        self.policy = policy
        self.io = None
        if root is not None:
            from ..persistence.manifest import StagedIO
            self.io = StagedIO(Path(root), seed=seed)
        self._reb = None            # in-flight rebalance bookkeeping
        self._journal = None        # RoundJournal of the in-flight window
        self._reb_seq = 0           # completed+started rebalances (dir)
        self._updates_since_check = 0
        # per-global-bucket flush load since the last rebalance — what
        # the auto policy (and replan_splits) read
        self.loads = np.zeros(n_buckets, np.int64)
        self.rebalances_completed = 0
        self.rounds_total = 0       # drain rounds across all rebalances
        self.migrated_total = 0
        self.pulls_total = 0
        self.last_report: Optional[RebalanceReport] = None
        self.last_trigger_imbalance: Optional[float] = None

    # ---------------- pass-through geometry --------------------------- #
    @property
    def n_shards(self) -> int:
        return self.map.n_shards

    @property
    def n_buckets(self) -> int:
        return self.map.n_buckets

    @property
    def splits(self) -> Tuple[int, ...]:
        """The *authoritative* boundaries — the new splits as soon as a
        rebalance opens (ops route by them from that moment on)."""
        return (self._reb["new"] if self._reb else self.map).splits

    @property
    def capacity(self) -> int:
        return (self._reb["new"] if self._reb else self.map).capacity

    @property
    def cap_local(self) -> int:
        return (self._reb["new"] if self._reb else self.map).cap_local

    @property
    def state(self):
        """The authoritative map's :class:`~repro.core.sharded.ShardedState`
        (the destination map's, while a rebalance is draining into it)."""
        return (self._reb["new"] if self._reb else self.map).state

    @property
    def rebalancing(self) -> bool:
        return self._reb is not None

    @property
    def frontier(self) -> Optional[int]:
        return None if self._reb is None else self._reb["frontier"]

    @property
    def cursors(self) -> np.ndarray:
        """Guaranteed-upper-bound per-shard pool usage: the serving
        map's cursors, plus — during a rebalance — the un-drained live
        keys still owed to each new shard (the drain reserve)."""
        if self._reb is None:
            return self.map.cursors
        return self._reb["new"].cursors + self._reb["remaining"]

    @property
    def flushes(self) -> int:
        f = self.map.flushes
        if self._reb is not None:
            f += self._reb["new"].flushes
        return f

    @property
    def fences(self) -> int:
        f = self.map.fences
        if self._reb is not None:
            f += self._reb["new"].fences
        return f

    def owners_of(self, ks) -> np.ndarray:
        """Owner shards under the authoritative (new-first) split."""
        return (self._reb["new"] if self._reb else self.map).owners_of(ks)

    def fresh_demand(self, ks) -> np.ndarray:
        """Per-shard allocation demand of a batch of distinct insert
        keys, *beyond* what :attr:`cursors`' drain reserve already
        holds.  Mid-rebalance a key allocates in the new map unless it
        already has a node there OR is live in the old map (then the
        reserve covers its pull/drain) — in particular a key whose only
        node is a *dead* one in the frozen old map does allocate; the
        merged ``probe``'s ``exists`` would wrongly exclude it."""
        if self._reb is None:
            return self.map.fresh_demand(ks)
        ks = np.asarray(ks, np.int32)
        new = self._reb["new"]
        ex_new, _, _ = new.probe(ks)
        _, live_old, _ = self.map.probe(ks)
        covered = ex_new | live_old
        return np.bincount(new.owners_of(ks[~covered]),
                           minlength=self.n_shards).astype(np.int64)

    def chain_stats(self) -> Tuple[int, float]:
        """Chain shape of the authoritative map (the destination layout
        while a rebalance is draining into it)."""
        return (self._reb["new"] if self._reb else self.map).chain_stats()

    def items(self) -> dict:
        """Abstract content ``{key: (live, val)}``, new-authoritative."""
        out = self.map.items()
        if self._reb is not None:
            out.update(self._reb["new"].items())
        return out

    # ---------------- op API ------------------------------------------- #
    def update(self, ops, ks, vs):
        """One mixed round in batch order, identical results to a single
        merged map of unchanged capacity; advances ``rounds_per_update``
        drain rounds first while a rebalance is in flight, and — with a
        policy — opens one when the load counters say so.  Returns
        ``(ok, ShardCommitStats)`` exactly like the plain sharded map."""
        ops = np.asarray(ops, np.int32)
        ks = np.asarray(ks, np.int32)
        vs = np.asarray(vs, np.int32)
        if self._reb is None:
            self._maybe_trigger()
        if self._reb is None:
            ok, stats = self.map.update(ops, ks, vs)
            self._note(stats)
            return ok, stats
        for _ in range(self.rounds_per_update):
            if self._reb is not None:
                self.rebalance_round()
        if self._reb is None:
            return self.update(ops, ks, vs)     # finished mid-call
        return self._commit_rebalancing(ops, ks, vs)

    def insert(self, ks, vs):
        ks = np.asarray(ks, np.int32)
        return self.update(np.full(ks.shape, B.OP_INSERT, np.int32),
                           ks, vs)

    def delete(self, ks):
        ks = np.asarray(ks, np.int32)
        return self.update(np.full(ks.shape, B.OP_DELETE, np.int32),
                           ks, np.zeros_like(ks))

    def probe(self, ks):
        """Merged node-level probe ``(exists, live, vals)`` — the new
        map's node (live or dead) shadows the old map's."""
        if self._reb is None:
            return self.map.probe(ks)
        ex_n, live_n, val_n = self._reb["new"].probe(ks)
        ex_o, live_o, val_o = self.map.probe(ks)
        return (ex_n | ex_o, np.where(ex_n, live_n, live_o),
                np.where(ex_n, val_n, val_o).astype(np.int32))

    def lookup(self, ks):
        """New-then-old batched lookup (zero persistence work); exactly
        :func:`repro.core.batched.lookup`'s contract."""
        if self._reb is None:
            return self.map.lookup(ks)
        ex_n, live_n, val_n = self._reb["new"].probe(ks)
        _, live_o, val_o = self.map.probe(ks)
        return B.merge_new_old(ex_n, live_n, val_n, live_o, val_o)

    # ---------------- the auto policy ---------------------------------- #
    def _note(self, stats) -> None:
        if stats is None:
            return
        self.loads += np.asarray(stats.bucket_flushes, np.int64)
        self._updates_since_check += 1
        # NVTrace gauges, from the same numbers the auto policy reads:
        # per-shard accumulated flush load and the hottest-shard ratio
        per = np.add.reduceat(self.loads, np.asarray(self.splits[:-1]))
        total = float(per.sum())
        m = get_registry()
        for s, v in enumerate(per):
            m.gauge("map_shard_load", shard=str(s)).set(float(v))
        if total > 0:
            m.gauge("map_load_imbalance").set(
                float(per.max()) / (total / len(per)))

    def _maybe_trigger(self) -> None:
        p = self.policy
        if p is None or self._updates_since_check < p.check_every:
            return
        self._updates_since_check = 0
        if int(self.loads.sum()) < p.min_load:
            return
        from ..launch.mesh import replan_splits
        new_splits, imbalance = replan_splits(
            self.map.splits, self.loads, threshold=p.threshold)
        if new_splits is None:
            return
        try:
            self.start_rebalance(new_splits,
                                 buckets_per_round=p.buckets_per_round)
        except ValueError:
            # flush load ≠ live-key placement: the quantile plan can
            # pack more live keys into one new shard than its pool
            # holds.  The auto path must never crash a user update —
            # decline, and re-plan only after fresh load accumulates
            # (an explicit start_rebalance still raises).
            self.loads[:] = 0
            get_registry().counter("map_rebalance_declined_total").inc()
            return
        self.last_trigger_imbalance = imbalance
        get_registry().gauge("map_trigger_imbalance").set(imbalance)

    def imbalance(self) -> float:
        """Hottest shard's share of the accumulated load, normalized so
        1.0 is perfect balance (what the policy thresholds)."""
        from ..launch.mesh import replan_splits
        return replan_splits(self.splits, self.loads,
                             threshold=float("inf"))[1]

    # ---------------- rebalance control -------------------------------- #
    def start_rebalance(self, splits: Sequence[int], *,
                        capacity: Optional[int] = None,
                        buckets_per_round: Optional[int] = None) -> None:
        """Freeze the current map as the drain source, open an empty map
        on the new boundaries, and durably publish the
        :class:`RebalanceState` header (phase=rebalancing, frontier=0)
        plus the frozen snapshot."""
        if self._reb is not None:
            raise RuntimeError("rebalance already in flight")
        new = ShardedDurableMap(
            self.map.n_shards, capacity=capacity or self.map.capacity,
            n_buckets=self.map.n_buckets, mesh=self.map.mesh,
            splits=splits)
        host = jax.device_get(self.map.state)
        shard_host = [{f: np.asarray(getattr(host, f)[s])
                       for f in host._fields}
                      for s in range(self.map.n_shards)]
        remaining = _pending_per_shard(shard_host, self.map.splits, 0, new)
        if not bool((1 + remaining <= new.cap_local).all()):
            raise ValueError(
                f"splits {tuple(splits)} cannot hold the live content: "
                f"per-shard demand {remaining.tolist()} vs per-shard "
                f"pool {new.cap_local - 1}")
        bpr = (buckets_per_round or self.buckets_per_round
               or max(1, self.map.n_buckets // 8))
        self._reb = {
            "new": new, "frontier": 0, "bpr": bpr, "n_rounds": 0,
            "drain_rounds": 0, "shard_host": shard_host,
            "remaining": remaining, "migrated": 0, "skipped": 0,
            "foreign": 0, "bf": np.zeros(self.map.n_buckets, np.int64),
            "splits_old": self.map.splits,
            "chain_before": self.map.chain_stats(),
        }
        self._reb_seq += 1
        if self.io is not None:
            self._journal = RoundJournal(self.io, self._reb_dir())
            self._journal.write_snapshot(
                {f: np.asarray(getattr(host, f)) for f in host._fields})
            self._publish_header("rebalancing")

    def _reb_dir(self) -> str:
        return f"reb_{self._reb_seq:04d}"

    def _header(self, phase: str) -> RebalanceState:
        r = self._reb
        return RebalanceState(
            phase=phase, frontier=r["frontier"],
            n_buckets=self.map.n_buckets,
            capacity_old=self.map.capacity,
            capacity_new=r["new"].capacity,
            splits_old=r["splits_old"], splits_new=r["new"].splits,
            buckets_per_round=r["bpr"], n_rounds=r["n_rounds"])

    def _publish_header(self, phase: str) -> None:
        self._journal.publish_header(self._header(phase).to_bytes())

    def _journal_round(self, ops, ks, vs, frontier_after: int) -> None:
        r = self._reb
        if self._journal is None:
            r["n_rounds"] += 1
            return
        self._journal.append(ops=ops, ks=ks, vs=vs,
                             frontier=np.int32(frontier_after))
        r["n_rounds"] = self._journal.n_rounds

    def rebalance_round(self) -> bool:
        """Drain the next ``buckets_per_round`` *global* old buckets into
        the new map as one routed insert round, journal it, and advance
        the frontier.  Returns True when the rebalance completed (the
        last round also adopts the new map)."""
        r = self._reb
        assert r is not None, "no rebalance in flight"
        nb = self.map.n_buckets
        lo, hi = r["frontier"], min(r["frontier"] + r["bpr"], nb)
        parts = []
        for s in range(self.map.n_shards):   # split order = bucket-asc
            a = max(lo, r["splits_old"][s])
            b = min(hi, r["splits_old"][s + 1])
            if a < b:
                parts.append(drain_range(
                    r["shard_host"][s], a - r["splits_old"][s],
                    b - r["splits_old"][s]))
        ks = (np.concatenate([p[0] for p in parts]) if parts
              else np.zeros(0, np.int32))
        vs = (np.concatenate([p[1] for p in parts]) if parts
              else np.zeros(0, np.int32))
        n_cand = int(ks.size)
        if n_cand:
            r["remaining"] -= np.bincount(
                r["new"].owners_of(ks), minlength=self.map.n_shards)
            # new-authoritative filter: keys user traffic already pulled
            # (or re-inserted, or deleted) must not be re-migrated
            with get_tracker().reason("resplit_width_change"):
                ex, _, _ = r["new"].probe(ks)
            ks, vs = ks[~ex], vs[~ex]
        ops = np.zeros(ks.size, np.int32)          # all OP_INSERT
        if ks.size:
            with get_tracker().reason("resplit_width_change"):
                ok, stats = r["new"].insert(ks, vs)
            if not ok.all():   # not assert: must survive python -O too
                raise RuntimeError(
                    f"rebalance drain dropped keys at global bucket "
                    f"{lo} (reserve accounting bug)")
            r["foreign"] += int(np.sum(np.asarray(stats.foreign_ops)))
            r["bf"] += np.asarray(stats.bucket_flushes)
        self._journal_round(ops, ks, vs, hi)
        r["frontier"] = hi
        r["drain_rounds"] += 1
        r["migrated"] += int(ks.size)
        r["skipped"] += n_cand - int(ks.size)
        self.rounds_total += 1     # per-instance shims; registry mirror:
        self.migrated_total += int(ks.size)
        get_registry().counter("map_rebalance_rounds_total").inc()
        get_registry().counter("map_rebalanced_keys_total").inc(
            int(ks.size))
        if hi >= nb:
            self._finish()
            return True
        return False

    def run_rebalance(self) -> RebalanceReport:
        """Drive the in-flight rebalance to completion (blocking)."""
        assert self._reb is not None
        while self._reb is not None:
            self.rebalance_round()
        return self.last_report

    def _finish(self) -> None:
        r = self._reb
        if self._journal is not None:
            self._publish_header("done")
            if self._reb_seq > 1:      # previous window's journal is
                self.io.remove_tree(   # superseded: bound disk growth
                    f"reb_{self._reb_seq - 1:04d}")
        self.last_report = RebalanceReport(
            rounds=r["drain_rounds"], migrated=r["migrated"],
            foreign_ops=r["foreign"],
            bucket_flushes=r["bf"].astype(np.int32),
            splits_old=r["splits_old"], splits_new=r["new"].splits,
            chain_before=r["chain_before"],
            chain_after=r["new"].chain_stats())
        self.map = r["new"]
        self._reb = None
        self._journal = None
        # the trigger measures post-rebalance traffic only: stale skew
        # must not immediately re-fire against the corrected boundaries
        self.loads[:] = 0
        self._updates_since_check = 0
        self.rebalances_completed += 1   # shim; registry mirror:
        get_registry().counter("map_rebalances_total").inc()

    def _commit_rebalancing(self, ops, ks, vs):
        """Commit a user batch into the new map as one mixed routed
        round of ``[pull-inserts; user ops]`` (pull-first, see module
        docstring)."""
        r = self._reb
        new = r["new"]
        uniq = np.unique(ks)
        with get_tracker().reason("resplit_width_change"):
            ex_new, _, _ = new.probe(uniq)
        cand = uniq[~ex_new]
        _, live_old, val_old = self.map.probe(cand)
        pull_ks = cand[live_old]
        pull_vs = val_old[live_old].astype(np.int32)
        # exact per-shard reserve check: every pull and every fresh user
        # insert allocates at worst one node in its owner shard; the
        # un-drained remainder must still fit behind them
        fresh_cand = cand[~live_old]
        fresh_user = np.unique(ks[ops == B.OP_INSERT])
        fresh_user = fresh_user[np.isin(fresh_user, fresh_cand,
                                        assume_unique=True)]
        alloc_ks = np.concatenate([pull_ks, fresh_user])
        demand = (np.bincount(new.owners_of(alloc_ks),
                              minlength=self.map.n_shards)
                  if alloc_ks.size else np.zeros(self.map.n_shards,
                                                 np.int64))
        if not bool((new.cursors + demand + r["remaining"]
                     <= new.cap_local).all()):
            # this batch plus the un-drained remainder cannot fit the
            # new pools: finish now (the reserve guarantees the drains
            # fit) and commit against the adopted map
            self.run_rebalance()
            return self.update(ops, ks, vs)
        bops = np.concatenate(
            [np.full(pull_ks.size, B.OP_INSERT, np.int32), ops])
        bks = np.concatenate([pull_ks, ks])
        bvs = np.concatenate([pull_vs, vs])
        if bks.size == 0:
            return np.zeros(0, np.bool_), None
        with get_tracker().reason("resplit_width_change"):
            ok, stats = new.update(bops, bks, bvs)
        if not ok[:pull_ks.size].all():  # not assert: survive python -O
            raise RuntimeError("rebalance pull dropped keys "
                               "(reserve accounting bug)")
        r["foreign"] += int(np.sum(np.asarray(stats.foreign_ops)))
        r["bf"] += np.asarray(stats.bucket_flushes)
        self._journal_round(bops, bks, bvs, r["frontier"])
        self.pulls_total += int(pull_ks.size)   # shim; registry mirror:
        get_registry().counter("map_pulls_total").inc(int(pull_ks.size))
        self._note(stats)
        return ok[pull_ks.size:], stats

    # ---------------- growth (for the index backend) ------------------- #
    def grow_to(self, *, capacity: Optional[int] = None,
                n_buckets: Optional[int] = None) -> RebalanceReport:
        """Capacity/bucket growth: finish any in-flight rebalance, then
        migrate through the blocking mesh path
        (:meth:`~ShardedDurableMap.migrate_to`, splits scaled by its
        rules) and adopt the grown map in place.  The load counters
        reset — they are per-bucket and the bucket space may change."""
        if self._reb is not None:
            self.run_rebalance()
        self.map, report = self.map.migrate_to(capacity=capacity,
                                               n_buckets=n_buckets)
        self.loads = np.zeros(self.map.n_buckets, np.int64)
        self._updates_since_check = 0
        self.last_report = report
        return report

    # ---------------- crash recovery ----------------------------------- #
    def crash(self, evict: str = "none", p_evict: float = 0.5) -> None:
        """Simulate a process kill: the staging area is lost (unfenced
        journal bytes with it) and the in-memory maps are dropped.
        ``evict`` selects the shared implicit-eviction adversary
        (:func:`repro.core.pmem.evicted_mask`) over the staged journal
        files.  Use :meth:`recover` on the same root afterwards."""
        assert self.io is not None, "crash() needs a durable root"
        self.io.crash(evict=evict, p_evict=p_evict)
        self.map = None
        self._reb = None
        self._journal = None

    @classmethod
    def recover(cls, root, n_shards: Optional[int] = None, *,
                mesh=None, seed: int = 0, rounds_per_update: int = 1,
                policy: Optional[AutoRebalancePolicy] = None
                ) -> "RebalancingShardedMap":
        """Rebuild from the newest rebalance journal: restore the frozen
        old map from the snapshot, replay the published rounds in order
        through the routed engine (deterministic → bit-identical), and
        resume from the recovered frontier.  A ``done`` header recovers
        the completed re-split map."""
        root = Path(root)
        d = RoundJournal.newest_dir(root, "reb")
        if d is None:
            raise FileNotFoundError(
                f"no published rebalance journal under {root}")
        hdr_bytes, snap, rounds = RoundJournal.read(root, d)
        hdr = RebalanceState.from_bytes(hdr_bytes)
        m = cls(n_shards, capacity=hdr.capacity_old,
                n_buckets=hdr.n_buckets, mesh=mesh,
                splits=hdr.splits_old, root=root, seed=seed,
                rounds_per_update=rounds_per_update, policy=policy)
        m._reb_seq = int(d.split("_")[1])
        m.map.load_state(snap)
        new = ShardedDurableMap(
            m.map.n_shards, capacity=hdr.capacity_new,
            n_buckets=hdr.n_buckets, mesh=m.map.mesh,
            splits=hdr.splits_new)
        frontier = drain_rounds = migrated = foreign = 0
        bf = np.zeros(hdr.n_buckets, np.int64)
        for rec in rounds:
            if rec["ks"].size:
                _, stats = new.update(rec["ops"], rec["ks"], rec["vs"])
                foreign += int(np.sum(np.asarray(stats.foreign_ops)))
                bf += np.asarray(stats.bucket_flushes)
            f_after = int(rec["frontier"])
            if f_after > frontier:               # a drain round
                drain_rounds += 1
                migrated += int(rec["ks"].size)
                frontier = f_after
        if hdr.phase == "done":
            m.map = new
            m.rebalances_completed = 1
            return m
        shard_host = [{f: np.asarray(snap[f][s])
                       for f in ("key", "val", "nxt", "live", "head",
                                 "cursor", "flushes", "fences")}
                      for s in range(m.map.n_shards)]
        m._reb = {
            "new": new, "frontier": frontier,
            "bpr": hdr.buckets_per_round, "n_rounds": len(rounds),
            "drain_rounds": drain_rounds, "shard_host": shard_host,
            "remaining": _pending_per_shard(shard_host, hdr.splits_old,
                                            frontier, new),
            "migrated": migrated, "skipped": 0, "foreign": foreign,
            "bf": bf, "splits_old": hdr.splits_old,
            "chain_before": m.map.chain_stats(),
        }
        m._journal = RoundJournal(m.io, d)
        m._journal.n_rounds = len(rounds)    # resume round numbering
        return m
