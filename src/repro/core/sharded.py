"""Sharded durable map: bucket-range partitioning of the plan/commit engine.

The NVTraverse split is naturally shard-local.  The *plan* phase (the
journey) is embarrassingly parallel — it reads a snapshot and does zero
persistence work — and the *commit* phase (the destination) only ever
touches one bucket chain, so partitioning the node pool and the bucket
heads by **bucket range** keeps every flush and fence inside the shard
that owns the bucket.  Nothing crosses a shard boundary at commit time;
recovery is per-shard independent.

Layout (``ShardedState``): the single-device :class:`HashMapState` gains
a leading shard axis.  Shard ``s`` of ``S`` owns global buckets
``[s·nb_local, (s+1)·nb_local)`` where ``nb_local = n_buckets / S``, and
a private node pool with its own bump cursor.  Because ``nb_local``
divides ``n_buckets``, the local bucket of a key equals its global
bucket mod ``nb_local`` — the unmodified single-device engine
(:func:`repro.core.batched.update_parallel` with ``n_buckets=nb_local``)
places every key in the *same global bucket* it would occupy unsharded,
so the gathered sharded map is a bucket-permutation-equivalent of the
single-device map (identical per-key values and liveness; node ids
differ only by per-shard allocation order).

Routing: ops enter data-parallel (each shard holds a contiguous slice of
the batch), are grouped by owner shard (``owner = global_bucket //
nb_local``) with a stable sort so batch order survives inside each
group, and are exchanged with one ``all_to_all`` whose per-(src, dst)
block is padded to the slice length — static shapes, no host round-trip.
The flattened receive buffer is src-major, i.e. *global batch order*, so
each shard's local plan/commit round composes duplicate-key ops exactly
as the single-device engine would; padding slots ride along as
``valid=False`` ops, which the engine treats as fully transparent.

Accounting: per-shard ``CommitStats`` come back stacked
(:class:`ShardCommitStats`) so the O(1)-flushes / 2-fences-per-update
law still holds globally — per-op flush/fence sums equal the
single-device engine's bit for bit, and the coalesced batch cost is
``2 × max over shards of the largest same-bucket conflict group``
(shards fence concurrently).  ``bucket_flushes`` is the locality proof:
stacked to a global array it must be nonzero only inside each shard's
own range, and ``foreign_ops`` counts ops a shard received for buckets
outside its range (always 0 unless routing is broken).

Re-splittable ranges (the migration layer): ``splits`` generalizes the
even partition to *arbitrary* contiguous boundaries — shard ``s`` owns
global buckets ``[splits[s], splits[s+1])`` — by handing the engine the
range base (``update_parallel(..., nb_global=n_buckets, base=…)``), so
a key's local bucket is ``global_bucket - base`` instead of the mod
trick.  :meth:`ShardedDurableMap.rebalance` re-splits a live map under
a skewed load: it opens a fresh map on the new boundaries and drains
the old one into it in bounded global-bucket-range rounds — each round
one ordinary routed ``update`` batch, so every migrated key commits
with the same O(1) flushes + 2 fences *in its new owner shard* and the
per-round ``bucket_flushes``/``foreign_ops`` counters prove it.
:meth:`ShardedDurableMap.migrate_to` is the general form (new capacity
and/or bucket count and/or boundaries) the membership index's growth
path runs on.
"""
from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from . import batched
from ..obs.compile import get_tracker
from ..obs.metrics import get_registry

AXIS = "shards"


class ShardedState(NamedTuple):
    """:class:`~repro.core.batched.HashMapState` with a leading shard
    axis; row ``s`` is shard ``s``'s private node pool + bucket heads."""
    key: jax.Array          # int32[S, cap_local]
    val: jax.Array          # int32[S, cap_local]
    nxt: jax.Array          # int32[S, cap_local]
    live: jax.Array         # bool[S, cap_local]
    head: jax.Array         # int32[S, nb_local]
    cursor: jax.Array       # int32[S]  per-shard bump allocator
    flushes: jax.Array      # int32[S]  per-shard persistence accounting
    fences: jax.Array       # int32[S]


class ShardCommitStats(NamedTuple):
    """Per-shard :class:`~repro.core.batched.CommitStats`, stacked.

    All fields except ``bucket_flushes`` are ``int32[S]`` (one entry per
    shard); ``bucket_flushes`` is the global ``int32[n_buckets]`` array
    (shard rows concatenated in bucket-range order, so index ``b`` *is*
    global bucket ``b``).  ``foreign_ops[s]`` counts valid ops shard
    ``s`` received whose global bucket is outside its own range — the
    routing invariant says it is always 0.
    """
    ops_committed: jax.Array
    conflict_groups: jax.Array
    max_group: jax.Array
    coalesced_flushes: jax.Array
    coalesced_fences: jax.Array
    foreign_ops: jax.Array
    bucket_flushes: jax.Array

    @property
    def total_ops_committed(self) -> int:
        return int(jnp.sum(self.ops_committed))

    @property
    def total_coalesced_flushes(self) -> int:
        return int(jnp.sum(self.coalesced_flushes))

    @property
    def global_coalesced_fences(self) -> int:
        """Shards commit concurrently, so their fences overlap: the batch
        needs ``2 × (largest same-bucket group on any shard)`` fences."""
        return int(jnp.max(self.coalesced_fences))


def _state_specs() -> ShardedState:
    two = P(AXIS, None)
    one = P(AXIS)
    return ShardedState(key=two, val=two, nxt=two, live=two, head=two,
                        cursor=one, flushes=one, fences=one)


def items_of_state(state: batched.HashMapState) -> dict:
    """``{key: (live, val)}`` over every allocated node of a
    single-device map — the engine allocates at most one node per key,
    so this is the map's abstract content (dead nodes included)."""
    st = jax.device_get(state)
    c = int(st.cursor)
    return {int(k): (bool(l), int(v))
            for k, l, v in zip(st.key[1:c], st.live[1:c], st.val[1:c])}


# --------------------------------------------------------------------- #
# shard-local bodies, compiled once per (mesh, n_shards, n_buckets)      #
# --------------------------------------------------------------------- #
def _route(owner: jax.Array, valid: jax.Array, S: int):
    """Send-buffer layout for one all-to-all: group this shard's ops by
    owner (stable sort, so batch order survives within each group) and
    place group ``d`` at block ``d`` of a ``[S, L0]`` buffer."""
    L0 = owner.shape[0]
    owner = jnp.where(valid, owner, 0)           # pads ride to shard 0
    sort_idx = jnp.argsort(owner)                # stable: ties keep order
    so = owner[sort_idx]
    counts = jnp.zeros(S, jnp.int32).at[owner].add(1)
    starts = jnp.cumsum(counts) - counts
    flat = so * L0 + (jnp.arange(L0, dtype=jnp.int32) - starts[so])
    return sort_idx, flat


def _a2a(x: jax.Array, S: int) -> jax.Array:
    """Exchange a ``[S·L0]`` or ``[S·L0, W]`` dest-major buffer; the
    result, flattened src-major, is this shard's slice of the batch in
    global order (block ``d`` of ``S·L0`` rows goes to shard ``d``)."""
    shp = x.shape
    return jax.lax.all_to_all(
        x.reshape(S, -1), AXIS, 0, 0, tiled=True).reshape(shp)


def _send_packed(fields, sort_idx, flat, S: int):
    """Route a whole op payload with ONE all_to_all: the fields stack as
    int32 columns of a ``[S·L0, W]`` buffer (one collective per commit
    round instead of one per field — the latency floor of a real
    multi-device deployment is per-collective, not per-byte)."""
    cols = jnp.stack([f.astype(jnp.int32) for f in fields], axis=1)
    buf = jnp.zeros((cols.shape[0] * S, cols.shape[1]), jnp.int32)
    recv = _a2a(buf.at[flat].set(cols[sort_idx]), S)
    return [recv[:, i] for i in range(len(fields))]


def _squeeze(state: ShardedState) -> batched.HashMapState:
    return batched.HashMapState(*(f[0] for f in state))


@lru_cache(maxsize=None)
def _build_fns(mesh, S: int, n_buckets: int, nb_max: int):
    """The jitted shard_map update/lookup closures for one map config —
    cached so every :class:`ShardedDurableMap` instance with the same
    (mesh, shards, buckets, max range width) shares compiles.  The split
    boundaries themselves are *traced operands* (``bounds`` replicated,
    ``base``/``size`` per-shard), so a rebalanced map re-uses the same
    compile."""

    def update_local(state, ops, ks, vs, valid, bounds, base, size):
        st = _squeeze(state)
        base_me, size_me = base[0], size[0]
        owner = (jnp.searchsorted(
            bounds, batched.bucket_of(ks, n_buckets), side="right")
            .astype(jnp.int32) - 1)
        sort_idx, flat = _route(owner, valid, S)
        r_ops, r_ks, r_vs, r_valid_i = _send_packed(
            [ops, ks, vs, valid], sort_idx, flat, S)
        r_valid = r_valid_i.astype(jnp.bool_)
        # routing invariant instrumentation: a shard must never be asked
        # to commit (flush/fence) a bucket outside its own range
        g = batched.bucket_of(r_ks, n_buckets) - base_me
        foreign = jnp.sum(
            r_valid & ((g < 0) | (g >= size_me))).astype(jnp.int32)
        st2, ok_r, stats = batched.update_parallel(
            st, r_ops, r_ks, r_vs, nb_max, valid=r_valid,
            nb_global=n_buckets, base=base_me)
        # hand each op's result back to the shard that holds its slot
        ok = jnp.zeros(ops.shape[0], jnp.bool_).at[sort_idx].set(
            _a2a(ok_r, S)[flat])
        sstats = ShardCommitStats(
            ops_committed=stats.ops_committed[None],
            conflict_groups=stats.conflict_groups[None],
            max_group=stats.max_group[None],
            coalesced_flushes=stats.coalesced_flushes[None],
            coalesced_fences=stats.coalesced_fences[None],
            foreign_ops=foreign[None],
            bucket_flushes=stats.bucket_flushes,
        )
        return ShardedState(*(f[None] for f in st2)), ok, sstats

    def lookup_local(state, ks, valid, bounds, base):
        st = _squeeze(state)
        owner = (jnp.searchsorted(
            bounds, batched.bucket_of(ks, n_buckets), side="right")
            .astype(jnp.int32) - 1)
        sort_idx, flat = _route(owner, valid, S)
        r_ks, = _send_packed([ks], sort_idx, flat, S)
        # probe, not lookup: exists (node present, live or dead) rides
        # along for free — the growth path's exact fits check needs it
        r_exists, r_live, r_vals = batched.probe(
            st, r_ks, nb_max, nb_global=n_buckets, base=base[0])
        # one packed collective for the answers too
        back = _a2a(jnp.stack([r_exists.astype(jnp.int32),
                               r_live.astype(jnp.int32), r_vals],
                              axis=1), S)[flat]
        n = ks.shape[0]
        exists = jnp.zeros(n, jnp.bool_).at[sort_idx].set(
            back[:, 0].astype(jnp.bool_))
        found = jnp.zeros(n, jnp.bool_).at[sort_idx].set(
            back[:, 1].astype(jnp.bool_))
        vals = jnp.zeros(n, jnp.int32).at[sort_idx].set(back[:, 2])
        return exists, found, vals

    sspec = _state_specs()
    ospec = ShardCommitStats(*([P(AXIS)] * 7))
    # check_rep=False: the chain-walk while_loop has no replication rule
    # in jax 0.4.37; every output here is explicitly sharded anyway.
    update_fn = jax.jit(shard_map(
        update_local, mesh=mesh,
        in_specs=(sspec, P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(None),
                  P(AXIS), P(AXIS)),
        out_specs=(sspec, P(AXIS), ospec), check_rep=False))
    lookup_fn = jax.jit(shard_map(
        lookup_local, mesh=mesh,
        in_specs=(sspec, P(AXIS), P(AXIS), P(None), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(AXIS)), check_rep=False))
    return update_fn, lookup_fn


class RebalanceReport(NamedTuple):
    """What a re-split / migration actually did — and the proof it kept
    persistence local to the *new* owner ranges."""
    rounds: int
    migrated: int               # live keys drained into the new map
    foreign_ops: int            # Σ over rounds/shards (must be 0)
    bucket_flushes: np.ndarray  # int32[n_buckets_new] Σ over rounds
    splits_old: Tuple[int, ...]
    splits_new: Tuple[int, ...]
    chain_before: Tuple[int, float]
    chain_after: Tuple[int, float]


def even_splits(n_buckets: int, n_shards: int) -> Tuple[int, ...]:
    """The default contiguous-range boundaries: ``n_shards`` equal
    ranges (requires divisibility, like the original static split).

    >>> even_splits(64, 4)
    (0, 16, 32, 48, 64)
    """
    if n_buckets % n_shards:
        raise ValueError(
            f"n_buckets={n_buckets} not divisible by n_shards={n_shards}"
            " (pass explicit splits= for uneven ranges)")
    w = n_buckets // n_shards
    return tuple(s * w for s in range(n_shards)) + (n_buckets,)


class ShardedDurableMap:
    """Bucket-range-sharded durable map running the plan/commit engine
    per shard under ``shard_map``.

    ``capacity`` is the *total* node budget (split evenly; each shard
    reserves its own null node 0, so the usable total is
    ``S·(ceil(capacity/S) - 1)``).  ``splits`` (optional, ``S+1``
    strictly increasing boundaries with ``splits[0]=0`` and
    ``splits[-1]=n_buckets``) assigns shard ``s`` the contiguous global
    bucket range ``[splits[s], splits[s+1])``; the default is the even
    partition (then ``n_buckets`` must be divisible by the shard
    count).  Requires ``n_shards`` jax devices — force host devices for
    CPU work with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    def __init__(self, n_shards: Optional[int] = None, *,
                 capacity: int = 1 << 16, n_buckets: int = 1024,
                 mesh=None, splits: Optional[Sequence[int]] = None):
        if mesh is None:
            from ..launch.mesh import make_map_mesh
            mesh = make_map_mesh(n_shards or jax.device_count())
        self.mesh = mesh
        self.n_shards = int(np.prod(list(mesh.shape.values())))
        if n_shards is not None and n_shards != self.n_shards:
            raise ValueError(
                f"n_shards={n_shards} does not match the given mesh "
                f"({self.n_shards} devices); pass one or the other")
        if splits is None:
            splits = even_splits(n_buckets, self.n_shards)
        self.splits = tuple(int(b) for b in splits)
        if (len(self.splits) != self.n_shards + 1
                or self.splits[0] != 0 or self.splits[-1] != n_buckets
                or any(a >= b for a, b in zip(self.splits,
                                              self.splits[1:]))):
            raise ValueError(
                f"splits={splits} must be {self.n_shards + 1} strictly "
                f"increasing boundaries from 0 to {n_buckets}")
        self.n_buckets = n_buckets
        self.sizes = tuple(b - a for a, b in zip(self.splits,
                                                 self.splits[1:]))
        self.nb_max = max(self.sizes)       # head width (ranges padded)
        self.nb_local = self.nb_max         # back-compat alias
        self.capacity = capacity
        self.cap_local = -(-capacity // self.n_shards)
        S, C, NBM = self.n_shards, self.cap_local, self.nb_max
        state = ShardedState(
            key=jnp.zeros((S, C), jnp.int32),
            val=jnp.zeros((S, C), jnp.int32),
            nxt=jnp.full((S, C), batched.NIL, jnp.int32),
            live=jnp.zeros((S, C), jnp.bool_),
            head=jnp.full((S, NBM), batched.NIL, jnp.int32),
            cursor=jnp.ones(S, jnp.int32),
            flushes=jnp.zeros(S, jnp.int32),
            fences=jnp.zeros(S, jnp.int32),
        )
        self.state = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(
                mesh, P(AXIS, *([None] * (x.ndim - 1))))), state)
        self._bounds = jnp.asarray(self.splits, jnp.int32)
        shard1 = NamedSharding(mesh, P(AXIS))
        self._base = jax.device_put(
            jnp.asarray(self.splits[:-1], jnp.int32), shard1)
        self._size = jax.device_put(
            jnp.asarray(self.sizes, jnp.int32), shard1)
        self._update_fn, self._lookup_fn = _build_fns(
            mesh, S, n_buckets, NBM)
        # NVTrace compile seam: a (mesh, S, n_buckets, nb_max) miss above
        # is only *built* here — the XLA compile stall lands on the first
        # call per argument-shape signature, which the tracker times and
        # attributes to the active reason (re-split width change,
        # capacity-ladder step, or "steady" cold start)
        trk = get_tracker()
        cfg = f"S={S},nb={n_buckets},nb_max={NBM}"
        self._update_fn = trk.instrument("sharded.update", cfg,
                                         self._update_fn)
        self._lookup_fn = trk.instrument("sharded.lookup", cfg,
                                         self._lookup_fn)
        self._metrics = get_registry()

    # ---------------- host API --------------------------------------- #
    def _pad(self, *arrs: np.ndarray):
        """Pad the batch so each shard's slice is the same power-of-two
        length (static all-to-all shapes, retraces capped at one per
        log2 size); pad slots are ``valid=False`` and fully transparent
        to the engine."""
        n = arrs[0].shape[0]
        per = -(-max(n, 1) // self.n_shards)
        per = 1 << (per - 1).bit_length()
        total = per * self.n_shards
        out = [jnp.asarray(np.concatenate(
            [a, np.zeros(total - n, a.dtype)])) for a in arrs]
        valid = jnp.asarray(np.arange(total) < n)
        return out, valid

    def update(self, ops, ks, vs) -> Tuple[np.ndarray, ShardCommitStats]:
        """One mixed plan/commit round over the whole map: route each op
        to its owner shard, commit per shard, return per-op ``ok`` in
        batch order plus the stacked per-shard stats (``bucket_flushes``
        re-assembled on the global bucket axis from the per-range rows)."""
        ops = np.asarray(ops, np.int32)
        ks = np.asarray(ks, np.int32)
        vs = np.asarray(vs, np.int32)
        n = ks.shape[0]
        if n == 0:
            return np.zeros(0, np.bool_), None
        (ops_p, ks_p, vs_p), valid = self._pad(ops, ks, vs)
        self.state, ok, stats = self._update_fn(
            self.state, ops_p, ks_p, vs_p, valid,
            self._bounds, self._base, self._size)
        bf = np.asarray(stats.bucket_flushes).reshape(
            self.n_shards, self.nb_max)
        stats = stats._replace(bucket_flushes=np.concatenate(
            [bf[s, :w] for s, w in enumerate(self.sizes)]))
        self._export_stats(stats)
        return np.asarray(ok)[:n], stats

    def _export_stats(self, stats: ShardCommitStats) -> None:
        """Mirror one round's commit accounting onto the NVTrace
        registry (the satellite that gives `CommitStats` sums, foreign
        ops and per-shard load one read path): cumulative flush/fence
        totals, the routing invariant, and per-shard committed-op load."""
        m = self._metrics
        committed = np.asarray(stats.ops_committed)
        m.counter("map_commit_ops_total").inc(int(committed.sum()))
        m.counter("map_commit_flushes_total").inc(
            int(np.asarray(stats.coalesced_flushes).sum()))
        m.counter("map_commit_fences_total").inc(
            int(np.asarray(stats.coalesced_fences).max(initial=0)))
        m.counter("map_foreign_ops_total").inc(
            int(np.asarray(stats.foreign_ops).sum()))
        for s in range(self.n_shards):
            m.counter("map_shard_ops_total", shard=str(s)).inc(
                int(committed[s]))

    def owners_of(self, ks) -> np.ndarray:
        """Owner shard of each key under the current split (host-side
        routing twin — used by the index's exact per-shard fits check)."""
        b = batched.bucket_of_np(np.asarray(ks, np.int32), self.n_buckets)
        return (np.searchsorted(np.asarray(self.splits), b,
                                side="right") - 1).astype(np.int32)

    def insert(self, ks, vs):
        ks = np.asarray(ks, np.int32)
        return self.update(np.full(ks.shape, batched.OP_INSERT, np.int32),
                           ks, vs)

    def delete(self, ks):
        ks = np.asarray(ks, np.int32)
        return self.update(np.full(ks.shape, batched.OP_DELETE, np.int32),
                           ks, np.zeros_like(ks))

    def lookup(self, ks) -> Tuple[np.ndarray, np.ndarray]:
        """Batched lookup (the journey — no persistence work on any
        shard): returns ``(found bool[n], vals int32[n])``.  Exactly
        :func:`repro.core.batched.lookup`'s contract: a not-found key's
        val is 0, even when a dead node still holds its last value."""
        _, found, vals = self.probe(ks)
        return found, np.where(found, vals, 0).astype(np.int32)

    def probe(self, ks) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Node-level probe across shards (zero persistence work):
        ``(exists, live, vals)``, where ``exists`` is True iff the key
        holds a node at all — dead included.  The exact fit check of
        the index growth path keys off ``exists``: a removed member's
        node is resurrected in place, never re-allocated."""
        ks = np.asarray(ks, np.int32)
        n = ks.shape[0]
        if n == 0:
            z = np.zeros(0, np.bool_)
            return z, z, np.zeros(0, np.int32)
        (ks_p,), valid = self._pad(ks)
        exists, found, vals = self._lookup_fn(self.state, ks_p, valid,
                                              self._bounds, self._base)
        return (np.asarray(exists)[:n], np.asarray(found)[:n],
                np.asarray(vals)[:n])

    def items(self) -> dict:
        """Gathered abstract content ``{key: (live, val)}`` — the
        bucket-permutation-invariant view used by the state-identity
        checks against the single-device engine.  Keys are disjoint
        across shards (bucket ranges partition the hash space), so the
        union over per-shard views is exact."""
        st = jax.device_get(self.state)
        out = {}
        for s in range(self.n_shards):
            out.update(items_of_state(
                batched.HashMapState(*(f[s] for f in st))))
        return out

    @property
    def flushes(self) -> int:
        """Aggregate per-op flush accounting (sums the per-shard
        counters; equals the single-device engine's on the same ops)."""
        return int(np.sum(jax.device_get(self.state.flushes)))

    @property
    def fences(self) -> int:
        return int(np.sum(jax.device_get(self.state.fences)))

    @property
    def cursor_max(self) -> int:
        """Fullest shard's bump cursor — the growth trigger (a batch of
        fresh inserts could in the worst case all hash to one shard)."""
        return int(np.max(jax.device_get(self.state.cursor)))

    @property
    def cursors(self) -> np.ndarray:
        """Per-shard bump cursors (``int64[S]``) — the exact per-shard
        fits checks (index growth, live rebalance reserve) compare these
        against per-shard allocation demand."""
        return np.asarray(jax.device_get(self.state.cursor), np.int64)

    def fresh_demand(self, ks) -> np.ndarray:
        """Per-shard allocation demand (``int64[S]``) of a batch of
        distinct insert keys: only keys without a node (live or dead —
        a removed key's node is resurrected in place) allocate, each in
        its owner shard.  The exact half of the index growth check."""
        ks = np.asarray(ks, np.int32)
        exists, _, _ = self.probe(ks)
        return np.bincount(self.owners_of(ks[~exists]),
                           minlength=self.n_shards).astype(np.int64)

    def load_state(self, arrays: dict) -> None:
        """Adopt a host snapshot (field name → stacked ``[S, …]`` numpy
        array, as ``jax.device_get(self.state)`` produces) as this map's
        state, re-sharded onto the mesh — the rebalance journal's
        recovery path.  The arrays must match this map's geometry."""
        st = ShardedState(**{f: jnp.asarray(arrays[f])
                             for f in ShardedState._fields})
        self.state = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(
                self.mesh, P(AXIS, *([None] * (x.ndim - 1))))), st)

    def chain_stats(self) -> Tuple[int, float]:
        """Global (max, mean) chain length over all shards' buckets
        (each shard contributes only its *owned* range — the padding
        rows of an uneven split hold no chains and are excluded)."""
        st = jax.device_get(self.state)
        mx, total = 0, 0.0
        for s, w in enumerate(self.sizes):
            local = batched.HashMapState(*(f[s] for f in st))
            local = local._replace(head=local.head[:w])
            m, mean = batched.chain_stats(
                jax.tree_util.tree_map(jnp.asarray, local), w)
            mx = max(mx, int(m))
            total += float(mean) * w
        return mx, total / self.n_buckets

    # ---------------- migration over the mesh -------------------------- #
    def migrate_to(self, *, capacity: Optional[int] = None,
                   n_buckets: Optional[int] = None,
                   splits: Optional[Sequence[int]] = None,
                   buckets_per_round: Optional[int] = None,
                   ) -> Tuple["ShardedDurableMap", RebalanceReport]:
        """Drain this map into a fresh one — new boundaries and/or a
        larger pool and/or a different global bucket count — in bounded
        rounds of ``buckets_per_round`` *old* global buckets each.

        Every round is one ordinary routed ``update`` on the new map:
        the drained keys ride the same all_to_all to their new owner
        shards and commit through the unmodified plan/commit engine, so
        each migrated key pays O(1) flushes + 2 fences in its new owner
        range and nothing anywhere else — the per-round stats are summed
        into the report as the proof (``foreign_ops == 0``;
        ``bucket_flushes`` nonzero only where the new split says).
        Returns ``(new_map, report)``; the old map is left frozen (do
        not write it again)."""
        nb_new = n_buckets or self.n_buckets
        if splits is None:
            if nb_new == self.n_buckets:
                splits = self.splits
            elif nb_new % self.n_buckets == 0:
                # bucket-count growth keeps the split *shape*: scale the
                # boundaries so each shard keeps its share of the space
                f = nb_new // self.n_buckets
                splits = tuple(b * f for b in self.splits)
            else:
                # never silently fall back to the even partition: that
                # would undo a load-weighted rebalance behind the
                # caller's back (or fail on divisibility mid-migration)
                raise ValueError(
                    f"n_buckets={nb_new} is not a multiple of the "
                    f"current {self.n_buckets}; pass splits= explicitly "
                    f"to re-shape the ranges")
        # compile attribution: a geometry change here is what buys the
        # recompile — a capacity/bucket step is the ladder, a pure
        # boundary move is the re-split width change the ROADMAP taxes
        reason = ("capacity_ladder" if (capacity or n_buckets)
                  else "resplit_width_change")
        with get_tracker().reason(reason):
            new = ShardedDurableMap(
                self.n_shards, capacity=capacity or self.capacity,
                n_buckets=nb_new, mesh=self.mesh, splits=splits)
        bpr = buckets_per_round or max(1, self.n_buckets // 8)
        chain_before = self.chain_stats()
        host = jax.device_get(self.state)
        # per-shard host views in drain_range's dict form (one shared
        # chain-walk implementation with the single-device migration)
        from .migrate import drain_range
        shard_host = [{f: getattr(host, f)[s] for f in host._fields}
                      for s in range(self.n_shards)]
        rounds = migrated = foreign = 0
        bf_total = np.zeros(new.n_buckets, np.int64)
        with get_tracker().reason(reason):  # drain pays the first calls
            for lo in range(0, self.n_buckets, bpr):
                hi = min(lo + bpr, self.n_buckets)
                parts = []
                for s in range(self.n_shards):  # split order = global
                    a = max(lo, self.splits[s])  # bucket-ascending order
                    b = min(hi, self.splits[s + 1])
                    if a < b:
                        parts.append(drain_range(
                            shard_host[s], a - self.splits[s],
                            b - self.splits[s]))
                ks = np.concatenate([p[0] for p in parts])
                vs = np.concatenate([p[1] for p in parts])
                rounds += 1
                if not ks.size:
                    continue
                ok, stats = new.insert(ks, vs)
                if not ok.all():
                    raise RuntimeError(
                        f"rebalance drain overflowed the new pool at "
                        f"global bucket {lo} (capacity {new.capacity})")
                migrated += int(ks.size)
                foreign += int(np.sum(np.asarray(stats.foreign_ops)))
                bf_total += np.asarray(stats.bucket_flushes)
        m = get_registry()
        m.counter("map_drain_rounds_total").inc(rounds)
        m.counter("map_drained_keys_total").inc(migrated)
        return new, RebalanceReport(
            rounds=rounds, migrated=migrated, foreign_ops=foreign,
            bucket_flushes=bf_total.astype(np.int32),
            splits_old=self.splits, splits_new=new.splits,
            chain_before=chain_before, chain_after=new.chain_stats())

    def rebalance(self, splits: Sequence[int], *,
                  buckets_per_round: Optional[int] = None
                  ) -> RebalanceReport:
        """Re-split the bucket ranges in place: migrate every chain to
        its owner under the new boundaries (see :meth:`migrate_to`) and
        adopt the rebalanced state.  The public handle survives — only
        the split (and the node placement that proves it) changes."""
        new, report = self.migrate_to(splits=splits,
                                      buckets_per_round=buckets_per_round)
        self.__dict__.update(new.__dict__)
        return report
