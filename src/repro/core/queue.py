"""Lock-free FIFO queue in traversal form (Michael & Scott [35] lineage).

The paper (§3, Property 2) lists queues among traversal data structures:
the core tree is the chain from the head sentinel; the *tail pointer* is an
auxiliary entry point (volatile, reconstructed after a crash), used only by
``findEntry`` as a shortcut.  This is also the structure against which the
paper situates the only previously *proven* durable algorithm, the
DurableQueue of Friedman et al. [21].

  * enqueue: findEntry returns the volatile tail hint; traverse walks to
    the last node (stopping condition: next == NULL — a mutable field, as
    Property 4(2) allows); critical CASes last.next from NULL to the new
    node.  The queue demonstrates the **Supplement 2** variant: each node
    records its original parent (the pointer that linked it in), and
    ensureReachable flushes the location stored there.
  * dequeue: findEntry returns head; traverse reads the first node;
    critical *marks* it (logical dequeue, Definition 1) and then swings
    head.next (the unique disconnection, Property 5(2)).

Node layout: ``[value, next, orig_parent, _pad]``.
"""
from __future__ import annotations

from typing import List

from .instr import NULLPTR, OpContext, is_marked, pack, unpack, with_mark
from .pmem import PMem
from .traversal import TraversalDS, TraverseResult

VAL, NXT, OPAR = 0, 1, 2


class MSQueue(TraversalDS):
    NODE_WORDS = 4

    def __init__(self, mem: PMem):
        super().__init__(mem)
        self.head = mem.alloc(self.NODE_WORDS)
        mem.write(self.head + NXT, NULLPTR)
        mem.persist_all()
        self.tail_hint = self.head      # volatile auxiliary entry point

    # ------------------------------------------------------------------ #
    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        if op == "enqueue":
            return self.tail_hint       # may be stale; traverse walks on
        return self.head

    def traverse(self, ctx: OpContext, entry: int, op: str, args) -> TraverseResult:
        if op == "enqueue":
            curr = entry
            w = ctx.read(curr + NXT)
            while True:
                nxt, _ = unpack(w)
                if nxt == NULLPTR:
                    break
                curr = nxt
                w = ctx.read(curr + NXT)
            return TraverseResult(nodes=[curr], info=w)
        # dequeue / peek: head and its first successor
        hw = ctx.read(self.head + NXT)
        first, _ = unpack(hw)
        nodes = [self.head] if first == NULLPTR else [self.head, first]
        return TraverseResult(nodes=nodes, info=hw)

    def ensure_reachable_addrs(self, tr: TraverseResult) -> List[int]:
        first = tr.nodes[0]
        if first == self.head:
            return []                   # the root sentinel is always durable
        # Supplement 2: flush the location recorded in the original-parent
        # field (populated before the node was published).
        return [int(self.mem.volatile[first + OPAR])]

    def read_field_addrs(self, tr: TraverseResult) -> List[int]:
        return [n + NXT for n in tr.nodes]

    # ------------------------------------------------------------------ #
    def critical(self, ctx: OpContext, tr: TraverseResult, op: str, args):
        if op == "enqueue":
            last = tr.nodes[0]
            last_w = ctx.read(last + NXT)
            if unpack(last_w)[0] != NULLPTR or is_marked(last_w):
                return True, None       # tail moved (or node dequeued): retry
            new = ctx.alloc(self.NODE_WORDS)
            ctx.write_local(new + VAL, args[0])
            ctx.write_local(new + NXT, NULLPTR)
            ctx.write_local(new + OPAR, last + NXT)
            ok = ctx.cas(last + NXT, last_w, pack(new, 0))
            if ok:
                self.tail_hint = new    # volatile hint update
                return False, True
            return True, None
        if op == "dequeue":
            if len(tr.nodes) == 1:
                return False, None      # empty queue
            head, first = tr.nodes
            val = ctx.read(first + VAL, immutable=True)
            fw = ctx.read(first + NXT)
            if is_marked(fw):
                # help finish the pending dequeue, then retry
                hw = ctx.read(head + NXT)
                if unpack(hw)[0] == first:
                    ctx.cas(head + NXT, hw, pack(unpack(fw)[0], 0))
                return True, None
            if not ctx.cas(first + NXT, fw, with_mark(fw)):
                return True, None       # lost the race: retry
            # unique disconnection: swing head.next past the marked node
            ctx.cas(head + NXT, pack(first, 0), pack(unpack(fw)[0], 0))
            if self.tail_hint == first:
                self.tail_hint = self.head
            return False, val
        raise ValueError(op)

    # ------------------------------------------------------------------ #
    def disconnect(self) -> None:
        mem = self.mem
        while True:
            hw = int(mem.volatile[self.head + NXT])
            first, _ = unpack(hw)
            if first == NULLPTR:
                break
            fw = int(mem.volatile[first + NXT])
            if not is_marked(fw):
                break
            mem.cas(self.head + NXT, hw, pack(unpack(fw)[0], 0))
            mem.flush(self.head + NXT)
        mem.fence()
        # rebuild the volatile tail hint (auxiliary reconstruction)
        curr = self.head
        while True:
            nxt, _ = unpack(int(mem.volatile[curr + NXT]))
            if nxt == NULLPTR:
                break
            curr = nxt
        self.tail_hint = curr

    # ------------------------------------------------------------------ #
    def _walk(self, image) -> list:
        out = []
        curr, _ = unpack(int(image[self.head + NXT]))
        hops = 0
        while curr != NULLPTR:
            w = int(image[curr + NXT])
            if not is_marked(w):
                out.append(int(image[curr + VAL]))
            curr, _ = unpack(w)
            hops += 1
            assert hops < self.mem.capacity, "runaway queue walk"
        return out

    def contents(self) -> list:
        return self._walk(self.mem.volatile)

    def persistent_contents(self) -> list:
        return self._walk(self.mem.persistent)

    def check_integrity(self, *, require_unmarked: bool = False) -> None:
        image = self.mem.volatile
        curr, _ = unpack(int(image[self.head + NXT]))
        seen = set()
        marked_allowed = True           # only a prefix may be marked
        while curr != NULLPTR:
            assert curr not in seen, "cycle in queue"
            seen.add(curr)
            w = int(image[curr + NXT])
            if is_marked(w):
                assert marked_allowed, "marked node after live node"
                if require_unmarked:
                    raise AssertionError("marked node survived recovery")
            else:
                marked_allowed = False
            curr, _ = unpack(w)
