"""OrderedNVT: JAX-native batch-parallel durable *ordered* map.

The plan/commit split of :mod:`repro.core.batched`, lifted from the hash
map onto the paper's canonical traversal structure — a skiplist whose
**persistent core is only the sorted bottom-level list** (Property 2:
"only a linked list at the bottom level holds all the data, while the
rest of the nodes and edges simply serve as a way to access the linked
list faster").  Concretely:

  * the **bottom list** is a node-pool array structure (``key`` /
    ``val`` / ``nxt`` / ``live``) threaded in strictly ascending key
    order off a reserved head sentinel (node 0, key −∞).  Deletes are
    logical marks; nodes are never unlinked inside a batch — exactly the
    hash engine's crash model, so a crash mid-batch durably commits a
    *prefix* of the batch;
  * the **index towers are volatile**: a :class:`TowerIndex` of
    per-level sorted ``(key, addr)`` arrays whose promotion heights come
    from :func:`repro.core.skiplist.tower_heights` — the deterministic
    geometric(1/2) hash promotion of the seed skiplist — so the index
    rebuilt after a crash from the recovered bottom list is
    **bit-identical** to the pre-crash one (the optional Property 2
    reconstruction function, batch form);
  * *plan* (the journey): a ``vmap``-parallel descent of the towers plus
    a bottom-list walk locates every op's **predecessor** — the last
    physical node with key strictly below the op's key — against the
    pre-batch snapshot, with zero persistence accounting;
  * *commit* (the destination): duplicate-key conflicts are resolved by
    the same per-key liveness-composition segment scan as
    ``update_parallel`` (``ok = is_insert XOR prev_live``, snapshot
    seed, first successful insert of an absent key allocates, capacity
    failure kills the whole key group); the *conflict group* is the
    **predecessor node** instead of the hash bucket: all fresh nodes
    sharing a predecessor splice into one gap, linked in ascending key
    order — which reproduces, bit for bit, the chain the sequential
    scan oracle :func:`apply_ordered` leaves behind (node ids are
    assigned in batch order, links end up sorted);
  * per-op NVTraverse accounting is identical to the hash engine
    (fresh insert = flush(node), fence, publish CAS on ``pred.nxt``,
    flush(pred line), fence → 2 flushes + 2 fences; resurrect/delete =
    1 flush + 2 fences), and :class:`OrderedCommitStats` reports the
    coalesced batch cost — ``2 × (largest same-predecessor group)``
    fences, à la the bucket fence coalescing of the hash engine.

On top of the traversal ride the ordered primitives the hash map cannot
answer: :func:`range_query`, :func:`scan` (ordered prefix), and
:func:`top_k` — all journeys, zero persistence.

:class:`DurableOrderedMap` is the durable deployment surface: committed
batches are journaled through :class:`repro.persistence.manifest.
StagedIO` (write → flush → fence → atomic publish per round, snapshot +
trim for bounded restart), so the PR 6 :class:`~repro.robustness.
faultinject.CrashPlan` crash sites and the PR 7 PersistLint trace
checker apply to the ordered layer unmodified.

Pure host-side oracle (what every differential test checks against —
dict + ``sorted``, no engine code):

>>> items = {}
>>> oracle_apply(items, [0, 0, 1], [5, 3, 5], [50, 30, 0], capacity=8)
[True, True, True]
>>> sorted((k, lv) for k, (lv, _) in items.items())
[(3, True), (5, False)]
>>> oracle_range(items, 0, 9)
[(3, 30)]
"""
from __future__ import annotations

import json
from functools import partial
from pathlib import Path
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .batched import NIL, OP_DELETE, OP_INSERT
from .skiplist import tower_heights

KEY_MIN = -(2 ** 31)        # head-sentinel key (node 0): -inf
KEY_PAD = 2 ** 31 - 1       # tower padding: +inf.  Valid keys are in
                            # (KEY_MIN, KEY_PAD) — the int32 interior.
MAX_LEVEL = 8               # default tower height cap (seed skiplist's)


class OrderedState(NamedTuple):
    """The persistent bottom-level list (node pool + accounting)."""
    key: jax.Array          # int32[N] node keys (node 0: KEY_MIN sentinel)
    val: jax.Array          # int32[N] node values
    nxt: jax.Array          # int32[N] ascending-key chain (NIL = end)
    live: jax.Array         # bool[N]  logically present
    cursor: jax.Array       # int32    bump allocator (next free node id)
    flushes: jax.Array      # int32    persistence accounting (per-op law)
    fences: jax.Array


class TowerIndex(NamedTuple):
    """The volatile auxiliary index (Property 2): per level 2..max_level
    a sorted, KEY_PAD-padded array of the live keys promoted to that
    level and their node addresses.  Never persisted; rebuilt
    deterministically from the bottom list by :func:`build_towers`."""
    keys: jax.Array         # int32[levels, N] sorted keys (pad: KEY_PAD)
    addr: jax.Array         # int32[levels, N] node ids


class OrderedCommitStats(NamedTuple):
    """Coalesced batch cost at the destination, grouped by predecessor
    node (the ordered engine's conflict unit — the gap being spliced)."""
    ops_committed: jax.Array      # int32  ops that mutated state
    conflict_groups: jax.Array    # int32  predecessors with ≥1 commit
    max_group: jax.Array          # int32  largest same-pred group
    coalesced_flushes: jax.Array  # int32
    coalesced_fences: jax.Array   # int32  2 × max_group


def make_ordered(capacity: int) -> OrderedState:
    """Fresh empty ordered map.  Node 0 is the permanent head sentinel
    (key −∞, never live) — the same reserved-slot-0 convention as the
    hash engine, which doubles as the always-present predecessor."""
    return OrderedState(
        key=jnp.zeros(capacity, jnp.int32).at[0].set(KEY_MIN),
        val=jnp.zeros(capacity, jnp.int32),
        nxt=jnp.full(capacity, NIL, jnp.int32),
        live=jnp.zeros(capacity, jnp.bool_),
        cursor=jnp.int32(1),
        flushes=jnp.int32(0),
        fences=jnp.int32(0),
    )


# --------------------------------------------------------------------- #
# the volatile towers (Property 2's reconstruction function, batch form) #
# --------------------------------------------------------------------- #
def build_towers(state: OrderedState, max_level: int = MAX_LEVEL
                 ) -> TowerIndex:
    """Deterministic volatile index over the *live* keys of ``state``.

    Promotion heights are :func:`repro.core.skiplist.tower_heights` —
    the seed skiplist's geometric(1/2) key-hash promotion — so two
    calls on states with the same live set return bit-identical towers:
    the post-crash rebuild equals the pre-crash index, which is exactly
    what makes ordered crash tests deterministic."""
    ks = np.asarray(state.key)
    ids = np.nonzero(np.asarray(state.live))[0].astype(np.int32)
    order = np.argsort(ks[ids], kind="stable")
    sk, sid = ks[ids][order], ids[order]
    h = tower_heights(sk, max_level) if sk.size else np.zeros(0, np.int32)
    cap = int(state.key.shape[0])
    levels = max(1, max_level - 1)
    keys = np.full((levels, cap), KEY_PAD, np.int32)
    addr = np.zeros((levels, cap), np.int32)
    for lvl in range(2, max_level + 1):
        sel = h >= lvl
        m = int(sel.sum())
        keys[lvl - 2, :m] = sk[sel]
        addr[lvl - 2, :m] = sid[sel]
    return TowerIndex(keys=jnp.asarray(keys), addr=jnp.asarray(addr))


def _descend(tk: jax.Array, ta: jax.Array, k: jax.Array):
    """Tower descent (the journey's shortcut): the topmost level holding
    a key strictly below ``k`` hands over the closest such shortcut;
    lower levels only refine.  Falls back to the head sentinel."""
    entry = jnp.int32(0)
    ekey = jnp.int32(KEY_MIN)
    for lvl in range(tk.shape[0] - 1, -1, -1):
        i = jnp.searchsorted(tk[lvl], k, side="left") - 1
        j = jnp.maximum(i, 0)
        ck = tk[lvl][j]
        better = (i >= 0) & (ck > ekey)
        entry = jnp.where(better, ta[lvl][j], entry)
        ekey = jnp.where(better, ck, ekey)
    return entry


def _find_pred(state: OrderedState, tk, ta, k: jax.Array):
    """Walk from the tower entry to the last *physical* node (live or
    dead — deletes are logical) with key < k.  Zero persistence."""
    entry = _descend(tk, ta, k)

    def cond(pred):
        nx = state.nxt[pred]
        return (nx != NIL) & (state.key[nx] < k)

    def body(pred):
        return state.nxt[pred]

    return jax.lax.while_loop(cond, body, entry)


def _plan(state: OrderedState, tk, ta, ks: jax.Array):
    """The journey, batch-wide: every op's predecessor + existing node
    against the pre-batch snapshot, fully ``vmap``-parallel."""
    def one(k):
        pred = _find_pred(state, tk, ta, k)
        nx = state.nxt[pred]
        found = (nx != NIL) & (state.key[nx] == k)
        node = jnp.where(found, nx, NIL)
        return pred, node

    pred, node = jax.vmap(one)(ks)
    snap_live = (node != NIL) & state.live[node]
    return pred, node, snap_live


# --------------------------------------------------------------------- #
# traversal reads (zero persistence)                                     #
# --------------------------------------------------------------------- #
@jax.jit
def lookup_ordered(state: OrderedState, ks: jax.Array,
                   towers: Optional[TowerIndex] = None):
    """Batched ordered lookup: (found bool[B], vals int32[B])."""
    tk, ta = _tower_arrays(state, towers)
    pred, node, snap_live = _plan(state, tk, ta, ks.astype(jnp.int32))
    return snap_live, jnp.where(snap_live, state.val[node], 0)


def _tower_arrays(state: OrderedState, towers: Optional[TowerIndex]):
    if towers is None:
        cap = state.key.shape[0]
        return (jnp.full((1, cap), KEY_PAD, jnp.int32),
                jnp.zeros((1, cap), jnp.int32))
    return towers.keys, towers.addr


@partial(jax.jit, static_argnames="max_items")
def range_query(state: OrderedState, lo, hi, max_items: int,
                towers: Optional[TowerIndex] = None):
    """Ordered range read ``[lo, hi]`` (a pure journey): returns
    ``(total, keys int32[max_items], vals int32[max_items])`` — the
    first ``max_items`` live keys in ascending order plus the *total*
    live count in range (> ``max_items`` means the output is a
    truncated prefix).  Unused slots hold :data:`KEY_PAD`."""
    lo = jnp.asarray(lo, jnp.int32)
    hi = jnp.asarray(hi, jnp.int32)
    tk, ta = _tower_arrays(state, towers)
    pred = _find_pred(state, tk, ta, lo)

    def cond(c):
        node, *_ = c
        return (node != NIL) & (state.key[node] <= hi)

    def body(c):
        node, total, out_k, out_v = c
        ok = state.live[node]
        slot = jnp.where(ok & (total < max_items), total, max_items)
        out_k = out_k.at[slot].set(state.key[node], mode="drop")
        out_v = out_v.at[slot].set(state.val[node], mode="drop")
        return (state.nxt[node], total + ok.astype(jnp.int32),
                out_k, out_v)

    node0 = state.nxt[pred]
    total, out_k, out_v = jax.lax.while_loop(
        cond, body, (node0, jnp.int32(0),
                     jnp.full(max_items, KEY_PAD, jnp.int32),
                     jnp.zeros(max_items, jnp.int32)))[1:]
    return total, out_k, out_v


def scan(state: OrderedState, max_items: int,
         towers: Optional[TowerIndex] = None):
    """Full ordered scan (ascending): :func:`range_query` over the whole
    key interior."""
    return range_query(state, KEY_MIN + 1, KEY_PAD - 1, max_items,
                       towers)


@partial(jax.jit, static_argnames="k")
def top_k(state: OrderedState, k: int):
    """The ``k`` largest live keys, ascending — one bottom-list walk
    into a ring buffer (zero persistence).  Returns
    ``(count, keys int32[k], vals int32[k])`` with ``count =
    min(k, live)``; only the first ``count`` slots are meaningful."""
    def cond(c):
        node, *_ = c
        return node != NIL

    def body(c):
        node, i, bk, bv = c
        ok = state.live[node]
        slot = jnp.where(ok, i % k, k)
        bk = bk.at[slot].set(state.key[node], mode="drop")
        bv = bv.at[slot].set(state.val[node], mode="drop")
        return state.nxt[node], i + ok.astype(jnp.int32), bk, bv

    _, n_live, bk, bv = jax.lax.while_loop(
        cond, body, (state.nxt[jnp.int32(0)], jnp.int32(0),
                     jnp.full(k, KEY_PAD, jnp.int32),
                     jnp.zeros(k, jnp.int32)))
    shift = jnp.where(n_live >= k, n_live % k, 0)
    return (jnp.minimum(n_live, k), jnp.roll(bk, -shift),
            jnp.roll(bv, -shift))


# --------------------------------------------------------------------- #
# sequential scan oracle (the linearization reference)                   #
# --------------------------------------------------------------------- #
@jax.jit
def apply_ordered(state: OrderedState, ops: jax.Array, ks: jax.Array,
                  vs: jax.Array):
    """Sequential mixed oracle: the batch serialized in batch order,
    each op one full head-to-predecessor walk.  Insert succeeds iff the
    key is dead/absent (dead nodes resurrect in place; absent keys
    allocate, failing cleanly when the pool is full); delete succeeds
    iff live.  Accounting: fresh = 2 flushes, resurrect/delete = 1,
    +2 fences per successful op — the hash oracle's exact law."""
    cap = state.key.shape[0]

    def step(st: OrderedState, okv):
        op, k, v = okv

        def cond(pred):
            nx = st.nxt[pred]
            return (nx != NIL) & (st.key[nx] < k)

        pred = jax.lax.while_loop(cond, lambda p: st.nxt[p], jnp.int32(0))
        nx = st.nxt[pred]
        found = (nx != NIL) & (st.key[nx] == k)
        node = jnp.where(found, nx, NIL)
        exists_live = found & st.live[node]

        def do_resurrect(st):
            return st._replace(
                val=st.val.at[node].set(v),
                live=st.live.at[node].set(True),
                flushes=st.flushes + 1,
                fences=st.fences + 2,
            ), jnp.bool_(True)

        def do_fresh(st):
            def full(st):
                return st, jnp.bool_(False)

            def alloc(st):
                nid = st.cursor
                return st._replace(
                    key=st.key.at[nid].set(k),
                    val=st.val.at[nid].set(v),
                    nxt=st.nxt.at[nid].set(st.nxt[pred]).at[pred].set(nid),
                    live=st.live.at[nid].set(True),
                    cursor=st.cursor + 1,
                    flushes=st.flushes + 2,
                    fences=st.fences + 2,
                ), jnp.bool_(True)

            return jax.lax.cond(st.cursor < cap, alloc, full, st)

        def insert_op(st):
            def fail(st):
                return st, jnp.bool_(False)

            def attempt(st):
                dead_here = found & ~st.live[node]
                return jax.lax.cond(dead_here, do_resurrect, do_fresh, st)

            return jax.lax.cond(exists_live, fail, attempt, st)

        def delete_op(st):
            def do(st):
                return st._replace(
                    live=st.live.at[node].set(False),
                    flushes=st.flushes + 1,
                    fences=st.fences + 2,
                ), jnp.bool_(True)

            def skip(st):
                return st, jnp.bool_(False)

            return jax.lax.cond(exists_live, do, skip, st)

        return jax.lax.cond(op == OP_INSERT, insert_op, delete_op, st)

    state, ok = jax.lax.scan(step, state, (ops.astype(jnp.int32),
                                           ks.astype(jnp.int32),
                                           vs.astype(jnp.int32)))
    return state, ok


# --------------------------------------------------------------------- #
# plan/commit engine (the hot path)                                      #
# --------------------------------------------------------------------- #
def update_parallel_ordered(state: OrderedState, ops, ks, vs,
                            towers: Optional[TowerIndex] = None,
                            max_level: int = MAX_LEVEL):
    """One plan/commit round of mixed inserts/deletes over the ordered
    map — bit-identical to :func:`apply_ordered` (state arrays, per-op
    ok flags, flush/fence accounting).  Returns ``(state', ok bool[B],
    OrderedCommitStats)``.

    ``towers`` (optional) is the pre-batch volatile index; when absent
    it is rebuilt from ``state`` — either way the plan phase descends
    it with a ``vmap`` and the commit groups conflicts by predecessor
    node.  Passing stale towers (built from a different state) is a
    contract violation."""
    if towers is None:
        towers = build_towers(state, max_level)
    return _update_jit(state, jnp.asarray(ops, jnp.int32),
                       jnp.asarray(ks, jnp.int32),
                       jnp.asarray(vs, jnp.int32),
                       towers.keys, towers.addr)


@jax.jit
def _update_jit(state: OrderedState, ops, ks, vs, tk, ta):
    n = ks.shape[0]
    cap = state.key.shape[0]
    if n == 0:
        z = jnp.int32(0)
        return state, jnp.zeros(0, jnp.bool_), OrderedCommitStats(
            z, z, z, z, z)

    # ---- plan: the journey, fully parallel, zero persistence --------- #
    pred, node, snap_live = _plan(state, tk, ta, ks)
    is_ins = ops == OP_INSERT

    # ---- merged conflict resolution: per-key liveness composition ---- #
    order = jnp.argsort(ks)            # stable: ties keep batch order
    sk = ks[order]
    s_ins = is_ins[order]
    s_node = node[order]
    s_exists = (node != NIL)[order]
    first = jnp.concatenate([jnp.ones((1,), jnp.bool_), sk[1:] != sk[:-1]])
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1
    pos = jnp.arange(n, dtype=jnp.int32)

    prev_live = jnp.where(
        first, snap_live[order],
        jnp.concatenate([jnp.zeros((1,), jnp.bool_), s_ins[:-1]]))
    s_ok = s_ins ^ prev_live      # insert iff dead/absent, delete iff live
    s_okins = s_ok & s_ins

    # the allocator of an absent-key group is its first successful insert
    first_okins = jnp.full(n, n, jnp.int32).at[seg].min(
        jnp.where(s_okins, pos, n))
    s_alloc = s_okins & (pos == first_okins[seg]) & ~s_exists

    # ---- commit: allocation in batch order (oracle-identical ids) ---- #
    alloc = jnp.zeros(n, jnp.bool_).at[order].set(s_alloc)
    rank = jnp.cumsum(alloc.astype(jnp.int32)) - alloc
    alloc = alloc & (state.cursor + rank < cap)
    # a capacity-failed allocator fails its entire duplicate-key group
    s_alloc_ok = alloc[order]
    dead_seg = jnp.zeros(n, jnp.int32).at[seg].max(
        (s_alloc & ~s_alloc_ok).astype(jnp.int32))
    s_ok = s_ok & (dead_seg[seg] == 0)
    s_okins = s_ok & s_ins
    s_alloc = s_alloc & s_alloc_ok

    s_fresh_nid = jnp.where(s_alloc, state.cursor + rank[order], 0)
    seg_nid = jnp.zeros(n, jnp.int32).at[seg].max(s_fresh_nid)
    s_nid = jnp.where(s_exists, s_node, seg_nid[seg])

    last_ok = jnp.full(n, -1, jnp.int32).at[seg].max(
        jnp.where(s_ok, pos, -1))
    s_write_live = s_ok & (pos == last_ok[seg])
    last_okins = jnp.full(n, -1, jnp.int32).at[seg].max(
        jnp.where(s_okins, pos, -1))
    s_write_val = s_okins & (pos == last_okins[seg])

    sv = vs[order]
    key = state.key.at[jnp.where(s_alloc, s_nid, cap)].set(sk, mode="drop")
    val = state.val.at[jnp.where(s_write_val, s_nid, cap)].set(
        sv, mode="drop")
    live = state.live.at[jnp.where(s_write_live, s_nid, cap)].set(
        s_ins, mode="drop")

    # ---- chain splicing: the ordered divergence from the hash engine -- #
    # Fresh nodes sharing a predecessor splice into one gap.  Sorting
    # them by (pred, key) and linking each at its in-group successor —
    # the group's last at the predecessor's *snapshot* successor, the
    # predecessor at the group's first — yields the ascending chain the
    # sequential oracle converges to, while node *ids* keep batch order
    # (the allocator rank above).  Logical deletes never relink, so
    # predecessor slots (< cursor) and fresh slots (>= cursor) are
    # disjoint scatter targets.
    nid_b = jnp.where(alloc, state.cursor + rank, 0)
    pkey = jnp.where(alloc, pred, cap)          # non-fresh sort last
    order2 = jnp.lexsort((ks, pkey))            # by pred, then key
    sp = pkey[order2]
    snid = nid_b[order2]
    sfresh = alloc[order2]
    same_next = jnp.concatenate([sp[:-1] == sp[1:],
                                 jnp.zeros((1,), jnp.bool_)])
    succ_snap = state.nxt[jnp.clip(sp, 0, cap - 1)]
    link = jnp.where(same_next,
                     jnp.concatenate([snid[1:],
                                      jnp.zeros((1,), jnp.int32)]),
                     succ_snap)
    nxt = state.nxt.at[jnp.where(sfresh, snid, cap)].set(link, mode="drop")
    group_first = sfresh & ~jnp.concatenate(
        [jnp.zeros((1,), jnp.bool_), sp[1:] == sp[:-1]])
    nxt = nxt.at[jnp.where(group_first, sp, cap)].set(snid, mode="drop")

    # ---- accounting (the oracle's per-op law) + coalesced stats ------- #
    ok = jnp.zeros(n, jnp.bool_).at[order].set(s_ok)
    flushes_per_op = jnp.where(alloc, 2, jnp.where(ok, 1, 0))
    state = state._replace(
        key=key, val=val, nxt=nxt, live=live,
        cursor=state.cursor + alloc.astype(jnp.int32).sum(),
        flushes=state.flushes + flushes_per_op.sum(),
        fences=state.fences + 2 * ok.sum(),
    )
    counts = jnp.zeros(cap, jnp.int32).at[pred].add(ok.astype(jnp.int32))
    max_group = counts.max()
    stats = OrderedCommitStats(
        ops_committed=ok.sum().astype(jnp.int32),
        conflict_groups=(counts > 0).sum().astype(jnp.int32),
        max_group=max_group,
        coalesced_flushes=jnp.where(ok, flushes_per_op, 0).sum()
        .astype(jnp.int32),
        coalesced_fences=(2 * max_group).astype(jnp.int32),
    )
    return state, ok, stats


# --------------------------------------------------------------------- #
# host-side helpers + the pure differential oracle                       #
# --------------------------------------------------------------------- #
def items_host(state: OrderedState) -> dict:
    """Walk the bottom list on the host: ``{key: (live, val)}`` in chain
    order — every physical node, dead ones included."""
    key = np.asarray(state.key)
    val = np.asarray(state.val)
    nxt = np.asarray(state.nxt)
    live = np.asarray(state.live)
    out, seen = {}, set()
    node = int(nxt[0])
    while node != int(NIL):
        if node in seen:
            raise AssertionError("cycle in bottom list")
        seen.add(node)
        out[int(key[node])] = (bool(live[node]), int(val[node]))
        node = int(nxt[node])
    return out


def live_items(state: OrderedState) -> dict:
    """Abstract live content {key: val}."""
    return {k: v for k, (lv, v) in items_host(state).items() if lv}


def check_sorted(state: OrderedState) -> None:
    """Integrity: the physical chain is strictly ascending, cycle-free,
    and threads *every* allocated node (allocation always links)."""
    key = np.asarray(state.key)
    nxt = np.asarray(state.nxt)
    node = int(nxt[0])
    prev, n = KEY_MIN, 0
    seen = set()
    while node != int(NIL):
        assert node not in seen, "cycle in bottom list"
        seen.add(node)
        k = int(key[node])
        assert k > prev, f"keys not strictly sorted: {k} after {prev}"
        prev = k
        n += 1
        node = int(nxt[node])
    assert n == int(state.cursor) - 1, \
        f"chain threads {n} nodes, {int(state.cursor) - 1} allocated"


def oracle_apply(items: dict, ops, ks, vs, capacity: Optional[int] = None
                 ) -> list:
    """The pure-dict differential oracle: apply one mixed batch to
    ``items`` (``{key: (live, val)}``, mutated in place) in batch
    order with the engine's exact semantics — insert iff dead/absent,
    delete iff live, a dead key keeps its node (and last value), and
    with ``capacity`` a fresh insert fails once ``1 + len(items)``
    (sentinel + allocated nodes) reaches the pool.  Returns per-op ok.

    >>> it = {}
    >>> oracle_apply(it, [0, 1, 0], [7, 7, 7], [70, 0, 71])
    [True, True, True]
    >>> it[7]
    (True, 71)
    >>> oracle_apply(it, [0], [9], [90], capacity=2)   # pool full
    [False]
    """
    out = []
    for o, k, v in zip(ops, ks, vs):
        o, k, v = int(o), int(k), int(v)
        lv, old = items.get(k, (False, 0))
        if o == OP_INSERT:
            if lv:
                out.append(False)
            elif k in items:
                items[k] = (True, v)
                out.append(True)
            elif capacity is not None and 1 + len(items) >= capacity:
                out.append(False)
            else:
                items[k] = (True, v)
                out.append(True)
        else:
            if lv:
                items[k] = (False, old)
                out.append(True)
            else:
                out.append(False)
    return out


def oracle_range(items: dict, lo: int, hi: int) -> list:
    """Sorted-dict range oracle: ascending live ``(key, val)`` in
    ``[lo, hi]``.

    >>> oracle_range({3: (True, 30), 4: (False, 0), 9: (True, 90)}, 3, 9)
    [(3, 30), (9, 90)]
    """
    return sorted((k, v) for k, (lv, v) in items.items()
                  if lv and lo <= k <= hi)


# --------------------------------------------------------------------- #
# the durable deployment surface (journaled batches through StagedIO)    #
# --------------------------------------------------------------------- #
class DurableOrderedMap:
    """Ordered map whose committed batches are the durable surface.

    Each :meth:`update` journals its batch as one staged round file —
    write → flush → fence → atomic publish (``ord_NNNNNN.json``) —
    *before* the in-memory engine applies it, so an acknowledged batch
    is always recoverable and a crash replays a strict prefix of the
    acknowledged stream (batch order is the linearization order).
    :meth:`snapshot` publishes the full engine state (the bottom list
    *is* the data — towers are never persisted) and trims the rounds it
    covers, bounding restart to O(post-snapshot suffix).  Recovery
    (``__init__``) loads the newest valid snapshot, replays the round
    suffix through the same plan/commit engine, and rebuilds the
    volatile towers — bit-identical to the pre-crash state by
    construction."""

    def __init__(self, root, capacity: int = 256,
                 max_level: int = MAX_LEVEL, seed: int = 0):
        from ..persistence.manifest import StagedIO
        self.io = StagedIO(Path(root), seed=seed)
        self.capacity = capacity
        self.max_level = max_level
        self.state = make_ordered(capacity)
        self._n = 0                 # next round index
        self._snap_name: Optional[str] = None
        self._recover()
        self.towers = build_towers(self.state, max_level)

    # -- recovery ------------------------------------------------------ #
    @staticmethod
    def _round_index(name: str) -> Optional[int]:
        try:
            return int(name[len("ord_"):-len(".json")])
        except ValueError:
            return None

    def _recover(self) -> None:
        root = Path(self.io.root)
        snaps = sorted(p.name for p in root.glob("osnap_*.json"))
        horizon = 0
        for name in reversed(snaps):
            try:
                data = json.loads(self.io.read(name).decode())
                self.state = OrderedState(
                    key=jnp.asarray(data["key"], jnp.int32),
                    val=jnp.asarray(data["val"], jnp.int32),
                    nxt=jnp.asarray(data["nxt"], jnp.int32),
                    live=jnp.asarray(data["live"], jnp.bool_),
                    cursor=jnp.int32(data["cursor"]),
                    flushes=jnp.int32(data["flushes"]),
                    fences=jnp.int32(data["fences"]),
                )
                horizon = int(data["horizon"])
                self._snap_name = name
                break
            except (OSError, json.JSONDecodeError, KeyError, ValueError):
                continue            # torn snapshot: fall back to older
        rounds = []
        for p in sorted(root.glob("ord_*.json")):
            idx = self._round_index(p.name)
            if idx is None or idx < horizon:
                continue
            try:
                rounds.append((idx, json.loads(self.io.read(p.name)
                                               .decode())))
            except (OSError, json.JSONDecodeError, ValueError):
                continue            # torn round (never published whole)
        self._n = horizon
        for idx, rec in sorted(rounds):
            self.state, _, _ = update_parallel_ordered(
                self.state, np.asarray(rec["ops"], np.int32),
                np.asarray(rec["ks"], np.int32),
                np.asarray(rec["vs"], np.int32),
                max_level=self.max_level)
            self._n = idx + 1

    # -- the durable commit path --------------------------------------- #
    def update(self, ops, ks, vs):
        """Journal one mixed batch, then apply it through the plan/
        commit engine.  Returns per-op ok flags (numpy bool[B])."""
        rec = {"ops": [int(o) for o in ops],
               "ks": [int(k) for k in ks],
               "vs": [int(v) for v in vs]}
        rel = f"ord_{self._n:06d}.json"
        self.io.write("ord.tmp", json.dumps(rec).encode())
        self.io.flush("ord.tmp")
        self.io.fence()
        self.io.publish("ord.tmp", rel)
        self._n += 1
        self.state, ok, _ = update_parallel_ordered(
            self.state, np.asarray(ops, np.int32),
            np.asarray(ks, np.int32), np.asarray(vs, np.int32),
            towers=self.towers, max_level=self.max_level)
        self.towers = build_towers(self.state, self.max_level)
        return np.asarray(ok)

    def insert(self, ks, vs):
        return self.update(np.full(len(ks), OP_INSERT, np.int32), ks, vs)

    def delete(self, ks):
        return self.update(np.full(len(ks), OP_DELETE, np.int32), ks,
                           np.zeros(len(ks), np.int32))

    def snapshot(self) -> Optional[str]:
        """Publish the engine state (bottom list only — Property 2:
        towers stay volatile) and trim the covered rounds + the
        superseded snapshot.  Same staged discipline as a round."""
        if self._n == 0:
            return None
        payload = json.dumps({
            "horizon": self._n,
            "key": np.asarray(self.state.key).tolist(),
            "val": np.asarray(self.state.val).tolist(),
            "nxt": np.asarray(self.state.nxt).tolist(),
            "live": np.asarray(self.state.live).astype(int).tolist(),
            "cursor": int(self.state.cursor),
            "flushes": int(self.state.flushes),
            "fences": int(self.state.fences),
        })
        final = f"osnap_{self._n:08d}.json"
        self.io.write("osnap.tmp", payload.encode())
        self.io.flush("osnap.tmp")
        self.io.fence()
        self.io.publish("osnap.tmp", final)
        old, self._snap_name = self._snap_name, final
        for p in sorted(Path(self.io.root).glob("ord_*.json")):
            idx = self._round_index(p.name)
            if idx is not None and idx < self._n:
                self.io.unlink(p.name)
        if old is not None:
            self.io.unlink(old)
        return final

    # -- reads --------------------------------------------------------- #
    def lookup(self, ks):
        found, vals = lookup_ordered(self.state, jnp.asarray(ks),
                                     self.towers)
        return np.asarray(found), np.asarray(vals)

    def range(self, lo: int, hi: int, max_items: int):
        total, ks, vs = range_query(self.state, lo, hi, max_items,
                                    self.towers)
        m = min(int(total), max_items)
        return int(total), np.asarray(ks)[:m], np.asarray(vs)[:m]

    def items(self) -> dict:
        return items_host(self.state)
