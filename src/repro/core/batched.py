"""JAX-native batched durable hash map (the framework-facing core structure).

The Python-driven structures in this package are instruction-level faithful
and power the durability checker; *this* module is the JAX-native, jittable
counterpart used by the framework itself (checkpoint-manifest index,
serving request dedup) and benchmarked for real throughput.

Design: node-pool arrays + bucket heads.  Two update engines share the
same state and the same abstract semantics:

**Sequential scan engine** (``insert`` / ``delete``, plus the mixed-op
``apply``) — the oracle.  A batch is *serialized deterministically*
(scan order is the linearization order), each op runs as one
``lax.scan`` step containing a serial ``lax.while_loop`` chain walk.
``apply`` takes per-op codes (:data:`OP_INSERT` / :data:`OP_DELETE`) and
is the linearization reference for mixed insert/delete batches.  Kept as
the reference the durability checker and the equivalence tests validate
against.

**Plan/commit engine** (``update_parallel``, with ``insert_parallel`` /
``delete_parallel`` as homogeneous-batch wrappers) — the hot path.  The
paper's split, taken literally:

  * *plan* (the journey): every op's destination — bucket, existing node,
    resurrect-vs-fresh — is located by a fully ``vmap``-parallel chain
    walk over the pre-batch snapshot, with **zero persistence
    accounting**;
  * *commit* (the destination): ops are sorted by key (stable, so batch
    order is preserved inside a group) and duplicate-key conflicts are
    resolved with a **merged conflict-resolution pass** — a per-key
    segment scan that composes each op's effect on the {live, dead}
    liveness state in batch order.  The composition collapses because
    the post-state of any op is determined by the op alone (after an
    INSERT the key is live whether the op succeeded or not; after a
    DELETE it is dead either way), so an op's success needs only its
    *predecessor's* op code: ``ok = is_insert XOR prev_live``, with the
    pre-batch snapshot's liveness seeding each segment.  Insert succeeds
    iff the key is currently dead/absent, delete iff currently live, so
    duplicate keys with alternating ops get oracle-identical results —
    the first-occurrence-wins dedup of the homogeneous engines is the
    degenerate case (at most one op per key can flip the seed state).
    A key absent from the snapshot allocates on its *first successful
    insert* only (later successful inserts of the group resurrect that
    node in place); fresh node ids are assigned by a prefix-sum over
    batch order so allocation matches the oracle bit-for-bit, and
    chains are linked newest-first exactly as the sequential engine
    would have (deletes are logical marks and never relink);
  * the per-op NVTraverse accounting (Protocol 2: flush(node fields),
    fence, publish CAS, flush(bucket head), fence — **O(1) flushes +
    2 fences per update, 0 during the journey**) is preserved identically
    in ``state.flushes`` / ``state.fences``, while :class:`CommitStats`
    additionally reports the *coalesced* cost the batch engine actually
    pays: ops in different buckets share fences (batch fence coalescing
    à la Zuriel et al.), so the batch needs only ``2 × (largest
    same-bucket conflict group)`` fences in total;
  * lookups (the traversal) touch no persistence state at all;
  * crash semantics: linearization order is the batch order, so a crash
    mid-batch durably commits exactly a *prefix* of the batch; replaying
    that prefix through either engine reproduces the recovered state
    (``test_commit_engine.py`` exercises this).

The chain-walk lookup is also the reference semantics for the
``nvt_probe`` Pallas kernel (kernels/nvt_probe).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

NIL = jnp.int32(-1)   # explicit chain-link sentinel: no valid node id is
                      # negative, so an empty link can never alias a node.
                      # (Slot 0 additionally stays reserved — the bump
                      # cursor starts at 1 — so legacy zero-initialized
                      # link fields are *also* never a valid node.)
NULL = NIL            # back-compat alias

OP_INSERT = 0         # per-op codes for the mixed engines (apply /
OP_DELETE = 1         # update_parallel)


class HashMapState(NamedTuple):
    key: jax.Array          # int32[N] node keys
    val: jax.Array          # int32[N] node values
    nxt: jax.Array          # int32[N] chain links (NIL = end of chain)
    live: jax.Array         # bool[N]  logically present (False = deleted)
    head: jax.Array         # int32[B] bucket heads
    cursor: jax.Array       # int32    bump allocator (next free node id)
    flushes: jax.Array      # int32    persistence accounting
    fences: jax.Array


def make_state(capacity: int, n_buckets: int) -> HashMapState:
    """Fresh empty map.  Links (``nxt``, ``head``) are :data:`NIL`-filled:
    an empty link is explicitly distinguishable from every node index
    (node 0 included), so chain-walking code — in particular the
    migration engine's bucket drains — can never confuse "end of chain"
    with "points at node 0"."""
    return HashMapState(
        key=jnp.zeros(capacity, jnp.int32),
        val=jnp.zeros(capacity, jnp.int32),
        nxt=jnp.full(capacity, NIL, jnp.int32),
        live=jnp.zeros(capacity, jnp.bool_),
        head=jnp.full(n_buckets, NIL, jnp.int32),
        cursor=jnp.int32(1),
        flushes=jnp.int32(0),
        fences=jnp.int32(0),
    )


def _mix(x: jax.Array) -> jax.Array:
    """splitmix-style 32-bit hash (jit-friendly)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def bucket_of(k: jax.Array, n_buckets: int) -> jax.Array:
    return (_mix(k) % jnp.uint32(n_buckets)).astype(jnp.int32)


def bucket_of_np(k, n_buckets: int):
    """Numpy twin of :func:`bucket_of` for host-side routing decisions
    (migration round planning, per-shard fits checks) — bit-identical to
    the jitted hash.

    >>> bucket_of_np([1, 2, 3], 8).tolist() == \\
    ...     [int(b) for b in bucket_of(jnp.asarray([1, 2, 3]), 8)]
    True
    """
    import numpy as np
    x = np.asarray(k).astype(np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    x = x ^ (x >> np.uint32(16))
    return (x % np.uint32(n_buckets)).astype(np.int32)


# --------------------------------------------------------------------- #
# traversal (the journey — zero persistence work)                        #
# --------------------------------------------------------------------- #
def _bucket_local(k: jax.Array, n_buckets: int, nb_global, base):
    """Local bucket of ``k``: plain ``hash mod n_buckets`` by default, or
    — when this state holds the contiguous global-bucket range
    ``[base, base+n_buckets)`` of an ``nb_global``-bucket hash space —
    ``hash mod nb_global - base``.  Clipped so out-of-range keys (padding
    slots the caller masks out) index harmlessly instead of wrapping."""
    if nb_global is None:
        return bucket_of(k, n_buckets)
    b = bucket_of(k, nb_global) - jnp.asarray(base, jnp.int32)
    return jnp.clip(b, 0, n_buckets - 1)


def _find(state: HashMapState, k: jax.Array, n_buckets: int,
          nb_global=None, base=None):
    """Walk the chain; returns (node_id_or_NIL, steps)."""
    b = _bucket_local(k, n_buckets, nb_global, base)

    def cond(c):
        node, _ = c
        return (node != NIL) & (state.key[node] != k)

    def body(c):
        node, steps = c
        return state.nxt[node], steps + 1

    node, steps = jax.lax.while_loop(cond, body, (state.head[b], jnp.int32(0)))
    return node, steps


@partial(jax.jit, static_argnames=("n_buckets", "nb_global"))
def lookup(state: HashMapState, ks: jax.Array, n_buckets: int,
           nb_global=None, base=None):
    """Batched lookup: returns (found bool[batch], vals int32[batch]).

    ``nb_global``/``base`` (optional) treat the state as the owner of the
    contiguous global bucket range ``[base, base+n_buckets)`` of an
    ``nb_global``-bucket hash space — the sharded layer's re-splittable
    bucket ranges (core/sharded.py)."""
    def one(k):
        node, _ = _find(state, k, n_buckets, nb_global, base)
        found = (node != NIL) & state.live[node]
        return found, jnp.where(found, state.val[node], 0)

    return jax.vmap(one)(ks)


def merge_new_old(exists_new, live_new, vals_new, live_old, vals_old):
    """The migration/rebalance **new-then-old** lookup rule, composed in
    one place: once a key has *any* node in the new table — live or
    dead — the new table's word is final (a dead node there means
    "deleted during migration" and vetoes the old table's stale live
    copy); only node-less keys fall through to the old table.

    Host-side numpy — the two :func:`probe` results it merges are
    already on the host in every caller
    (:meth:`repro.core.migrate.MigratingMap.lookup`, the live mesh
    rebalance of :mod:`repro.core.rebalance`).  Returns ``(found,
    vals)`` with :func:`lookup`'s exact contract: a not-found key's val
    is 0 even when a dead node still holds its last value.

    >>> import numpy as np
    >>> f, v = merge_new_old(
    ...     np.array([True, True, False]),      # key 0 deleted in new,
    ...     np.array([False, True, False]),     # key 1 updated in new,
    ...     np.array([0, 7, 0]),                # key 2 only in old
    ...     np.array([True, True, True]),
    ...     np.array([5, 6, 9]))
    >>> f.tolist(), v.tolist()
    ([False, True, True], [0, 7, 9])
    """
    import numpy as np
    found = np.asarray(np.where(exists_new, live_new, live_old), np.bool_)
    vals = np.where(exists_new, vals_new, vals_old)
    return found, np.where(found, vals, 0).astype(np.int32)


@partial(jax.jit, static_argnames=("n_buckets", "nb_global"))
def probe(state: HashMapState, ks: jax.Array, n_buckets: int,
          nb_global=None, base=None):
    """Node-level probe (the journey — zero persistence work): returns
    ``(exists, live, vals)`` where ``exists`` is True iff the key has a
    node at all, dead or alive.  The migration engine uses this to make
    the new table authoritative: a key with *any* node in the new table
    must never be re-pulled from the old one (a dead node there means
    "deleted during migration", not "absent")."""
    def one(k):
        node, _ = _find(state, k, n_buckets, nb_global, base)
        exists = node != NIL
        live = exists & state.live[node]
        return exists, live, jnp.where(exists, state.val[node], 0)

    return jax.vmap(one)(ks)


# --------------------------------------------------------------------- #
# updates (the destination — O(1) flushes, 2 fences per op)              #
# --------------------------------------------------------------------- #
@partial(jax.jit, static_argnames="n_buckets")
def insert(state: HashMapState, ks: jax.Array, vs: jax.Array,
           n_buckets: int):
    """Batched insert; scan order is the linearization order.

    Returns (state', inserted bool[batch]).  A key already present (live)
    fails; a dead node with the key is resurrected in place (its value CAS
    is a single-word modification, same persistence cost).
    """

    def step(st: HashMapState, kv):
        k, v = kv
        node, _ = _find(st, k, n_buckets)
        exists_live = (node != NIL) & st.live[node]

        def do_resurrect(st):
            # value write + unmark: flush the node line, fence, return fence
            return st._replace(
                val=st.val.at[node].set(v),
                live=st.live.at[node].set(True),
                flushes=st.flushes + 1,
                fences=st.fences + 2,
            )

        def do_fresh(st):
            b = bucket_of(k, n_buckets)
            nid = st.cursor
            st = st._replace(
                key=st.key.at[nid].set(k),
                val=st.val.at[nid].set(v),
                nxt=st.nxt.at[nid].set(st.head[b]),
                live=st.live.at[nid].set(True),
                # NVTraverse commit: flush(node) ; fence ; publish ;
                # flush(head) ; fence        — 2 flushes, 2 fences, O(1).
                head=st.head.at[b].set(nid),
                cursor=st.cursor + 1,
                flushes=st.flushes + 2,
                fences=st.fences + 2,
            )
            return st

        def do_insert(st):
            dead_here = (node != NIL) & ~st.live[node]
            return jax.lax.cond(dead_here, do_resurrect, do_fresh, st)

        st = jax.lax.cond(exists_live, lambda s: s, do_insert, st)
        return st, ~exists_live

    state, ok = jax.lax.scan(step, state, (ks.astype(jnp.int32),
                                           vs.astype(jnp.int32)))
    return state, ok


@partial(jax.jit, static_argnames="n_buckets")
def delete(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batched delete via logical marking (mark-before-disconnect)."""

    def step(st: HashMapState, k):
        node, _ = _find(st, k, n_buckets)
        present = (node != NIL) & st.live[node]

        def do(st):
            return st._replace(
                live=st.live.at[node].set(False),
                flushes=st.flushes + 1,   # flush the marked line
                fences=st.fences + 2,     # pre-CAS fence + return fence
            )

        st = jax.lax.cond(present, do, lambda s: s, st)
        return st, present

    state, ok = jax.lax.scan(step, state, ks.astype(jnp.int32))
    return state, ok


@partial(jax.jit, static_argnames="n_buckets")
def apply(state: HashMapState, ops: jax.Array, ks: jax.Array,
          vs: jax.Array, n_buckets: int):
    """Sequential *mixed* oracle: one batch of interleaved inserts and
    deletes, serialized in batch order (the linearization order).

    ``ops[i]`` is :data:`OP_INSERT` or :data:`OP_DELETE`.  Insert
    succeeds iff the key is currently dead/absent (a dead node is
    resurrected in place; an absent key allocates a fresh node — failing
    cleanly when the pool is full, matching :func:`update_parallel`
    rather than :func:`insert`'s silent overflow); delete succeeds iff
    the key is currently live.  Returns ``(state', ok bool[batch])``.
    """
    cap = state.key.shape[0]

    def step(st: HashMapState, okv):
        op, k, v = okv
        node, _ = _find(st, k, n_buckets)
        exists_live = (node != NIL) & st.live[node]

        def do_resurrect(st):
            return st._replace(
                val=st.val.at[node].set(v),
                live=st.live.at[node].set(True),
                flushes=st.flushes + 1,
                fences=st.fences + 2,
            ), jnp.bool_(True)

        def do_fresh(st):
            def full(st):
                return st, jnp.bool_(False)

            def alloc(st):
                b = bucket_of(k, n_buckets)
                nid = st.cursor
                return st._replace(
                    key=st.key.at[nid].set(k),
                    val=st.val.at[nid].set(v),
                    nxt=st.nxt.at[nid].set(st.head[b]),
                    live=st.live.at[nid].set(True),
                    head=st.head.at[b].set(nid),
                    cursor=st.cursor + 1,
                    flushes=st.flushes + 2,
                    fences=st.fences + 2,
                ), jnp.bool_(True)

            return jax.lax.cond(st.cursor < cap, alloc, full, st)

        def insert_op(st):
            def fail(st):
                return st, jnp.bool_(False)

            def attempt(st):
                dead_here = (node != NIL) & ~st.live[node]
                return jax.lax.cond(dead_here, do_resurrect, do_fresh, st)

            return jax.lax.cond(exists_live, fail, attempt, st)

        def delete_op(st):
            def do(st):
                return st._replace(
                    live=st.live.at[node].set(False),
                    flushes=st.flushes + 1,
                    fences=st.fences + 2,
                ), jnp.bool_(True)

            def skip(st):
                return st, jnp.bool_(False)

            return jax.lax.cond(exists_live, do, skip, st)

        return jax.lax.cond(op == OP_INSERT, insert_op, delete_op, st)

    state, ok = jax.lax.scan(step, state, (ops.astype(jnp.int32),
                                           ks.astype(jnp.int32),
                                           vs.astype(jnp.int32)))
    return state, ok


# --------------------------------------------------------------------- #
# plan/commit engine (the hot path)                                       #
# --------------------------------------------------------------------- #
class CommitStats(NamedTuple):
    """What the batch engine actually pays at the destination.

    ``state.flushes``/``state.fences`` keep the oracle's per-op
    accounting; these fields report the coalesced batch cost: one
    commit *round* handles at most one op per bucket, all rounds'
    node-flushes share a fence and all head-flushes share a second, so
    a batch needs ``2 × max same-bucket group size`` fences regardless
    of batch width.

    ``bucket_flushes`` breaks the flush accounting down per bucket —
    the instrumentation the sharded layer (core/sharded.py) uses to
    *prove* persistence locality: a shard's commit may only ever flush
    buckets inside its own range, so the stacked per-shard arrays must
    be nonzero only inside each owner range.
    """
    ops_committed: jax.Array      # int32  ops that mutated state
    conflict_groups: jax.Array    # int32  buckets with ≥1 committing op
    max_group: jax.Array          # int32  largest same-bucket group
    coalesced_flushes: jax.Array  # int32  flushes the batch engine issues
    coalesced_fences: jax.Array   # int32  fences  ″  (2 × max_group)
    bucket_flushes: jax.Array     # int32[n_buckets]  flushes per bucket


def _plan(state: HashMapState, ks: jax.Array, n_buckets: int,
          nb_global=None, base=None):
    """The journey, batch-wide: locate every op's destination against the
    pre-batch snapshot with a vmap'd chain walk.  No persistence state is
    read or written."""
    node = jax.vmap(
        lambda k: _find(state, k, n_buckets, nb_global, base)[0])(ks)
    snap_exists = node != NIL
    snap_live = snap_exists & state.live[node]
    bucket = _bucket_local(ks, n_buckets, nb_global, base)
    return node, snap_exists, snap_live, bucket


def _commit_stats(bucket: jax.Array, ok: jax.Array, flushes_per_op,
                  n_buckets: int) -> CommitStats:
    counts = jnp.zeros(n_buckets, jnp.int32).at[bucket].add(
        ok.astype(jnp.int32))
    max_group = counts.max()
    flushes = jnp.where(ok, flushes_per_op, 0).astype(jnp.int32)
    return CommitStats(
        ops_committed=ok.sum().astype(jnp.int32),
        conflict_groups=(counts > 0).sum().astype(jnp.int32),
        max_group=max_group,
        coalesced_flushes=flushes.sum(),
        coalesced_fences=(2 * max_group).astype(jnp.int32),
        bucket_flushes=jnp.zeros(n_buckets, jnp.int32).at[bucket].add(
            flushes),
    )


@partial(jax.jit, static_argnames=("n_buckets", "nb_global"))
def update_parallel(state: HashMapState, ops: jax.Array, ks: jax.Array,
                    vs: jax.Array, n_buckets: int, valid=None,
                    nb_global=None, base=None):
    """Unified mixed-op engine: one plan/commit round over interleaved
    inserts and deletes (``ops[i]`` ∈ {:data:`OP_INSERT`,
    :data:`OP_DELETE`}).  Bit-identical to the sequential mixed oracle
    :func:`apply` (state arrays, per-op ok flags, flush/fence
    accounting); returns ``(state', ok bool[batch], CommitStats)``.

    ``valid`` (optional ``bool[batch]``) marks padding slots: an invalid
    op always fails (``ok=False``), never allocates, writes, or adds to
    the accounting, and is *transparent* to the liveness composition of
    its duplicate-key group — exactly as if the batch had been the valid
    subset alone.  The sharded layer uses this to keep all-to-all
    exchange shapes static (per-shard op counts padded to the max).

    Conflict resolution is a per-key segment scan over the batch sorted
    stably by key: within a duplicate-key group the liveness state after
    any op equals the op's own code (live after an insert, dead after a
    delete, successful or not), so ``ok = is_insert XOR prev_live`` with
    the pre-batch snapshot seeding each group.  A key absent from the
    snapshot allocates a node at its first successful insert only; every
    later successful insert of the group resurrects that node in place,
    so at most one node per key per batch.  The group's *last*
    successful op decides the node's final liveness and its last
    successful insert the final value — one scatter per array, no
    duplicate-index races.

    One deliberate divergence from the homogeneous scan engines: on
    node-pool exhaustion :func:`insert` silently drops node writes while
    still publishing the (dangling) id into the bucket head; here (and
    in :func:`apply`) an insert that would not fit simply *fails*
    (``ok=False``, no state change) — and every later op of its
    duplicate-key group fails with it, exactly as re-running each op
    against the still-exhausted pool would.  Full-map overflow is
    detectable by the caller instead of corrupting chains.

    ``nb_global``/``base`` (optional, see :func:`lookup`) commit against
    the contiguous global bucket range ``[base, base+n_buckets)`` of an
    ``nb_global``-bucket hash space — what lets the sharded layer's
    re-splittable (possibly uneven) bucket ranges run this engine
    unmodified per shard."""
    ops = ops.astype(jnp.int32)
    ks = ks.astype(jnp.int32)
    vs = vs.astype(jnp.int32)
    n = ks.shape[0]
    cap = state.key.shape[0]
    if n == 0:                       # static shape: an empty batch is a no-op
        empty = jnp.zeros(0, jnp.int32)
        return state, jnp.zeros(0, jnp.bool_), _commit_stats(
            empty, jnp.zeros(0, jnp.bool_), empty, n_buckets)

    # ---- plan: the journey, fully parallel, zero persistence ---------- #
    node, snap_exists, snap_live, bucket = _plan(state, ks, n_buckets,
                                                 nb_global, base)
    is_ins = ops == OP_INSERT

    # ---- merged conflict resolution: per-key liveness composition ----- #
    order = jnp.argsort(ks)            # stable: ties keep batch order
    sk = ks[order]
    s_ins = is_ins[order]
    s_node = node[order]
    s_exists = snap_exists[order]
    first = jnp.concatenate([jnp.ones((1,), jnp.bool_), sk[1:] != sk[:-1]])

    # segment machinery: segment id + scatter-min/max over segments
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1
    pos = jnp.arange(n, dtype=jnp.int32)

    if valid is None:
        prev_live = jnp.where(
            first, snap_live[order],
            jnp.concatenate([jnp.zeros((1,), jnp.bool_), s_ins[:-1]]))
        s_ok = s_ins ^ prev_live    # insert iff dead/absent, delete iff live
    else:
        # padding-transparent composition: liveness after any *valid* op
        # is that op's code, and invalid ops leave it untouched, so an
        # op's predecessor state is the code of the latest valid op
        # before it in its segment (the snapshot seed when there is
        # none).  A cummax over valid positions finds that predecessor
        # without assuming pads sort after real ops within a group.
        s_valid = valid[order]
        lastv = jax.lax.cummax(jnp.where(s_valid, pos, -1))
        prev_j = jnp.concatenate([jnp.full((1,), -1, jnp.int32),
                                  lastv[:-1]])
        pj = jnp.clip(prev_j, 0, n - 1)
        in_seg = (prev_j >= 0) & (seg[pj] == seg)
        prev_live = jnp.where(in_seg, s_ins[pj], snap_live[order])
        s_ok = (s_ins ^ prev_live) & s_valid
    s_okins = s_ok & s_ins

    # the allocator of an absent-key group is its first successful insert
    first_okins = jnp.full(n, n, jnp.int32).at[seg].min(
        jnp.where(s_okins, pos, n))
    s_alloc = s_okins & (pos == first_okins[seg]) & ~s_exists

    # ---- commit: allocation in batch order (oracle-identical ids) ----- #
    # an op that would allocate past the pool fails; failed allocators
    # consume no id, so the surviving ids are exactly cursor, cursor+1, …
    alloc = jnp.zeros(n, jnp.bool_).at[order].set(s_alloc)
    rank = jnp.cumsum(alloc.astype(jnp.int32)) - alloc
    alloc = alloc & (state.cursor + rank < cap)
    # a capacity-failed allocator fails its entire duplicate-key group
    # (the key stays absent for the whole batch: the pool only grows)
    s_alloc_ok = alloc[order]
    dead_seg = jnp.zeros(n, jnp.int32).at[seg].max(
        (s_alloc & ~s_alloc_ok).astype(jnp.int32))
    s_ok = s_ok & (dead_seg[seg] == 0)
    s_okins = s_ok & s_ins
    s_alloc = s_alloc & s_alloc_ok

    # group node id: the snapshot node, or the allocator's fresh id
    # broadcast to its group (failed ops never write, so the 0 the
    # pre-allocator ops of a capacity-failed group see is harmless)
    s_fresh_nid = jnp.where(s_alloc, state.cursor + rank[order], 0)
    seg_nid = jnp.zeros(n, jnp.int32).at[seg].max(s_fresh_nid)
    s_nid = jnp.where(s_exists, s_node, seg_nid[seg])   # NIL in absent
    # groups is replaced by the allocator's fresh id (0 when the whole
    # group capacity-failed — those ops never write, so it is inert)

    # the last successful op / insert of each group decide final values
    last_ok = jnp.full(n, -1, jnp.int32).at[seg].max(
        jnp.where(s_ok, pos, -1))
    s_write_live = s_ok & (pos == last_ok[seg])
    last_okins = jnp.full(n, -1, jnp.int32).at[seg].max(
        jnp.where(s_okins, pos, -1))
    s_write_val = s_okins & (pos == last_okins[seg])

    # node-field publication (masked ops scatter out of bounds → dropped)
    sv = vs[order]
    key = state.key.at[jnp.where(s_alloc, s_nid, cap)].set(sk, mode="drop")
    val = state.val.at[jnp.where(s_write_val, s_nid, cap)].set(
        sv, mode="drop")
    live = state.live.at[jnp.where(s_write_live, s_nid, cap)].set(
        s_ins, mode="drop")

    # chain linking: sort fresh allocations by (bucket, batch index);
    # inside a bucket group each fresh node points at its predecessor in
    # the group, the group's first at the snapshot head, and the group's
    # last becomes the new head — newest-first, exactly the scan order.
    # (Logical deletes never relink, so only allocators touch chains.)
    nid_b = jnp.where(alloc, state.cursor + rank, 0)
    bkey = jnp.where(alloc, bucket, n_buckets)      # non-fresh sort last
    order2 = jnp.argsort(bkey)                      # stable within groups
    sb = bkey[order2]
    snid = nid_b[order2]
    sfresh = alloc[order2]
    same_prev = jnp.concatenate(
        [jnp.zeros((1,), jnp.bool_), sb[1:] == sb[:-1]])
    link = jnp.where(same_prev,
                     jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                      snid[:-1]]),
                     state.head[jnp.clip(sb, 0, n_buckets - 1)])
    nxt = state.nxt.at[jnp.where(sfresh, snid, cap)].set(link, mode="drop")
    group_last = sfresh & jnp.concatenate(
        [sb[:-1] != sb[1:], jnp.ones((1,), jnp.bool_)])
    head = state.head.at[jnp.where(group_last, sb, n_buckets)].set(
        snid, mode="drop")

    # oracle accounting: fresh = 2 flushes, resurrect/delete = 1,
    # +2 fences per successful op
    ok = jnp.zeros(n, jnp.bool_).at[order].set(s_ok)
    flushes_per_op = jnp.where(alloc, 2, jnp.where(ok, 1, 0))
    state = state._replace(
        key=key, val=val, nxt=nxt, live=live, head=head,
        cursor=state.cursor + alloc.astype(jnp.int32).sum(),
        flushes=state.flushes + flushes_per_op.sum(),
        fences=state.fences + 2 * ok.sum(),
    )
    return state, ok, _commit_stats(bucket, ok, flushes_per_op, n_buckets)


def insert_parallel(state: HashMapState, ks: jax.Array, vs: jax.Array,
                    n_buckets: int):
    """Batch insert via plan/commit — :func:`update_parallel` with a
    homogeneous :data:`OP_INSERT` batch.  Bit-identical to :func:`insert`
    (state, per-op results, flush/fence accounting) except for the clean
    fail on pool exhaustion (see :func:`update_parallel`); returns
    ``(state', ok bool[batch], CommitStats)``."""
    ops = jnp.full(jnp.shape(ks), OP_INSERT, jnp.int32)
    return update_parallel(state, ops, ks, vs, n_buckets)


def delete_parallel(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batch logical delete via plan/commit — :func:`update_parallel`
    with a homogeneous :data:`OP_DELETE` batch; oracle-identical to
    :func:`delete`.  Returns ``(state', ok bool[batch], CommitStats)``."""
    ops = jnp.full(jnp.shape(ks), OP_DELETE, jnp.int32)
    return update_parallel(state, ops, ks, jnp.zeros_like(ks, jnp.int32),
                           n_buckets)


@partial(jax.jit, static_argnames="n_buckets")
def chain_stats(state: HashMapState, n_buckets: int):
    """Max/mean chain length — the traversal cost the paper's transform
    makes persistence-free."""
    def walk(b):
        def cond(c):
            node, steps = c
            return (node != NIL) & (steps < state.key.shape[0])

        def body(c):
            node, steps = c
            return state.nxt[node], steps + 1

        _, steps = jax.lax.while_loop(cond, body, (state.head[b], jnp.int32(0)))
        return steps

    lens = jax.vmap(walk)(jnp.arange(n_buckets, dtype=jnp.int32))
    return lens.max(), lens.mean()
