"""JAX-native batched durable hash map (the framework-facing core structure).

The Python-driven structures in this package are instruction-level faithful
and power the durability checker; *this* module is the JAX-native, jittable
counterpart used by the framework itself (checkpoint-manifest index,
serving request dedup) and benchmarked for real throughput.

Design: node-pool arrays + bucket heads.  Two update engines share the
same state and the same abstract semantics:

**Sequential scan engine** (``insert`` / ``delete``) — the oracle.  A
batch is *serialized deterministically* (scan order is the linearization
order), each op runs as one ``lax.scan`` step containing a serial
``lax.while_loop`` chain walk.  Kept as the reference the durability
checker and the equivalence tests validate against.

**Plan/commit engine** (``insert_parallel`` / ``delete_parallel``) — the
hot path.  The paper's split, taken literally:

  * *plan* (the journey): every op's destination — bucket, existing node,
    resurrect-vs-fresh — is located by a fully ``vmap``-parallel chain
    walk over the pre-batch snapshot, with **zero persistence
    accounting**;
  * *commit* (the destination): ops are sorted by bucket (stable, so
    batch order is preserved inside a group) and conflicts are resolved
    with segment-scan primitives *within* same-bucket groups only —
    first-occurrence-of-key wins, fresh node ids are assigned by a
    prefix-sum over batch order so allocation matches the oracle
    bit-for-bit, and chains are linked newest-first exactly as the
    sequential engine would have;
  * the per-op NVTraverse accounting (Protocol 2: flush(node fields),
    fence, publish CAS, flush(bucket head), fence — **O(1) flushes +
    2 fences per update, 0 during the journey**) is preserved identically
    in ``state.flushes`` / ``state.fences``, while :class:`CommitStats`
    additionally reports the *coalesced* cost the batch engine actually
    pays: ops in different buckets share fences (batch fence coalescing
    à la Zuriel et al.), so the batch needs only ``2 × (largest
    same-bucket conflict group)`` fences in total;
  * lookups (the traversal) touch no persistence state at all;
  * crash semantics: linearization order is the batch order, so a crash
    mid-batch durably commits exactly a *prefix* of the batch; replaying
    that prefix through either engine reproduces the recovered state
    (``test_commit_engine.py`` exercises this).

The chain-walk lookup is also the reference semantics for the
``nvt_probe`` Pallas kernel (kernels/nvt_probe).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

NULL = jnp.int32(0)   # node id 0 is reserved as null


class HashMapState(NamedTuple):
    key: jax.Array          # int32[N] node keys
    val: jax.Array          # int32[N] node values
    nxt: jax.Array          # int32[N] chain links (0 = null)
    live: jax.Array         # bool[N]  logically present (False = deleted)
    head: jax.Array         # int32[B] bucket heads
    cursor: jax.Array       # int32    bump allocator (next free node id)
    flushes: jax.Array      # int32    persistence accounting
    fences: jax.Array


def make_state(capacity: int, n_buckets: int) -> HashMapState:
    return HashMapState(
        key=jnp.zeros(capacity, jnp.int32),
        val=jnp.zeros(capacity, jnp.int32),
        nxt=jnp.zeros(capacity, jnp.int32),
        live=jnp.zeros(capacity, jnp.bool_),
        head=jnp.zeros(n_buckets, jnp.int32),
        cursor=jnp.int32(1),
        flushes=jnp.int32(0),
        fences=jnp.int32(0),
    )


def _mix(x: jax.Array) -> jax.Array:
    """splitmix-style 32-bit hash (jit-friendly)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def bucket_of(k: jax.Array, n_buckets: int) -> jax.Array:
    return (_mix(k) % jnp.uint32(n_buckets)).astype(jnp.int32)


# --------------------------------------------------------------------- #
# traversal (the journey — zero persistence work)                        #
# --------------------------------------------------------------------- #
def _find(state: HashMapState, k: jax.Array, n_buckets: int):
    """Walk the chain; returns (node_id_or_0, steps)."""
    b = bucket_of(k, n_buckets)

    def cond(c):
        node, _ = c
        return (node != NULL) & (state.key[node] != k)

    def body(c):
        node, steps = c
        return state.nxt[node], steps + 1

    node, steps = jax.lax.while_loop(cond, body, (state.head[b], jnp.int32(0)))
    return node, steps


@partial(jax.jit, static_argnames="n_buckets")
def lookup(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batched lookup: returns (found bool[batch], vals int32[batch])."""
    def one(k):
        node, _ = _find(state, k, n_buckets)
        found = (node != NULL) & state.live[node]
        return found, jnp.where(found, state.val[node], 0)

    return jax.vmap(one)(ks)


# --------------------------------------------------------------------- #
# updates (the destination — O(1) flushes, 2 fences per op)              #
# --------------------------------------------------------------------- #
@partial(jax.jit, static_argnames="n_buckets")
def insert(state: HashMapState, ks: jax.Array, vs: jax.Array,
           n_buckets: int):
    """Batched insert; scan order is the linearization order.

    Returns (state', inserted bool[batch]).  A key already present (live)
    fails; a dead node with the key is resurrected in place (its value CAS
    is a single-word modification, same persistence cost).
    """

    def step(st: HashMapState, kv):
        k, v = kv
        node, _ = _find(st, k, n_buckets)
        exists_live = (node != NULL) & st.live[node]

        def do_resurrect(st):
            # value write + unmark: flush the node line, fence, return fence
            return st._replace(
                val=st.val.at[node].set(v),
                live=st.live.at[node].set(True),
                flushes=st.flushes + 1,
                fences=st.fences + 2,
            )

        def do_fresh(st):
            b = bucket_of(k, n_buckets)
            nid = st.cursor
            st = st._replace(
                key=st.key.at[nid].set(k),
                val=st.val.at[nid].set(v),
                nxt=st.nxt.at[nid].set(st.head[b]),
                live=st.live.at[nid].set(True),
                # NVTraverse commit: flush(node) ; fence ; publish ;
                # flush(head) ; fence        — 2 flushes, 2 fences, O(1).
                head=st.head.at[b].set(nid),
                cursor=st.cursor + 1,
                flushes=st.flushes + 2,
                fences=st.fences + 2,
            )
            return st

        def do_insert(st):
            dead_here = (node != NULL) & ~st.live[node]
            return jax.lax.cond(dead_here, do_resurrect, do_fresh, st)

        st = jax.lax.cond(exists_live, lambda s: s, do_insert, st)
        return st, ~exists_live

    state, ok = jax.lax.scan(step, state, (ks.astype(jnp.int32),
                                           vs.astype(jnp.int32)))
    return state, ok


@partial(jax.jit, static_argnames="n_buckets")
def delete(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batched delete via logical marking (mark-before-disconnect)."""

    def step(st: HashMapState, k):
        node, _ = _find(st, k, n_buckets)
        present = (node != NULL) & st.live[node]

        def do(st):
            return st._replace(
                live=st.live.at[node].set(False),
                flushes=st.flushes + 1,   # flush the marked line
                fences=st.fences + 2,     # pre-CAS fence + return fence
            )

        st = jax.lax.cond(present, do, lambda s: s, st)
        return st, present

    state, ok = jax.lax.scan(step, state, ks.astype(jnp.int32))
    return state, ok


# --------------------------------------------------------------------- #
# plan/commit engine (the hot path)                                       #
# --------------------------------------------------------------------- #
class CommitStats(NamedTuple):
    """What the batch engine actually pays at the destination.

    ``state.flushes``/``state.fences`` keep the oracle's per-op
    accounting; these fields report the coalesced batch cost: one
    commit *round* handles at most one op per bucket, all rounds'
    node-flushes share a fence and all head-flushes share a second, so
    a batch needs ``2 × max same-bucket group size`` fences regardless
    of batch width.
    """
    ops_committed: jax.Array      # int32  ops that mutated state
    conflict_groups: jax.Array    # int32  buckets with ≥1 committing op
    max_group: jax.Array          # int32  largest same-bucket group
    coalesced_flushes: jax.Array  # int32  flushes the batch engine issues
    coalesced_fences: jax.Array   # int32  fences  ″  (2 × max_group)


def _plan(state: HashMapState, ks: jax.Array, n_buckets: int):
    """The journey, batch-wide: locate every op's destination against the
    pre-batch snapshot with a vmap'd chain walk.  No persistence state is
    read or written.  Returns (node, snap_live, bucket, first) where
    ``first`` marks the first occurrence of each key in batch order —
    the only op of a duplicate-key group that can commit."""
    node = jax.vmap(lambda k: _find(state, k, n_buckets)[0])(ks)
    snap_live = (node != NULL) & state.live[node]
    bucket = bucket_of(ks, n_buckets)
    n = ks.shape[0]
    order = jnp.argsort(ks)                     # stable: ties keep batch order
    sk = ks[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sk[1:] != sk[:-1]])
    first = jnp.zeros(n, jnp.bool_).at[order].set(first_sorted)
    return node, snap_live, bucket, first


def _commit_stats(bucket: jax.Array, ok: jax.Array, flushes_per_op,
                  n_buckets: int) -> CommitStats:
    counts = jnp.zeros(n_buckets, jnp.int32).at[bucket].add(
        ok.astype(jnp.int32))
    max_group = counts.max()
    return CommitStats(
        ops_committed=ok.sum().astype(jnp.int32),
        conflict_groups=(counts > 0).sum().astype(jnp.int32),
        max_group=max_group,
        coalesced_flushes=jnp.sum(
            jnp.where(ok, flushes_per_op, 0)).astype(jnp.int32),
        coalesced_fences=(2 * max_group).astype(jnp.int32),
    )


@partial(jax.jit, static_argnames="n_buckets")
def insert_parallel(state: HashMapState, ks: jax.Array, vs: jax.Array,
                    n_buckets: int):
    """Batch insert via plan/commit.  Bit-identical to :func:`insert`
    (state, per-op results, flush/fence accounting); returns
    ``(state', ok bool[batch], CommitStats)``.

    One deliberate divergence: on node-pool exhaustion the scan oracle
    silently drops node writes while still publishing the (dangling) id
    into the bucket head; here a fresh insert that would not fit simply
    *fails* (``ok=False``, no state change) — full-map overflow is
    detectable by the caller instead of corrupting chains."""
    ks = ks.astype(jnp.int32)
    vs = vs.astype(jnp.int32)
    n = ks.shape[0]
    cap = state.key.shape[0]

    # ---- plan: the journey, fully parallel, zero persistence ---------- #
    node, snap_live, bucket, first = _plan(state, ks, n_buckets)
    ok = first & ~snap_live
    snap_dead = (node != NULL) & ~snap_live
    fresh = ok & ~snap_dead

    # ---- commit: allocation in batch order (oracle-identical ids) ----- #
    # an op that would allocate past the pool fails; failed ops consume
    # no id, so the surviving ids are exactly cursor, cursor+1, …
    fresh_rank = jnp.cumsum(fresh.astype(jnp.int32)) - fresh
    fresh = fresh & (state.cursor + fresh_rank < cap)
    ok = fresh | (ok & snap_dead)
    resurrect = ok & snap_dead
    fresh_i32 = fresh.astype(jnp.int32)
    nid = jnp.where(fresh, state.cursor + fresh_rank, node)

    # node-field publication (masked ops scatter out of bounds → dropped)
    widx = jnp.where(ok, nid, cap)
    key = state.key.at[widx].set(ks, mode="drop")
    val = state.val.at[widx].set(vs, mode="drop")
    live = state.live.at[widx].set(True, mode="drop")

    # chain linking: sort fresh ops by (bucket, batch index); inside a
    # bucket group each fresh node points at its predecessor in the
    # group, the group's first at the snapshot head, and the group's
    # last becomes the new head — newest-first, exactly the scan order.
    bkey = jnp.where(fresh, bucket, n_buckets)      # non-fresh sort last
    order = jnp.argsort(bkey)                       # stable within groups
    sb = bkey[order]
    snid = nid[order]
    sfresh = fresh[order]
    same_prev = jnp.concatenate(
        [jnp.zeros((1,), jnp.bool_), sb[1:] == sb[:-1]])
    link = jnp.where(same_prev,
                     jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                      snid[:-1]]),
                     state.head[jnp.clip(sb, 0, n_buckets - 1)])
    nxt = state.nxt.at[jnp.where(sfresh, snid, cap)].set(link, mode="drop")
    group_last = sfresh & jnp.concatenate(
        [sb[:-1] != sb[1:], jnp.ones((1,), jnp.bool_)])
    head = state.head.at[jnp.where(group_last, sb, n_buckets)].set(
        snid, mode="drop")

    # oracle accounting: fresh = 2 flushes, resurrect = 1, +2 fences each
    flushes_per_op = jnp.where(fresh, 2, jnp.where(resurrect, 1, 0))
    state = state._replace(
        key=key, val=val, nxt=nxt, live=live, head=head,
        cursor=state.cursor + fresh_i32.sum(),
        flushes=state.flushes + flushes_per_op.sum(),
        fences=state.fences + 2 * ok.sum(),
    )
    return state, ok, _commit_stats(bucket, ok, flushes_per_op, n_buckets)


@partial(jax.jit, static_argnames="n_buckets")
def delete_parallel(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batch logical delete via plan/commit; oracle-identical to
    :func:`delete`.  Returns ``(state', ok bool[batch], CommitStats)``."""
    ks = ks.astype(jnp.int32)
    cap = state.key.shape[0]
    node, snap_live, bucket, first = _plan(state, ks, n_buckets)
    ok = first & snap_live
    live = state.live.at[jnp.where(ok, node, cap)].set(False, mode="drop")
    flushes_per_op = jnp.where(ok, 1, 0)
    state = state._replace(
        live=live,
        flushes=state.flushes + flushes_per_op.sum(),
        fences=state.fences + 2 * ok.sum(),
    )
    return state, ok, _commit_stats(bucket, ok, flushes_per_op, n_buckets)


@partial(jax.jit, static_argnames="n_buckets")
def chain_stats(state: HashMapState, n_buckets: int):
    """Max/mean chain length — the traversal cost the paper's transform
    makes persistence-free."""
    def walk(b):
        def cond(c):
            node, steps = c
            return (node != NULL) & (steps < state.key.shape[0])

        def body(c):
            node, steps = c
            return state.nxt[node], steps + 1

        _, steps = jax.lax.while_loop(cond, body, (state.head[b], jnp.int32(0)))
        return steps

    lens = jax.vmap(walk)(jnp.arange(n_buckets, dtype=jnp.int32))
    return lens.max(), lens.mean()
