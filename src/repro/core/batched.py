"""JAX-native batched durable hash map (the framework-facing core structure).

The Python-driven structures in this package are instruction-level faithful
and power the durability checker; *this* module is the JAX-native, jittable
counterpart used by the framework itself (checkpoint-manifest index,
serving request dedup) and benchmarked for real throughput.

Design: node-pool arrays + bucket heads, operations expressed with
``lax.scan``/``lax.while_loop`` (no Python loops in the hot path):

  * a batch of operations is *serialized deterministically* (scan order is
    the linearization order), matching the sequential semantics the
    durability checker validates;
  * each successful insert performs the NVTraverse commit sequence of
    Protocol 2 — flush(new node fields), fence, publish CAS, flush(bucket
    head), fence — so the accounting is **O(1) flushes + 2 fences per
    update and 0 during the chain walk** (the journey), mirroring the
    instruction-level structures exactly (cross-checked in tests);
  * lookups (the traversal) touch no persistence state at all;
  * crash semantics: an in-flight insert is all-or-nothing because
    reachability requires the bucket-head update, which is fenced *after*
    the node contents — ``crash_replay`` in the tests exercises prefix
    durability.

The chain-walk lookup is also the reference semantics for the
``nvt_probe`` Pallas kernel (kernels/nvt_probe).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

NULL = jnp.int32(0)   # node id 0 is reserved as null


class HashMapState(NamedTuple):
    key: jax.Array          # int32[N] node keys
    val: jax.Array          # int32[N] node values
    nxt: jax.Array          # int32[N] chain links (0 = null)
    live: jax.Array         # bool[N]  logically present (False = deleted)
    head: jax.Array         # int32[B] bucket heads
    cursor: jax.Array       # int32    bump allocator (next free node id)
    flushes: jax.Array      # int32    persistence accounting
    fences: jax.Array


def make_state(capacity: int, n_buckets: int) -> HashMapState:
    return HashMapState(
        key=jnp.zeros(capacity, jnp.int32),
        val=jnp.zeros(capacity, jnp.int32),
        nxt=jnp.zeros(capacity, jnp.int32),
        live=jnp.zeros(capacity, jnp.bool_),
        head=jnp.zeros(n_buckets, jnp.int32),
        cursor=jnp.int32(1),
        flushes=jnp.int32(0),
        fences=jnp.int32(0),
    )


def _mix(x: jax.Array) -> jax.Array:
    """splitmix-style 32-bit hash (jit-friendly)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def bucket_of(k: jax.Array, n_buckets: int) -> jax.Array:
    return (_mix(k) % jnp.uint32(n_buckets)).astype(jnp.int32)


# --------------------------------------------------------------------- #
# traversal (the journey — zero persistence work)                        #
# --------------------------------------------------------------------- #
def _find(state: HashMapState, k: jax.Array, n_buckets: int):
    """Walk the chain; returns (node_id_or_0, steps)."""
    b = bucket_of(k, n_buckets)

    def cond(c):
        node, _ = c
        return (node != NULL) & (state.key[node] != k)

    def body(c):
        node, steps = c
        return state.nxt[node], steps + 1

    node, steps = jax.lax.while_loop(cond, body, (state.head[b], jnp.int32(0)))
    return node, steps


@partial(jax.jit, static_argnames="n_buckets")
def lookup(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batched lookup: returns (found bool[batch], vals int32[batch])."""
    def one(k):
        node, _ = _find(state, k, n_buckets)
        found = (node != NULL) & state.live[node]
        return found, jnp.where(found, state.val[node], 0)

    return jax.vmap(one)(ks)


# --------------------------------------------------------------------- #
# updates (the destination — O(1) flushes, 2 fences per op)              #
# --------------------------------------------------------------------- #
@partial(jax.jit, static_argnames="n_buckets")
def insert(state: HashMapState, ks: jax.Array, vs: jax.Array,
           n_buckets: int):
    """Batched insert; scan order is the linearization order.

    Returns (state', inserted bool[batch]).  A key already present (live)
    fails; a dead node with the key is resurrected in place (its value CAS
    is a single-word modification, same persistence cost).
    """

    def step(st: HashMapState, kv):
        k, v = kv
        node, _ = _find(st, k, n_buckets)
        exists_live = (node != NULL) & st.live[node]

        def do_resurrect(st):
            # value write + unmark: flush the node line, fence, return fence
            return st._replace(
                val=st.val.at[node].set(v),
                live=st.live.at[node].set(True),
                flushes=st.flushes + 1,
                fences=st.fences + 2,
            )

        def do_fresh(st):
            b = bucket_of(k, n_buckets)
            nid = st.cursor
            st = st._replace(
                key=st.key.at[nid].set(k),
                val=st.val.at[nid].set(v),
                nxt=st.nxt.at[nid].set(st.head[b]),
                live=st.live.at[nid].set(True),
                # NVTraverse commit: flush(node) ; fence ; publish ;
                # flush(head) ; fence        — 2 flushes, 2 fences, O(1).
                head=st.head.at[b].set(nid),
                cursor=st.cursor + 1,
                flushes=st.flushes + 2,
                fences=st.fences + 2,
            )
            return st

        def do_insert(st):
            dead_here = (node != NULL) & ~st.live[node]
            return jax.lax.cond(dead_here, do_resurrect, do_fresh, st)

        st = jax.lax.cond(exists_live, lambda s: s, do_insert, st)
        return st, ~exists_live

    state, ok = jax.lax.scan(step, state, (ks.astype(jnp.int32),
                                           vs.astype(jnp.int32)))
    return state, ok


@partial(jax.jit, static_argnames="n_buckets")
def delete(state: HashMapState, ks: jax.Array, n_buckets: int):
    """Batched delete via logical marking (mark-before-disconnect)."""

    def step(st: HashMapState, k):
        node, _ = _find(st, k, n_buckets)
        present = (node != NULL) & st.live[node]

        def do(st):
            return st._replace(
                live=st.live.at[node].set(False),
                flushes=st.flushes + 1,   # flush the marked line
                fences=st.fences + 2,     # pre-CAS fence + return fence
            )

        st = jax.lax.cond(present, do, lambda s: s, st)
        return st, present

    state, ok = jax.lax.scan(step, state, ks.astype(jnp.int32))
    return state, ok


@partial(jax.jit, static_argnames="n_buckets")
def chain_stats(state: HashMapState, n_buckets: int):
    """Max/mean chain length — the traversal cost the paper's transform
    makes persistence-free."""
    def walk(b):
        def cond(c):
            node, steps = c
            return (node != NULL) & (steps < state.key.shape[0])

        def body(c):
            node, steps = c
            return state.nxt[node], steps + 1

        _, steps = jax.lax.while_loop(cond, body, (state.head[b], jnp.int32(0)))
        return steps

    lens = jax.vmap(walk)(jnp.arange(n_buckets, dtype=jnp.int32))
    return lens.max(), lens.mean()
