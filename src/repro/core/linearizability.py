"""Linearizability and durable-linearizability checking (set semantics).

Durable linearizability [26] (paper §2): an execution history with crash
events is durably linearizable if, after removing crash events, the history
is linearizable — completed operations may not be lost, in-flight operations
are all-or-nothing, and taken-effect operations have their dependencies
taken effect.

For set ADTs (insert/delete/find keyed by ``k``), operations on distinct
keys commute, so a history is (durably) linearizable iff each per-key
sub-history is — which keeps the Wing & Gong style search tractable.  Per
key we search for a linearization of

    all completed operations  ∪  any subset of crash-pending operations

that (a) respects real-time order, (b) matches every completed operation's
return value under sequential set semantics, and (c) ends in the observed
post-recovery membership.  Pending ops carry no return-value constraint but
must linearize after their invocation.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .scheduler import OpRecord

INF = float("inf")


def _sem(op: str, present: bool) -> Tuple[bool, bool]:
    """Sequential set semantics: returns (ret, present')."""
    if op == "insert":
        return (not present), True
    if op == "delete":
        return present, False
    if op == "find":
        return present, present
    raise ValueError(op)


def _check_key(ops: Sequence[OpRecord], init_present: bool,
               final_present: Optional[bool]) -> bool:
    """Search for a valid linearization of one key's sub-history.

    ``final_present`` is the observed post-recovery membership (None when
    there was no crash — then only return values are checked).
    """
    completed = [o for o in ops if o.completed]
    pending = [o for o in ops if not o.completed and o.invoked]
    n_c, n_p = len(completed), len(pending)

    inv = [o.invoke_step for o in completed] + [o.invoke_step for o in pending]
    rsp = [o.respond_step for o in completed] + [INF] * n_p
    kinds = [o.op for o in completed] + [o.op for o in pending]
    rets = [bool(o.result) for o in completed] + [None] * n_p
    n = n_c + n_p

    @lru_cache(maxsize=None)
    def dfs(used_mask: int, present: bool) -> bool:
        if used_mask == (1 << n) - 1:
            return final_present is None or present == final_present
        # completion check: all completed ops must eventually be used;
        # pending ops may be dropped — allow "stop" if only pending remain.
        only_pending_left = all(
            (used_mask >> i) & 1 for i in range(n_c))
        if only_pending_left and (final_present is None
                                  or present == final_present):
            return True
        for i in range(n):
            if (used_mask >> i) & 1:
                continue
            # real-time: i may linearize now only if no unused op responded
            # strictly before i's invocation.
            ok = True
            for j in range(n):
                if j != i and not (used_mask >> j) & 1 and rsp[j] < inv[i]:
                    ok = False
                    break
            if not ok:
                continue
            ret, nxt = _sem(kinds[i], present)
            if rets[i] is not None and ret != rets[i]:
                continue
            if dfs(used_mask | (1 << i), nxt):
                return True
        return False

    return dfs(0, init_present)


def group_by_key(records: Iterable[OpRecord]) -> Dict[int, List[OpRecord]]:
    out: Dict[int, List[OpRecord]] = {}
    for r in records:
        out.setdefault(r.args[0], []).append(r)
    return out


def check_linearizable(records: Sequence[OpRecord],
                       initial_keys: Iterable[int] = ()) -> bool:
    """Crash-free check: all ops completed; return values must linearize."""
    initial = set(initial_keys)
    for key, ops in group_by_key(records).items():
        if not _check_key(ops, key in initial, None):
            return False
    return True


def check_durably_linearizable(records: Sequence[OpRecord],
                               recovered_keys: Iterable[int],
                               initial_keys: Iterable[int] = (),
                               universe: Optional[Iterable[int]] = None) -> bool:
    """Post-crash check against the recovered abstract state.

    ``recovered_keys``: keys present after crash + recovery.
    ``universe``: all keys that must be explained (defaults to keys touched
    by ops ∪ recovered ∪ initial — a recovered key nobody ever inserted is
    a corruption and fails).
    """
    initial = set(initial_keys)
    recovered = set(recovered_keys)
    by_key = group_by_key(records)
    keys = set(by_key) | recovered | initial
    if universe is not None:
        keys |= set(universe)
    for key in keys:
        ops = by_key.get(key, [])
        if not _check_key(ops, key in initial, key in recovered):
            return False
    return True


def check_queue_durably_linearizable(records: Sequence[OpRecord],
                                     recovered: Sequence[int],
                                     initial: Sequence[int] = ()) -> bool:
    """FIFO-queue variant: search for a linearization of completed ops ∪
    subset(pending) that matches all completed return values and ends with
    the recovered queue contents (``None`` recovered ⇒ return-values only).

    Enqueue values are assumed unique per history (the tests enforce it),
    which keeps the state space tiny.
    """
    recs = [o for o in records if o.invoked]
    n = len(recs)
    inv = [o.invoke_step for o in recs]
    rsp = [o.respond_step if o.completed else INF for o in recs]
    target = None if recovered is None else tuple(recovered)
    memo: dict = {}

    def dfs(used_mask: int, state: tuple) -> bool:
        key = (used_mask, state)
        if key in memo:
            return memo[key]
        done_completed = all(
            (used_mask >> i) & 1 for i in range(n) if recs[i].completed)
        if done_completed and (target is None or state == target):
            memo[key] = True
            return True
        ok = False
        for i in range(n):
            if (used_mask >> i) & 1:
                continue
            if any(j != i and not (used_mask >> j) & 1 and rsp[j] < inv[i]
                   for j in range(n)):
                continue
            o = recs[i]
            if o.op == "enqueue":
                nxt_state = state + (o.args[0],)
                ret = True
            elif o.op == "dequeue":
                if state:
                    ret, nxt_state = state[0], state[1:]
                else:
                    ret, nxt_state = None, state
            else:
                raise ValueError(o.op)
            if o.completed and o.result != ret:
                continue
            if dfs(used_mask | (1 << i), nxt_state):
                ok = True
                break
        memo[key] = ok
        return ok

    return dfs(0, tuple(initial))


def check_stack_durably_linearizable(records: Sequence[OpRecord],
                                     recovered: Sequence[int],
                                     initial: Sequence[int] = ()) -> bool:
    """LIFO variant of the queue checker.  ``recovered``: top-first."""
    recs = [o for o in records if o.invoked]
    n = len(recs)
    inv = [o.invoke_step for o in recs]
    rsp = [o.respond_step if o.completed else INF for o in recs]
    # state: bottom..top tuple; recovered list is top-first
    target = None if recovered is None else tuple(reversed(recovered))
    memo: dict = {}

    def dfs(used_mask: int, state: tuple) -> bool:
        key = (used_mask, state)
        if key in memo:
            return memo[key]
        done_completed = all(
            (used_mask >> i) & 1 for i in range(n) if recs[i].completed)
        if done_completed and (target is None or state == target):
            memo[key] = True
            return True
        ok = False
        for i in range(n):
            if (used_mask >> i) & 1:
                continue
            if any(j != i and not (used_mask >> j) & 1 and rsp[j] < inv[i]
                   for j in range(n)):
                continue
            o = recs[i]
            if o.op == "push":
                ret, nxt_state = True, state + (o.args[0],)
            elif o.op == "pop":
                if state:
                    ret, nxt_state = state[-1], state[:-1]
                else:
                    ret, nxt_state = None, state
            else:
                raise ValueError(o.op)
            if o.completed and o.result != ret:
                continue
            if dfs(used_mask | (1 << i), nxt_state):
                ok = True
                break
        memo[key] = ok
        return ok

    return dfs(0, tuple(reversed(list(initial))))


def explain_failure(records: Sequence[OpRecord],
                    recovered_keys: Iterable[int],
                    initial_keys: Iterable[int] = ()) -> List[str]:
    """Diagnostic: list the keys whose sub-history cannot linearize."""
    initial, recovered = set(initial_keys), set(recovered_keys)
    by_key = group_by_key(records)
    bad = []
    for key in set(by_key) | recovered | initial:
        ops = by_key.get(key, [])
        if not _check_key(ops, key in initial, key in recovered):
            ev = [(o.op, o.invoke_step, o.respond_step, o.result) for o in ops]
            bad.append(f"key={key} recovered={key in recovered} ops={ev}")
    return bad
