"""Lock-free hash table in traversal form (David et al. [18] style).

Fixed array of buckets, each bucket an independent Harris-list segment with
its own head/tail sentinels.  The core tree is rooted at the table object:
root → bucket heads → chains (the paper, §3: "hash tables have a core-tree
structure").  ``findEntry`` hashes the key and returns the bucket head —
the bucket array is immutable after construction, so findEntry performs no
mutable shared reads.

All traversal/critical/Protocol-1 behavior is inherited from
:class:`HarrisList`; only entry selection, enumeration and recovery differ.
The paper's observation that contention is per-bucket (and hence tiny for
large tables) is what makes the NVTraverse version beat link-and-persist on
the hash-table workloads (§5.3) — reproduced in the benchmark cost model.
"""
from __future__ import annotations

from typing import List

from .harris_list import KEY, NXT, VAL, KEY_MAX, KEY_MIN, HarrisList
from .instr import NULLPTR, OpContext, pack
from .pmem import PMem


def _splitmix(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return x ^ (x >> 31)


class HashTable(HarrisList):
    def __init__(self, mem: PMem, *, n_buckets: int = 16):
        # NOTE: deliberately not calling HarrisList.__init__ — the table has
        # per-bucket sentinels instead of a single head/tail pair.
        self.mem = mem
        self.use_orig_parent = False
        self.n_buckets = n_buckets
        self.heads: List[int] = []
        self.tails: List[int] = []
        for _ in range(n_buckets):
            tail = mem.alloc(self.NODE_WORDS)
            head = mem.alloc(self.NODE_WORDS)
            mem.write(tail + KEY, KEY_MAX)
            mem.write(tail + NXT, NULLPTR)
            mem.write(head + KEY, KEY_MIN)
            mem.write(head + NXT, pack(tail, 0))
            self.heads.append(head)
            self.tails.append(tail)
        mem.persist_all()
        self._head_index = {h: i for i, h in enumerate(self.heads)}

    # the table uses modulo of a mixed hash, like the paper's general
    # implementation (the bit-mask trick of David et al. is noted in §5.3)
    def bucket_of(self, key: int) -> int:
        return _splitmix(int(key)) % self.n_buckets

    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        return self.heads[self.bucket_of(args[0])]

    def _segment_head(self, entry: int) -> int:
        # entry is always a bucket head here (findEntry returns heads only)
        return entry

    # ------------------------------------------------------------------ #
    def disconnect(self) -> None:
        for head in self.heads:
            self.head = head          # reuse the list trimmer per bucket
            HarrisList.disconnect(self)
        del self.head

    def _walk_bucket(self, image, head) -> dict:
        self.head = head
        self.tail = self.tails[self._head_index[head]]
        try:
            return HarrisList._walk(self, image)
        finally:
            del self.head, self.tail

    def contents(self) -> dict:
        out = {}
        for h in self.heads:
            out.update(self._walk_bucket(self.mem.volatile, h))
        return out

    def persistent_contents(self) -> dict:
        out = {}
        for h in self.heads:
            out.update(self._walk_bucket(self.mem.persistent, h))
        return out

    def check_integrity(self, *, require_unmarked: bool = False) -> None:
        for i, h in enumerate(self.heads):
            self.head = h
            self.tail = self.tails[i]
            try:
                HarrisList.check_integrity(
                    self, require_unmarked=require_unmarked)
                # every key in this bucket must hash here
                for k in HarrisList._walk(self, self.mem.volatile):
                    assert self.bucket_of(k) == i, "key in wrong bucket"
            finally:
                del self.head, self.tail
