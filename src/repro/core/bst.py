"""Lock-free external (leaf-oriented) BST in traversal form.

Modeled on Ellen et al. [20] (one of the paper's evaluated structures),
adapted to the simulator's word-addressed memory: instead of Ellen's
Info-descriptor flag/mark protocol, each internal node stores BOTH child
pointers in a single 64-bit word together with the deletion mark:

    child_word = (mark_dir << 62) | (left_addr << 31) | right_addr

so that *marking is a single CAS that atomically makes the node immutable*
(every subsequent CAS expects an unmarked word and fails), exactly
Definition 1.  The mark encodes which child is being deleted, so the mark
alone uniquely determines the legal disconnection instruction
(Property 5(2)): the parent's child slot is swung to the marked node's
*survivor*, resolved through any chain of marked descendants
(Property 5(3): disconnection order is irrelevant because resolution is
confluent).  This packing plays the role of Ellen's descriptors and is
recorded in DESIGN.md as a word-model adaptation.

Traversal properties: routing uses only the immutable ``key`` (Property
4(3)); the stopping condition is the immutable leaf flag (4(2)); marks do
not affect routing at all, so traversal stability (4(5)) holds trivially;
the returned nodes are the path suffix [grandparent, parent, leaf] and the
extra ``parents=[great-grandparent]`` serves the Lemma 4.1 ensureReachable
optimization.

Layout per node (one line): ``[key, value, is_leaf, child_word]``.
Sentinels (Ellen's ∞₁/∞₂): S2(key=+∞) → left S1(key=+∞) → left leaf(−∞);
every operable leaf therefore has a parent and grandparent.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .instr import OpContext
from .pmem import PMem
from .traversal import TraversalDS, TraverseResult

KEY, VAL, LEAF, CW = 0, 1, 2, 3

KEY_MIN = -(1 << 40)
KEY_MAX = (1 << 40)        # Ellen's inf1
KEY_MAX2 = (1 << 40) + 1   # Ellen's inf2 (root sentinel)

# child_word packing: 30 bits per child address, 2 mark bits (fits int64)
_ADDR_BITS = 30
_ADDR_MASK = (1 << _ADDR_BITS) - 1
MARK_NONE, MARK_LEFT, MARK_RIGHT = 0, 1, 2


def pack_cw(left: int, right: int, mark: int = MARK_NONE) -> int:
    assert 0 <= left <= _ADDR_MASK and 0 <= right <= _ADDR_MASK
    return (mark << (2 * _ADDR_BITS)) | (left << _ADDR_BITS) | right


def unpack_cw(w: int) -> tuple[int, int, int]:
    return ((w >> _ADDR_BITS) & _ADDR_MASK, w & _ADDR_MASK,
            w >> (2 * _ADDR_BITS))


def cw_is_marked(w: int) -> bool:
    return (w >> (2 * _ADDR_BITS)) != MARK_NONE


class ExternalBST(TraversalDS):
    NODE_WORDS = 4

    def __init__(self, mem: PMem):
        super().__init__(mem)
        leaf_min = self._make_leaf_raw(KEY_MIN, 0)
        leaf_max1 = self._make_leaf_raw(KEY_MAX, 0)
        leaf_max2 = self._make_leaf_raw(KEY_MAX2, 0)
        self.s1 = mem.alloc(self.NODE_WORDS)
        mem.write(self.s1 + KEY, KEY_MAX)
        mem.write(self.s1 + CW, pack_cw(leaf_min, leaf_max1))
        self.s2 = mem.alloc(self.NODE_WORDS)
        mem.write(self.s2 + KEY, KEY_MAX2)
        mem.write(self.s2 + CW, pack_cw(self.s1, leaf_max2))
        mem.persist_all()

    def _make_leaf_raw(self, k: int, v: int) -> int:
        a = self.mem.alloc(self.NODE_WORDS)
        self.mem.write(a + KEY, k)
        self.mem.write(a + VAL, v)
        self.mem.write(a + LEAF, 1)
        return a

    # ------------------------------------------------------------------ #
    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        return self.s2

    def traverse(self, ctx: OpContext, entry: int, op: str, args) -> TraverseResult:
        k = args[0]
        ggp = entry          # great-grandparent (for ensureReachable)
        gp = entry           # grandparent
        p = entry            # parent
        node = entry
        # descend to a leaf; route only by immutable keys (Property 4(3))
        while not ctx.read(node + LEAF, immutable=True):
            ggp, gp, p = gp, p, node
            w = ctx.read(node + CW)
            left, right, _mark = unpack_cw(w)
            node = left if k < ctx.read(node + KEY, immutable=True) else right
        return TraverseResult(nodes=[gp, p, node], parents=[ggp],
                              info=None)

    def ensure_reachable_addrs(self, tr: TraverseResult) -> List[int]:
        return [p + CW for p in tr.parents]

    def read_field_addrs(self, tr: TraverseResult) -> List[int]:
        return [n + CW for n in tr.nodes]

    # ------------------------------------------------------------------ #
    def _resolve(self, ctx: OpContext, addr: int) -> int:
        """Follow survivor chains through marked internal nodes."""
        hops = 0
        while True:
            if ctx.read(addr + LEAF, immutable=True):
                return addr
            w = ctx.read(addr + CW)
            left, right, mark = unpack_cw(w)
            if mark == MARK_NONE:
                return addr
            addr = right if mark == MARK_LEFT else left
            hops += 1
            assert hops < 10_000, "marked chain runaway"

    def _trim(self, ctx: OpContext, parent: int, child: int) -> None:
        """Physically disconnect a marked ``child`` from an unmarked
        ``parent`` (the unique Property 5(2) instruction) — the helping
        step that replaces Ellen's descriptor-based helping and guarantees
        progress when a marked node's physical deletion was interrupted."""
        w = ctx.read(parent + CW)
        l, r, m = unpack_cw(w)
        if m != MARK_NONE or (l != child and r != child):
            return
        surv = self._resolve(ctx, child)
        nw = pack_cw(surv, r) if l == child else pack_cw(l, surv)
        ctx.cas(parent + CW, w, nw)

    def critical(self, ctx: OpContext, tr: TraverseResult, op: str, args):
        gp, p, leaf = tr.nodes
        ggp = tr.parents[0]
        k = args[0]
        if op == "find":
            found = ctx.read(leaf + KEY, immutable=True) == k
            return False, found
        if op == "insert":
            return self._insert_critical(ctx, ggp, gp, p, leaf, args)
        if op == "delete":
            return self._delete_critical(ctx, ggp, gp, p, leaf, args)
        raise ValueError(op)

    def _insert_critical(self, ctx, ggp, gp, p, leaf, args):
        k, v = args
        leaf_key = ctx.read(leaf + KEY, immutable=True)
        if leaf_key == k:
            return False, False  # already present
        pw = ctx.read(p + CW)
        pl, pr, pmark = unpack_cw(pw)
        if pmark != MARK_NONE:
            self._trim(ctx, gp, p)   # help finish the pending delete
            return True, False
        if pl != leaf and pr != leaf:
            return True, False       # leaf displaced: retry
        # build replacement subtree: internal node with the two leaves
        new_leaf = ctx.alloc(self.NODE_WORDS)
        ctx.write_local(new_leaf + KEY, k)
        ctx.write_local(new_leaf + VAL, v)
        ctx.write_local(new_leaf + LEAF, 1)
        internal = ctx.alloc(self.NODE_WORDS)
        ctx.write_local(internal + KEY, max(k, leaf_key))
        ctx.write_local(internal + LEAF, 0)
        if k < leaf_key:
            ctx.write_local(internal + CW, pack_cw(new_leaf, leaf))
        else:
            ctx.write_local(internal + CW, pack_cw(leaf, new_leaf))
        new_pw = pack_cw(internal, pr) if pl == leaf else pack_cw(pl, internal)
        ok = ctx.cas(p + CW, pw, new_pw)
        return (False, True) if ok else (True, False)

    def _delete_critical(self, ctx, ggp, gp, p, leaf, args):
        k = args[0]
        if ctx.read(leaf + KEY, immutable=True) != k:
            return False, False  # no such key
        if k in (KEY_MIN, KEY_MAX, KEY_MAX2):
            return False, False  # sentinels are not deletable
        pw = ctx.read(p + CW)
        pl, pr, pmark = unpack_cw(pw)
        if pmark != MARK_NONE:
            self._trim(ctx, gp, p)
            return True, False
        if pl != leaf and pr != leaf:
            return True, False
        gw = ctx.read(gp + CW)
        gl, gr, gmark = unpack_cw(gw)
        if gmark != MARK_NONE:
            self._trim(ctx, ggp, gp)  # help finish the pending delete above
            return True, False
        if gl != p and gr != p:
            return True, False
        # logical delete: mark the parent (single CAS, atomically immutable)
        mark = MARK_LEFT if pl == leaf else MARK_RIGHT
        if not ctx.cas(p + CW, pw, pack_cw(pl, pr, mark)):
            return True, False
        # physical delete: the unique disconnection at the grandparent
        survivor = self._resolve(ctx, p)
        new_gw = pack_cw(survivor, gr) if gl == p else pack_cw(gl, survivor)
        ctx.cas(gp + CW, gw, new_gw)  # failure is fine: someone else trims
        return False, True

    # ------------------------------------------------------------------ #
    # Supplement 1 / recovery                                             #
    # ------------------------------------------------------------------ #
    def disconnect(self) -> None:
        mem = self.mem
        changed = True
        while changed:
            changed = False
            stack = [self.s2]
            while stack:
                node = stack.pop()
                if int(mem.volatile[node + LEAF]):
                    continue
                w = int(mem.volatile[node + CW])
                left, right, mark = unpack_cw(w)
                if mark != MARK_NONE:
                    continue  # will be trimmed via its parent
                new_l = self._resolve_raw(left)
                new_r = self._resolve_raw(right)
                if (new_l, new_r) != (left, right):
                    mem.cas(node + CW, w, pack_cw(new_l, new_r))
                    mem.flush(node + CW)
                    changed = True
                stack.extend([new_l, new_r])
        mem.fence()

    def _resolve_raw(self, addr: int) -> int:
        mem = self.mem
        while True:
            if int(mem.volatile[addr + LEAF]):
                return addr
            l, r, mark = unpack_cw(int(mem.volatile[addr + CW]))
            if mark == MARK_NONE:
                return addr
            addr = r if mark == MARK_LEFT else l

    # ------------------------------------------------------------------ #
    def _walk(self, image: np.ndarray) -> dict:
        out = {}
        stack = [self.s2]
        seen = set()
        while stack:
            node = stack.pop()
            if node in seen:
                raise AssertionError("cycle in BST")
            seen.add(node)
            if int(image[node + LEAF]):
                k = int(image[node + KEY])
                if k not in (KEY_MIN, KEY_MAX, KEY_MAX2):
                    out[k] = int(image[node + VAL])
                continue
            left, right, mark = unpack_cw(int(image[node + CW]))
            if mark == MARK_LEFT:       # left child logically deleted
                stack.append(right)
            elif mark == MARK_RIGHT:
                stack.append(left)
            else:
                stack.extend([left, right])
        return out

    def contents(self) -> dict:
        return self._walk(self.mem.volatile)

    def persistent_contents(self) -> dict:
        return self._walk(self.mem.persistent)

    def check_integrity(self, *, require_unmarked: bool = False) -> None:
        image = self.mem.volatile

        def rec(node, lo, hi, depth):
            assert depth < 10_000, "BST depth runaway"
            k = int(image[node + KEY])
            if int(image[node + LEAF]):
                assert lo <= k <= hi, "leaf key out of range"
                return
            left, right, mark = unpack_cw(int(image[node + CW]))
            if require_unmarked:
                assert mark == MARK_NONE, "marked node survived recovery"
            # search-tree invariant on live edges: left keys < k ≤ right keys
            if mark != MARK_LEFT:    # left edge live
                rec(left, lo, k - 1, depth + 1)
            if mark != MARK_RIGHT:   # right edge live
                rec(right, k, hi, depth + 1)

        rec(self.s2, KEY_MIN, KEY_MAX2, 0)
