"""Lock-free skiplist in traversal form (Michael [34] style).

Paper §3, Property 2: "a skiplist can be a traversal data structure since
... only a linked list at the bottom level holds all the data, while the
rest of the nodes and edges simply serve as a way to access the linked list
faster".  Accordingly:

  * the **core tree** is the bottom-level Harris list (persistent);
  * the **index towers are auxiliary and volatile** — they live outside the
    persistent pool, are consulted only by ``findEntry`` to pick a shortcut
    entry node, and are *reconstructed* after a crash (the optional
    Property 2 rebuild function, implemented in :meth:`rebuild_index`).

Tower heights are derived deterministically from the key hash, so the
rebuilt index after recovery is identical to the pre-crash index — which
also makes crash tests deterministic.

``findEntry`` may return a stale or concurrently-marked shortcut node; the
inherited traversal falls back to the bottom head in that case (see
``HarrisList.traverse``), preserving correctness with zero persistence cost
for the index.
"""
from __future__ import annotations

import bisect
from typing import Dict, List

from .harris_list import KEY, NXT, HarrisList
from .hash_table import _splitmix
from .instr import OpContext, is_marked
from .pmem import PMem
from .traversal import TraverseResult


def tower_height(key: int, max_level: int) -> int:
    """Deterministic promotion: geometric(1/2) from the key hash."""
    h = _splitmix(int(key) ^ 0xA5A5_5A5A)
    level = 1
    while (h & 1) and level < max_level:
        level += 1
        h >>= 1
    return level


def tower_heights(keys, max_level: int):
    """Vectorized twin of :func:`tower_height` for whole key batches —
    the batch-parallel ordered engine (:mod:`repro.core.ordered`) builds
    its volatile tower index with one call instead of a Python loop per
    key.  Bit-identical to the scalar promotion, so an index rebuilt
    after a crash from the recovered bottom list is identical to the
    pre-crash one whichever code path built it.

    >>> import numpy as np
    >>> tower_heights(np.arange(64), 8).tolist() == \\
    ...     [tower_height(k, 8) for k in range(64)]
    True
    """
    import numpy as np
    x = (np.asarray(keys, np.int64).astype(np.uint64)
         ^ np.uint64(0xA5A5_5A5A))
    with np.errstate(over="ignore"):          # splitmix wraps mod 2**64
        x = x + np.uint64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
    level = np.ones(x.shape, np.int64)
    alive = np.ones(x.shape, np.bool_)
    for _ in range(max_level - 1):
        alive &= (x & np.uint64(1)).astype(bool) & (level < max_level)
        level += alive
        x = x >> np.uint64(1)
    return level.astype(np.int32)


class SkipList(HarrisList):
    def __init__(self, mem: PMem, *, max_level: int = 8):
        super().__init__(mem)
        self.max_level = max_level
        # volatile auxiliary index: level -> sorted list of (key, node_addr)
        self.index: Dict[int, List[tuple]] = {l: [] for l in
                                              range(2, max_level + 1)}

    # ------------------------------------------------------------------ #
    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        """Descend the volatile towers to the closest shortcut with
        key strictly below the target; fall back to the bottom head."""
        k = args[0]
        entry = self.head
        best = None
        for level in range(self.max_level, 1, -1):
            lst = self.index.get(level, ())
            i = bisect.bisect_left(lst, (k, -1)) - 1
            if i >= 0:
                key, addr = lst[i]
                # validity probe (a shared read; a stale/marked shortcut is
                # tolerated — the traversal falls back)
                if not is_marked(ctx.read(addr + NXT)):
                    best = (key, addr)
                    break
        if best is not None:
            entry = best[1]
        return entry

    # traverse/critical/Protocol 1 inherited from HarrisList.

    def post_insert(self, key: int, addr: int) -> None:
        """Volatile index maintenance after a successful insert."""
        h = tower_height(key, self.max_level)
        for level in range(2, h + 1):
            lst = self.index[level]
            i = bisect.bisect_left(lst, (key, -1))
            if i >= len(lst) or lst[i][0] != key:
                lst.insert(i, (key, addr))

    def post_delete(self, key: int) -> None:
        for level in range(2, self.max_level + 1):
            lst = self.index[level]
            i = bisect.bisect_left(lst, (key, -1))
            if i < len(lst) and lst[i][0] == key:
                del lst[i]

    def critical(self, ctx: OpContext, tr: TraverseResult, op: str, args):
        restart, val = super().critical(ctx, tr, op, args)
        if not restart and val:
            if op == "insert":
                # locate the published node (volatile bookkeeping only — a
                # stale entry is tolerated by the findEntry validity probe).
                addr = self._addr_of(args[0])
                if addr is not None:
                    self.post_insert(args[0], addr)
            elif op == "delete":
                self.post_delete(args[0])
        return restart, val

    # ------------------------------------------------------------------ #
    def rebuild_index(self) -> None:
        """Property 2's optional reconstruction function — run on recovery.

        One :meth:`~repro.core.harris_list.HarrisList.sorted_snapshot`
        walk re-promotes every live key deterministically (the old
        per-key ``_addr_of`` rescan was O(n²) and rotted the harness on
        large recoveries); the resulting towers are bit-identical to the
        incrementally maintained pre-crash index."""
        self.index = {l: [] for l in range(2, self.max_level + 1)}
        for key, addr in self.sorted_snapshot():
            self.post_insert(key, addr)

    def _addr_of(self, key: int):
        image = self.mem.volatile
        curr = (int(image[self.head + NXT])) >> 1
        while curr and curr != self.tail:
            w = int(image[curr + NXT])
            if not (w & 1) and int(image[curr + KEY]) == key:
                return curr
            curr = w >> 1
        return None

    def disconnect(self) -> None:
        HarrisList.disconnect(self)
        self.rebuild_index()
