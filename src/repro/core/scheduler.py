"""Controlled-interleaving scheduler for concurrency + crash testing.

The paper's correctness claim (Theorem 4.2: every NVTraverse data structure
is durably linearizable) quantifies over all interleavings, all crash points
and all implicit-eviction choices.  This module provides the adversary:

  * each operation runs in its own (real) thread, but every shared-memory
    instruction gates on the scheduler, which grants exactly one instruction
    at a time — interleavings are deterministic given a seed;
  * a crash can be injected at any global instruction boundary; in-flight
    operations become *pending* (no response), the volatile view is lost,
    and a chosen subset of unpersisted lines is evicted to NVRAM
    (:meth:`PMem.crash`);
  * the full invoke/respond history is recorded in real-time order for the
    linearizability checker.

This is test infrastructure (the paper's "threads"), not the data path; the
JAX-native batched structures are exercised separately.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from .instr import CrashInterrupt
from .policies import Policy
from .traversal import TraversalDS, run_operation


@dataclasses.dataclass
class OpRecord:
    opid: int
    op: str
    args: tuple
    invoke_step: Optional[int] = None    # global step of first instruction
    respond_step: Optional[int] = None   # global step of completion
    result: Any = None

    @property
    def completed(self) -> bool:
        return self.respond_step is not None

    @property
    def invoked(self) -> bool:
        return self.invoke_step is not None


class _OpThread:
    def __init__(self, ds: TraversalDS, policy: Policy, rec: OpRecord):
        self.rec = rec
        self._go = threading.Event()
        self._ready = threading.Event()
        self._crash = False
        self.alive = True
        self.error: Optional[BaseException] = None

        def hook(kind: str) -> None:
            self._ready.set()
            self._go.wait()
            self._go.clear()
            if self._crash:
                raise CrashInterrupt()

        def body() -> None:
            try:
                self.rec.result = run_operation(
                    ds, policy, rec.op, rec.args,
                    step_hook=hook, opid=rec.opid, max_restarts=10_000)
            except CrashInterrupt:
                pass
            except BaseException as e:  # surfaced by the scheduler
                self.error = e
            finally:
                self.alive = False
                self._ready.set()

        self.thread = threading.Thread(target=body, daemon=True)

    def start(self) -> None:
        self.thread.start()
        self._ready.wait()   # reaches first instruction boundary (or ends)
        self._ready.clear()

    def step(self) -> None:
        """Grant exactly one instruction; returns when the thread reaches
        the next boundary or terminates."""
        self._go.set()
        self._ready.wait()
        self._ready.clear()

    def kill(self) -> None:
        self._crash = True
        if self.alive:
            self._go.set()
            self.thread.join(timeout=10)


class Interleaver:
    """Runs a batch of operations under a seeded random interleaving."""

    def __init__(self, ds: TraversalDS, policy: Policy,
                 ops: Sequence[tuple], *, seed: int = 0):
        self.ds = ds
        self.policy = policy
        self.records = [OpRecord(i, op, tuple(args))
                        for i, (op, args) in enumerate(ops)]
        self._rng = np.random.default_rng(seed)
        self.global_step = 0
        self.crashed = False

    def run(self, *, crash_at: Optional[int] = None,
            evict: Any = "random", p_evict: float = 0.5,
            max_steps: int = 2_000_000) -> List[OpRecord]:
        threads = [_OpThread(self.ds, self.policy, r) for r in self.records]
        for t in threads:
            t.start()
        live = [t for t in threads if t.alive]
        try:
            while live and self.global_step < max_steps:
                if crash_at is not None and self.global_step >= crash_at:
                    self._crash(threads, evict, p_evict)
                    return self.records
                t = live[self._rng.integers(len(live))]
                if t.rec.invoke_step is None:
                    t.rec.invoke_step = self.global_step
                t.step()
                self.global_step += 1
                if not t.alive:
                    if t.error is not None:
                        raise t.error
                    t.rec.respond_step = self.global_step
                    live.remove(t)
            if live:
                raise RuntimeError("interleaver exceeded max_steps")
            return self.records
        finally:
            for t in threads:
                t.kill()

    def _crash(self, threads, evict, p_evict) -> None:
        for t in threads:
            t.kill()
        self.ds.mem.crash(evict=evict, p_evict=p_evict)
        self.crashed = True
