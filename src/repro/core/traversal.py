"""Algorithm 1 / Algorithm 2: the traversal-data-structure operation layout.

A traversal data structure exposes exactly three shared-memory methods
(Property 3) which are always called in order:

    findEntry(root, input) -> entry
    traverse(entry, input) -> (parents, nodes)     # read-only, Property 4
    critical(nodes, input) -> (restart, value)     # disconnections per Prop 5

:' func:`run_operation` drives the retry loop.  Under the NVTraverse policy it
additionally runs Protocol 1 between traverse and critical (Algorithm 2):

    ensureReachable(nodes.first())   # flush the linking parent pointer
    makePersistent(nodes)            # flush all fields traverse read + fence

``traverse`` returns a :class:`TraverseResult`:

  * ``nodes``   — the suffix of the traversed path handed to critical
                  (e.g. Harris list: left, marked…, right);
  * ``parents`` — the extra node(s) returned for the Lemma 4.1
                  ensureReachable *optimization* (the current parent of the
                  first returned node), when the structure does not maintain
                  an original-parent field; structures that do maintain the
                  Supplement 2 field instead expose ``original_parent_addr``.

Subclasses enumerate, per returned node, the addresses of the fields the
traversal read (``read_field_addrs``) so makePersistent can flush exactly
those (§4.1 Protocol 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

from .instr import OpContext, Phase
from .pmem import PMem
from .policies import Policy


@dataclasses.dataclass
class TraverseResult:
    nodes: List[int]                      # node base addresses, top-most first
    parents: List[int] = dataclasses.field(default_factory=list)
    # structure-specific payload threaded to critical (e.g. packed words read)
    info: Any = None


class TraversalDS:
    """Base class — subclasses implement the three methods + supplements."""

    #: number of words per node (one line-aligned allocation unit)
    NODE_WORDS: int = 0

    def __init__(self, mem: PMem):
        self.mem = mem

    # -- the three methods (Property 3) ---------------------------------- #
    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        raise NotImplementedError

    def traverse(self, ctx: OpContext, entry: int, op: str, args) -> TraverseResult:
        raise NotImplementedError

    def critical(self, ctx: OpContext, tr: TraverseResult, op: str, args):
        raise NotImplementedError

    # -- Protocol 1 support ------------------------------------------------#
    def ensure_reachable_addrs(self, tr: TraverseResult) -> List[int]:
        """Address(es) whose flush guarantees the topmost returned node is
        linked into the persistent structure (Lemma 4.1)."""
        raise NotImplementedError

    def read_field_addrs(self, tr: TraverseResult) -> List[int]:
        """Every field address the traversal read in the returned nodes."""
        raise NotImplementedError

    # -- Supplement 1: disconnect(root) ------------------------------------#
    def disconnect(self) -> None:
        """Trim all marked nodes (the entire recovery procedure, §4)."""
        raise NotImplementedError

    # -- verification helpers ----------------------------------------------#
    def contents(self) -> dict:
        """Abstract state read from the *volatile* view (spec oracle)."""
        raise NotImplementedError

    def persistent_contents(self) -> dict:
        """Abstract state as recovery would read it from NVRAM."""
        raise NotImplementedError

    def check_integrity(self) -> None:
        raise NotImplementedError


def run_operation(ds: TraversalDS, policy: Policy, op: str, args, *,
                  step_hook=None, opid: int = 0,
                  max_restarts: Optional[int] = None) -> Any:
    """Algorithm 2: the NVTraverse operation driver."""
    ctx = OpContext(ds.mem, policy, step_hook=step_hook, opid=opid)
    restarts = 0
    while True:
        ctx.enter(Phase.ENTRY)
        entry = ds.find_entry(ctx, op, args)
        ctx.enter(Phase.TRAVERSE)
        tr = ds.traverse(ctx, entry, op, args)
        # Protocol 1 (Algorithm 2 lines 5-6): ensureReachable + makePersistent
        # — runs between traverse and critical; its flushes belong to the
        # destination, not the journey, so leave the traverse phase first.
        ctx.enter(Phase.CRITICAL)
        policy.pre_critical(ctx, ds.ensure_reachable_addrs(tr),
                            ds.read_field_addrs(tr))
        restart, val = ds.critical(ctx, tr, op, args)
        if not restart:
            ctx.before_return()
            return val
        restarts += 1
        if max_restarts is not None and restarts > max_restarts:
            raise RuntimeError(f"operation {op}{args} exceeded "
                               f"{max_restarts} restarts")


def sequential_apply(ds: TraversalDS, policy: Policy,
                     ops: Sequence[tuple], **kw) -> list:
    """Run a sequence of (op, args) with no interleaving; returns results."""
    return [run_operation(ds, policy, op, args, **kw) for op, args in ops]
