"""Lock-free Treiber stack in traversal form.

The paper (§3, Property 2) lists stacks among traversal data structures:
the core tree is the chain from a fixed head sentinel (top = head.next),
findEntry returns the head, the traversal reads the top node, and the
critical method pushes/pops at the destination with O(1) persistence.

  * push(v): new node (next = top, orig_parent = &head.next recorded
    pre-publication — Supplement 2), CAS head.next top→new;
  * pop(): *mark* the top (Definition 1, the linearization point), then
    the unique disconnection CAS swings head.next past it (Property 5).
    A push can land between mark and swing, burying the marked node
    mid-chain — later pops help-trim marked runs exactly like the list's
    deleteMarkedNodes, and recovery's disconnect() trims them all.

Node layout: ``[value, next, orig_parent, _pad]``.
"""
from __future__ import annotations

from typing import List

from .instr import NULLPTR, OpContext, is_marked, pack, unpack, with_mark
from .pmem import PMem
from .traversal import TraversalDS, TraverseResult

VAL, NXT, OPAR = 0, 1, 2


class TreiberStack(TraversalDS):
    NODE_WORDS = 4

    def __init__(self, mem: PMem):
        super().__init__(mem)
        self.head = mem.alloc(self.NODE_WORDS)
        mem.write(self.head + NXT, NULLPTR)
        mem.persist_all()

    # ------------------------------------------------------------------ #
    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        return self.head

    def traverse(self, ctx: OpContext, entry: int, op: str, args) -> TraverseResult:
        hw = ctx.read(entry + NXT)
        top, _ = unpack(hw)
        nodes = [entry] if top == NULLPTR else [entry, top]
        return TraverseResult(nodes=nodes, info=hw)

    def ensure_reachable_addrs(self, tr: TraverseResult) -> List[int]:
        first = tr.nodes[0]
        if first == self.head:
            return []
        return [int(self.mem.volatile[first + OPAR])]

    def read_field_addrs(self, tr: TraverseResult) -> List[int]:
        return [n + NXT for n in tr.nodes]

    # ------------------------------------------------------------------ #
    def critical(self, ctx: OpContext, tr: TraverseResult, op: str, args):
        head = tr.nodes[0]
        top = tr.nodes[1] if len(tr.nodes) > 1 else NULLPTR
        if op == "push":
            hw = ctx.read(head + NXT)
            new = ctx.alloc(self.NODE_WORDS)
            ctx.write_local(new + VAL, args[0])
            ctx.write_local(new + NXT, hw)
            ctx.write_local(new + OPAR, head + NXT)   # Supplement 2
            ok = ctx.cas(head + NXT, hw, pack(new, 0))
            return (False, True) if ok else (True, None)
        if op == "pop":
            if top == NULLPTR:
                return False, None        # empty
            val = ctx.read(top + VAL, immutable=True)
            tw = ctx.read(top + NXT)
            if is_marked(tw):
                # help finish the pending pop, then retry
                hw = ctx.read(head + NXT)
                if unpack(hw)[0] == top:
                    ctx.cas(head + NXT, hw, pack(unpack(tw)[0], 0))
                return True, None
            if not ctx.cas(top + NXT, tw, with_mark(tw)):
                return True, None         # lost the race
            # the unique disconnection (may fail if a push landed; the
            # marked node is then trimmed by later helps / recovery)
            ctx.cas(head + NXT, pack(top, 0), pack(unpack(tw)[0], 0))
            return False, val
        raise ValueError(op)

    # ------------------------------------------------------------------ #
    def disconnect(self) -> None:
        """Trim every marked node in the chain (Supplement 1)."""
        mem = self.mem
        pred = self.head
        while True:
            pw = int(mem.volatile[pred + NXT])
            curr, _ = unpack(pw)
            if curr == NULLPTR:
                break
            run_end = curr
            rw = int(mem.volatile[run_end + NXT])
            trimmed = False
            while is_marked(rw):
                trimmed = True
                run_end, _ = unpack(rw)
                if run_end == NULLPTR:
                    break
                rw = int(mem.volatile[run_end + NXT])
            if trimmed:
                mem.cas(pred + NXT, pw, pack(run_end, 0))
                mem.flush(pred + NXT)
                if run_end == NULLPTR:
                    break
                continue
            pred = curr
        mem.fence()

    # ------------------------------------------------------------------ #
    def _walk(self, image) -> list:
        out = []
        curr, _ = unpack(int(image[self.head + NXT]))
        hops = 0
        while curr != NULLPTR:
            w = int(image[curr + NXT])
            if not is_marked(w):
                out.append(int(image[curr + VAL]))
            curr, _ = unpack(w)
            hops += 1
            assert hops < self.mem.capacity, "runaway stack walk"
        return out                         # top first

    def contents(self) -> list:
        return self._walk(self.mem.volatile)

    def persistent_contents(self) -> list:
        return self._walk(self.mem.persistent)

    def check_integrity(self, *, require_unmarked: bool = False) -> None:
        image = self.mem.volatile
        curr, _ = unpack(int(image[self.head + NXT]))
        seen = set()
        while curr != NULLPTR:
            assert curr not in seen, "cycle in stack"
            seen.add(curr)
            w = int(image[curr + NXT])
            if require_unmarked and is_marked(w):
                raise AssertionError("marked node survived recovery")
            curr, _ = unpack(w)
