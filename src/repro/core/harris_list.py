"""Harris's lock-free linked list in traversal form (paper §2.1, §4.4).

Node layout (one cache line): ``[key, value, next, orig_parent]``
  * ``key``   — immutable (never flushed on read, §4.2);
  * ``value`` — payload word;
  * ``next``  — packed ``(succ_addr << 1) | mark``; a set mark bit means the
    node is *logically deleted* and immutable (Definition 1);
  * ``orig_parent`` — Supplement 2 field: the address of the pointer that
    linked this node into the structure (populated *before* publication).
    Only consulted when ``use_orig_parent=True``; by default the list uses
    the Lemma 4.1 optimization (the traversal returns the current parent of
    the first returned node, and ensureReachable flushes that parent's
    ``next`` field).

The three methods follow the paper's pseudocode:
  * findEntry returns the head sentinel (Algorithm 3 line 9);
  * traverse is Algorithm 4 lines 8–36: returns ``[left, marked…, right]``
    plus ``leftParent`` for the ensureReachable optimization;
  * critical is Algorithm 3 (insert/delete) and Algorithm 4 (find), with
    ``deleteMarkedNodes`` trimming the marked interior nodes first.

Note: the paper's Algorithm 4 line 41 returns ``false`` when
``nodes.size()==2``; taken literally that retries forever when there is
nothing to trim.  We implement the evident intent: nothing to trim ⇒
proceed (return true).
"""
from __future__ import annotations

from typing import List

import numpy as np

from .instr import NULLPTR, OpContext, is_marked, pack, unpack, with_mark
from .pmem import PMem
from .traversal import TraversalDS, TraverseResult

# field offsets
KEY, VAL, NXT, OPAR = 0, 1, 2, 3

KEY_MIN = np.iinfo(np.int64).min + 1   # head sentinel key (-inf)
KEY_MAX = np.iinfo(np.int64).max       # tail sentinel key (+inf)


class HarrisList(TraversalDS):
    NODE_WORDS = 4

    def __init__(self, mem: PMem, *, base: int | None = None,
                 use_orig_parent: bool = False):
        super().__init__(mem)
        self.use_orig_parent = use_orig_parent
        if base is not None:
            mem.init_alloc(max(base, mem.line_words))  # address 0 reserved
        # sentinels (persisted immediately — structure creation is durable)
        self.tail = mem.alloc(self.NODE_WORDS)
        self.head = mem.alloc(self.NODE_WORDS)
        mem.write(self.tail + KEY, KEY_MAX)
        mem.write(self.tail + NXT, NULLPTR)
        mem.write(self.head + KEY, KEY_MIN)
        mem.write(self.head + NXT, pack(self.tail, 0))
        mem.persist_all()

    # ------------------------------------------------------------------ #
    # the three methods                                                   #
    # ------------------------------------------------------------------ #
    def find_entry(self, ctx: OpContext, op: str, args) -> int:
        return self.head  # the root is the only entry point

    def traverse(self, ctx: OpContext, entry: int, op: str, args) -> TraverseResult:
        k = args[0]
        head = self._segment_head(entry)
        while True:
            nodes: List[int] = []
            left_found = False
            left_parent = entry
            pred = entry
            curr = entry
            succ_w = ctx.read(curr + NXT)
            # walk while current node is marked or its key < k
            while is_marked(succ_w) or ctx.read(curr + KEY, immutable=True) < k:
                if not is_marked(succ_w):
                    nodes.clear()
                    left_found = True
                    left_parent = pred
                    nodes.append(curr)          # candidate left node
                else:
                    nodes.append(curr)          # marked interior node
                pred = curr
                curr, _ = unpack(succ_w)
                if curr == NULLPTR:
                    break
                succ_w = ctx.read(curr + NXT)
            right = curr
            nodes.append(right)
            # entry node itself was (or became) marked and no unmarked left
            # was seen — can happen when the entry point is an auxiliary
            # shortcut (skiplist tower / stale hint); fall back to the
            # segment head, which is a sentinel and never marked.
            if not left_found:
                entry = head
                continue
            # restart if right got marked under us (Algorithm 4 line 31)
            if right != NULLPTR and is_marked(ctx.read(right + NXT)):
                continue
            return TraverseResult(nodes=nodes, parents=[left_parent])

    def _segment_head(self, entry: int) -> int:
        """Sentinel head of the core-tree segment containing ``entry``
        (overridden by the hash table, which has one head per bucket)."""
        return self.head

    # -- Protocol 1 addresses -------------------------------------------- #
    def ensure_reachable_addrs(self, tr: TraverseResult) -> List[int]:
        first = tr.nodes[0]
        if self.use_orig_parent:
            # Supplement 2: the field stores the location of the pointer
            # that linked `first` in; flush that location.
            return [int(self.mem.volatile[first + OPAR])]
        # Lemma 4.1 optimization: flush the current parent's next field.
        return [p + NXT for p in tr.parents]

    def read_field_addrs(self, tr: TraverseResult) -> List[int]:
        # traverse read key+next of each returned node; nodes are
        # line-aligned so one flush per node covers both fields.
        return [n + NXT for n in tr.nodes]

    # ------------------------------------------------------------------ #
    # critical methods                                                    #
    # ------------------------------------------------------------------ #
    def _delete_marked_nodes(self, ctx: OpContext, tr: TraverseResult) -> bool:
        """Algorithm 4 lines 40–57: trim marked nodes between left and right."""
        nodes = tr.nodes
        if len(nodes) == 2 or len(nodes) == 1:
            return True  # nothing to trim (see module docstring re paper typo)
        left, right = nodes[0], nodes[-1]
        expected = pack(nodes[1], 0)
        ok = ctx.cas(left + NXT, expected, pack(right, 0))
        if ok:
            if right != NULLPTR and is_marked(ctx.read(right + NXT)):
                return False  # right got marked; retraverse
            return True
        return False

    def critical(self, ctx: OpContext, tr: TraverseResult, op: str, args):
        if op == "find":
            right = tr.nodes[-1]
            found = (right != NULLPTR
                     and ctx.read(right + KEY, immutable=True) == args[0])
            return False, found
        if op == "insert":
            return self._insert_critical(ctx, tr, args)
        if op == "delete":
            return self._delete_critical(ctx, tr, args)
        raise ValueError(op)

    def _insert_critical(self, ctx: OpContext, tr: TraverseResult, args):
        k, v = args
        if not self._delete_marked_nodes(ctx, tr):
            return True, False  # retry
        left, right = tr.nodes[0], tr.nodes[-1]
        if right != NULLPTR and ctx.read(right + KEY, immutable=True) == k:
            return False, False  # key already present
        new = ctx.alloc(self.NODE_WORDS)
        ctx.write_local(new + KEY, k)
        ctx.write_local(new + VAL, v)
        ctx.write_local(new + NXT, pack(right, 0))
        ctx.write_local(new + OPAR, left + NXT)  # Supplement 2
        ok = ctx.cas(left + NXT, pack(right, 0), pack(new, 0))
        if ok:
            return False, True
        return True, False  # retry

    def _delete_critical(self, ctx: OpContext, tr: TraverseResult, args):
        k = args[0]
        if not self._delete_marked_nodes(ctx, tr):
            return True, False
        left, right = tr.nodes[0], tr.nodes[-1]
        if right == NULLPTR or ctx.read(right + KEY, immutable=True) != k:
            return False, False  # no such key
        rnext_w = ctx.read(right + NXT)
        if not is_marked(rnext_w):
            ok = ctx.cas(right + NXT, rnext_w, with_mark(rnext_w))  # logical
            if ok:
                # physical delete; failure is fine (another op will trim)
                ctx.cas(left + NXT, pack(right, 0), rnext_w)
                return False, True
        return True, False  # retry

    # ------------------------------------------------------------------ #
    # Supplement 1: disconnect(root) — also THE recovery procedure (§4)   #
    # ------------------------------------------------------------------ #
    def disconnect(self) -> None:
        """Trim every marked node; persist the repaired pointers.

        Runs quiescently (post-crash recovery) directly against memory;
        each disconnection is the unique CAS of Property 5(2), and the
        repaired locations are flushed + fenced so the recovered state is
        itself durable.
        """
        mem = self.mem
        pred = self.head
        while True:
            pred_w = int(mem.volatile[pred + NXT])
            curr, _ = unpack(pred_w)
            if curr == NULLPTR:
                break
            # find maximal run of marked nodes starting at curr
            run_end = curr
            run_end_w = int(mem.volatile[run_end + NXT])
            trimmed = False
            while is_marked(run_end_w):
                trimmed = True
                run_end, _ = unpack(run_end_w)
                if run_end == NULLPTR:
                    break
                run_end_w = int(mem.volatile[run_end + NXT])
            if trimmed:
                mem.cas(pred + NXT, pred_w, pack(run_end, 0))
                mem.flush(pred + NXT)
                if run_end == NULLPTR:
                    break
                continue  # re-examine pred with its new successor
            pred = curr
        mem.fence()

    # ------------------------------------------------------------------ #
    # verification                                                        #
    # ------------------------------------------------------------------ #
    def _walk(self, image: np.ndarray) -> dict:
        out = {}
        seen = set()
        curr, _ = unpack(int(image[self.head + NXT]))
        while curr != NULLPTR and curr != self.tail:
            if curr in seen:
                raise AssertionError("cycle in list")
            seen.add(curr)
            w = int(image[curr + NXT])
            if not is_marked(w):
                out[int(image[curr + KEY])] = int(image[curr + VAL])
            curr, _ = unpack(w)
        return out

    def contents(self) -> dict:
        return self._walk(self.mem.volatile)

    def sorted_snapshot(self) -> List[tuple]:
        """One bottom-level walk returning ``[(key, addr), …]`` of every
        *unmarked* node in list (= key) order — the batch form of the
        traversal, exposed so callers that need every node (the skiplist
        index rebuild, the batch-parallel ordered engine's differential
        tests) pay one O(n) walk instead of one traversal per key."""
        image = self.mem.volatile
        out: List[tuple] = []
        seen = set()
        curr, _ = unpack(int(image[self.head + NXT]))
        while curr != NULLPTR and curr != self.tail:
            if curr in seen:
                raise AssertionError("cycle in list")
            seen.add(curr)
            w = int(image[curr + NXT])
            if not is_marked(w):
                out.append((int(image[curr + KEY]), curr))
            curr, _ = unpack(w)
        return out

    def persistent_contents(self) -> dict:
        return self._walk(self.mem.persistent)

    def check_integrity(self, *, require_unmarked: bool = False) -> None:
        image = self.mem.volatile
        curr, _ = unpack(int(image[self.head + NXT]))
        prev_key = KEY_MIN
        hops = 0
        while curr != NULLPTR and curr != self.tail:
            w = int(image[curr + NXT])
            k = int(image[curr + KEY])
            if not is_marked(w):
                assert k > prev_key, "keys not strictly sorted"
                prev_key = k
            elif require_unmarked:
                raise AssertionError("marked node survived recovery")
            curr, _ = unpack(w)
            hops += 1
            assert hops < self.mem.capacity, "runaway list walk"
