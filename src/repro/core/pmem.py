"""Persistent-memory simulator — the substrate for the NVTraverse reproduction.

Models the paper's memory system (Section 2, "Persistent memory"):

  * two levels: a *volatile* view (cache) and a *persistent* image (NVRAM);
  * all reads/writes hit the volatile view;
  * a value reaches the persistent image either *explicitly* (flush of its
    cache line followed by a fence) or *implicitly* (background cache
    eviction, which may happen at any time and in any order);
  * a crash loses the volatile view: every modification that was *pending*
    (written but not persisted) at crash time MAY be lost — implicit eviction
    means any subset of pending lines may have made it to NVRAM.

The simulator is word-addressed with configurable cache-line grouping
(``line_words``); flushes and evictions act on whole lines, matching
``clwb``/eviction granularity on x86 and the paper's per-node flush counting
(a node allocated within one line costs one flush).

Adversary model for ``crash``: each line with pending words is independently
either evicted (its *current volatile* words reach NVRAM) or dropped.  This
covers the old-value/new-value outcomes relevant to CAS-based lock-free
structures, where each location is written at most once per modification.
(Intermediate-value outcomes from multiple unfenced writes to the *same word*
are not modeled; the traversal structures here never rely on that case —
node fields are written once before publication and pointers change by CAS.)

This module is deliberately a small, mutable, numpy-backed machine: it is the
*verification substrate* that the instruction interpreter, the interleaving
scheduler and the durable-linearizability checker drive at single-instruction
granularity.  The JAX-native, jittable durable structures built for the
framework live in :mod:`repro.core.batched` and are cross-checked against
this machine's accounting in the tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

NULL = -1  # null "pointer" (node index)


def evicted_mask(n: int, evict, rng: np.random.Generator,
                 p_evict: float = 0.5) -> np.ndarray:
    """The shared implicit-eviction adversary, one policy for every
    crash model in the repo: given ``n`` pending items (dirty cache
    lines for :class:`PMem`, staged-but-unfenced files for
    :class:`repro.persistence.manifest.StagedIO`), return a bool mask —
    True means that item happened to reach durable storage at the
    crash.  Seedable via ``rng`` so adversarial schedules replay
    exactly; unknown modes raise instead of silently behaving like
    ``"random"``.

    >>> import numpy as np
    >>> evicted_mask(3, "none", np.random.default_rng(0)).tolist()
    [False, False, False]
    >>> evicted_mask(3, "all", np.random.default_rng(0)).tolist()
    [True, True, True]
    >>> a = evicted_mask(5, "random", np.random.default_rng(7))
    >>> b = evicted_mask(5, "random", np.random.default_rng(7))
    >>> bool((a == b).all())
    True
    """
    if evict == "none":
        return np.zeros(n, dtype=bool)
    if evict == "all":
        return np.ones(n, dtype=bool)
    if evict == "random":
        return rng.random(n) < p_evict
    raise ValueError(f"unknown evict mode {evict!r}")


@dataclasses.dataclass
class PMemCounters:
    """Instruction accounting used by the paper-figure cost model."""

    reads: int = 0
    writes: int = 0
    cas: int = 0
    flushes: int = 0          # every explicit flush instruction issued
    fences: int = 0
    # flushes/fences attributed to the traversal phase (must stay 0 for
    # NVTraverse structures — asserted in tests).
    traverse_flushes: int = 0
    traverse_fences: int = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class PMem:
    """Word-addressed two-level memory with explicit persistence.

    Addresses are integers in ``[0, capacity)``.  Values are int64 words.
    """

    def __init__(self, capacity: int, line_words: int = 8,
                 seed: Optional[int] = None):
        if capacity % line_words:
            capacity += line_words - capacity % line_words
        self.capacity = capacity
        self.line_words = line_words
        self.volatile = np.zeros(capacity, dtype=np.int64)
        self.persistent = np.zeros(capacity, dtype=np.int64)
        # dirty: written since last persisted (the "pending" set, per word)
        self.dirty = np.zeros(capacity, dtype=bool)
        # flushed_line: a flush was issued for this line since the last fence
        self.flushed_line = np.zeros(capacity // line_words, dtype=bool)
        self.counters = PMemCounters()
        self._rng = np.random.default_rng(seed)
        self._crashed = False
        # optional repro.robustness.faultinject.CrashPlan: when set,
        # every persistence instruction reports a crash site before
        # executing (attach via CrashPlan.attach, never set directly).
        # Recorders that additionally define ``on_event`` (e.g.
        # repro.analysis.trace.PersistTrace) receive the *full*
        # instruction stream, writes included.
        self.faults = None
        # address 0 is reserved (packed null); allocations start at line 1
        self._alloc_cursor = line_words

    def _event(self, kind: str, target: str = "", **meta) -> None:
        """Report one executed instruction to an attached trace recorder."""
        cb = getattr(self.faults, "on_event", None) if self.faults else None
        if cb is not None:
            cb(kind, target, **meta)

    # ------------------------------------------------------------------ #
    # basic instructions                                                  #
    # ------------------------------------------------------------------ #
    def read(self, addr: int) -> int:
        self.counters.reads += 1
        return int(self.volatile[addr])

    def write(self, addr: int, value: int) -> None:
        self.counters.writes += 1
        self.volatile[addr] = value
        self.dirty[addr] = True
        if self.faults is not None:
            self._event("write", f"line:{self.line_of(addr)}")

    def cas(self, addr: int, expected: int, new: int) -> bool:
        """Atomic compare-and-swap on the volatile view."""
        if self.faults is not None:
            self.faults.on_site("publish", f"addr:{addr}")
            self._event("publish", f"addr:{addr}")
        self.counters.cas += 1
        if int(self.volatile[addr]) == expected:
            self.volatile[addr] = new
            self.dirty[addr] = True
            # the successful swing dirties its line like any write
            if self.faults is not None:
                self._event("write", f"line:{self.line_of(addr)}")
            return True
        return False

    # ------------------------------------------------------------------ #
    # persistence instructions                                            #
    # ------------------------------------------------------------------ #
    def line_of(self, addr: int) -> int:
        return addr // self.line_words

    def flush(self, addr: int, *, in_traverse: bool = False) -> None:
        """Issue a flush (clwb) for the line containing ``addr``.

        The flush only *guarantees* persistence once a subsequent fence
        executes; until then the line may still be dropped by a crash
        (matching clwb + sfence semantics).
        """
        if self.faults is not None:
            self.faults.on_site("flush", f"line:{self.line_of(addr)}")
            self._event("flush", f"line:{self.line_of(addr)}",
                        in_traverse=in_traverse)
        self.counters.flushes += 1
        if in_traverse:
            self.counters.traverse_flushes += 1
        self.flushed_line[self.line_of(addr)] = True

    def fence(self, *, in_traverse: bool = False) -> None:
        """sfence: all lines flushed since the previous fence are persisted."""
        if self.faults is not None:
            self.faults.on_site("fence", "")
            self._event("fence", in_traverse=in_traverse)
        self.counters.fences += 1
        if in_traverse:
            self.counters.traverse_fences += 1
        lines = np.nonzero(self.flushed_line)[0]
        for ln in lines:
            lo, hi = ln * self.line_words, (ln + 1) * self.line_words
            sel = self.dirty[lo:hi]
            self.persistent[lo:hi][sel] = self.volatile[lo:hi][sel]
            self.dirty[lo:hi] = False
        self.flushed_line[:] = False

    def persist_all(self) -> None:
        """Test helper: persist everything (e.g. after prefill setup)."""
        self.persistent[self.dirty] = self.volatile[self.dirty]
        self.dirty[:] = False
        self.flushed_line[:] = False

    # ------------------------------------------------------------------ #
    # crash semantics                                                     #
    # ------------------------------------------------------------------ #
    def dirty_lines(self) -> np.ndarray:
        d = self.dirty.reshape(-1, self.line_words).any(axis=1)
        return np.nonzero(d)[0]

    def crash(self, evict: str | Iterable[int] = "random",
              p_evict: float = 0.5) -> None:
        """Simulate a full-system crash.

        ``evict`` selects the implicit-eviction adversary:
          * ``"none"``   — no pending line reached NVRAM (pure loss);
          * ``"all"``    — every pending line happened to be evicted;
          * ``"random"`` — each pending line independently evicted with
            probability ``p_evict`` (the general adversary);
          * an iterable of line indices — exact adversarial choice, used by
            the exhaustive durable-linearizability checker.

        Afterwards the volatile view is reloaded from the persistent image
        (cache contents are gone).
        """
        lines = self.dirty_lines()
        if isinstance(evict, str):
            chosen = lines[evicted_mask(len(lines), evict, self._rng,
                                        p_evict)]
        else:
            chosen = np.asarray(sorted(set(evict)), dtype=np.int64)
        for ln in chosen:
            lo, hi = ln * self.line_words, (ln + 1) * self.line_words
            sel = self.dirty[lo:hi]
            self.persistent[lo:hi][sel] = self.volatile[lo:hi][sel]
        # cache is lost; reload from NVRAM
        self.volatile = self.persistent.copy()
        self.dirty[:] = False
        self.flushed_line[:] = False
        self._crashed = True

    # ------------------------------------------------------------------ #
    # allocation                                                          #
    # ------------------------------------------------------------------ #
    # A bump allocator whose cursor is *volatile auxiliary state* in the
    # paper's sense (Property 2): after a crash it is reconstructed by the
    # recovery scan (see core/recovery.py), not persisted per allocation.
    # Allocations are line-aligned so one node == one flushable unit.

    def init_alloc(self, base: int) -> None:
        self._alloc_cursor = base

    def alloc(self, n_words: int) -> int:
        lines = -(-n_words // self.line_words)
        addr = self._alloc_cursor
        self._alloc_cursor += lines * self.line_words
        if self._alloc_cursor > self.capacity:
            raise MemoryError("PMem pool exhausted")
        return addr

    @property
    def alloc_cursor(self) -> int:
        return self._alloc_cursor
