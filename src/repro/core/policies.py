"""Flush/fence injection policies.

Three policies implement the three systems compared in the paper:

  * :class:`VolatilePolicy` — the original, non-durable lock-free algorithm
    (no flushes, no fences).  The upper bound on throughput.
  * :class:`IzraelevitzPolicy` — the general transformation of Izraelevitz
    et al. [26]: a flush + fence accompanies *every* shared-memory access
    ("add a flush and a fence instruction between every two synchronized
    instructions").  Provably correct, prohibitively expensive: O(path)
    fences per operation.
  * :class:`NVTraversePolicy` — the paper's contribution, Protocols 1 and 2:
      - nothing is persisted during findEntry/traverse (the journey);
      - between traverse and critical, ``pre_critical`` runs
        ``ensureReachable`` (flush the parent pointer that links the
        traversal's topmost returned node into the structure — Lemma 4.1)
        and ``makePersistent`` (flush every field the traversal read in the
        returned nodes), then ONE fence;
      - during critical: flush after every shared read (immutable fields
        exempt), flush after every write/CAS, fence before every write/CAS,
        fence before every return.

The policy objects are stateless; all accounting lives in the PMem counters,
so a policy can be swapped per-run to produce the paper's comparison curves.
"""
from __future__ import annotations

from .instr import OpContext, Phase


class Policy:
    name = "abstract"

    # -- Protocol 2 hooks ------------------------------------------------ #
    def after_read(self, ctx: OpContext, addr: int, *, immutable: bool) -> None:
        pass

    def before_mod(self, ctx: OpContext, addr: int) -> None:
        pass

    def after_mod(self, ctx: OpContext, addr: int) -> None:
        pass

    def after_local_write(self, ctx: OpContext, addr: int) -> None:
        pass

    def before_return(self, ctx: OpContext) -> None:
        pass

    # -- Protocol 1 hook (between traverse and critical) ------------------ #
    def pre_critical(self, ctx: OpContext, parent_addrs, node_field_addrs) -> None:
        """``parent_addrs``: address(es) ensureReachable must flush (the
        pointer location linking the topmost returned node — either the
        recorded original-parent location or, under the Lemma 4.1
        optimization, the current parent's pointer field returned by the
        traversal).  ``node_field_addrs``: every field the traversal read in
        the returned nodes, for makePersistent."""
        pass


class VolatilePolicy(Policy):
    name = "volatile"


class IzraelevitzPolicy(Policy):
    """Flush+fence around every shared access (incl. traversal reads)."""

    name = "izraelevitz"

    def after_read(self, ctx, addr, *, immutable):
        ctx.flush(addr)
        ctx.fence()

    def after_mod(self, ctx, addr):
        ctx.flush(addr)
        ctx.fence()

    def after_local_write(self, ctx, addr):
        ctx.flush(addr)
        ctx.fence()

    def before_return(self, ctx):
        ctx.fence()


class NVTraversePolicy(Policy):
    name = "nvtraverse"

    # During traverse, ctx.phase is TRAVERSE and the structure only issues
    # reads; after_read below is a no-op in that phase (the journey is free).

    def after_read(self, ctx, addr, *, immutable):
        if ctx.phase is Phase.CRITICAL and not immutable:
            ctx.flush(addr)

    def before_mod(self, ctx, addr):
        if ctx.phase is Phase.CRITICAL:
            ctx.fence()

    def after_mod(self, ctx, addr):
        if ctx.phase is Phase.CRITICAL:
            ctx.flush(addr)

    def after_local_write(self, ctx, addr):
        # flush each initialized field; the single fence happens via
        # before_mod of the publishing CAS.
        ctx.flush(addr)

    def before_return(self, ctx):
        ctx.fence()

    def pre_critical(self, ctx, parent_addrs, node_field_addrs):
        # ensureReachable: persist the link that makes the subtree reachable.
        for a in parent_addrs:
            ctx.flush(a)
        # makePersistent: persist every field the traversal read in the
        # returned nodes ...
        for a in node_field_addrs:
            ctx.flush(a)
        # ... and a single fence covering all of the above (§4.1).
        ctx.fence()


POLICIES = {p.name: p for p in (VolatilePolicy(), IzraelevitzPolicy(),
                                NVTraversePolicy())}


def get_policy(name: str) -> Policy:
    return POLICIES[name]
