"""arctic-480b [moe]: 35L d_model=7168 56H (kv=8) expert d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from .base import ArchConfig

ARCTIC_480B = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,              # per-expert hidden
    vocab=32000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    d_ff_dense=4864,
    moe_strategy="ep",      # 128 experts / 16 model shards = 8 per shard
    opt_dtype="bfloat16",   # fits-notes in EXPERIMENTS.md §Dry-run
    microbatches=8,           # §Perf C2
    attn_impl="blocked",
    accum_constraint=True,    # §Perf C1
    sp_prefill=True,
    skip_shapes=("long_500k",),
)
