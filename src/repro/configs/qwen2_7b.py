"""qwen2-7b [dense]: 28L d_model=3584 28H (kv=4) d_ff=18944
vocab=152064, GQA + QKV bias. [arXiv:2407.10671; hf]"""
from .base import ArchConfig

QWEN2_7B = ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    microbatches=4,
    attn_impl="blocked",
    sp_prefill=True,
    skip_shapes=("long_500k",),
)
