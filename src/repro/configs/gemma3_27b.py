"""gemma3-27b [dense]: 62L d_model=5376 32H (kv=16) d_ff=21504
vocab=262144, 5:1 local:global sliding-window, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ArchConfig

GEMMA3_27B = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab=262144,
    qk_norm=True,
    local_per_global=5,
    local_window=1024,
    rope_theta=1e6,
    microbatches=4,           # §Perf A7
    attn_impl="blocked",
    sp_prefill=True,
    # long_500k RUNS: 5/6 of layers are bounded-window; global layers
    # decode O(seq) against a sharded cache (DESIGN.md §4).
)
