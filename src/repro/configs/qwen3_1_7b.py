"""qwen3-1.7b [dense]: 28L d_model=2048 16H (kv=8) d_ff=6144
vocab=151936, qk_norm + GQA. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig

QWEN3_1_7B = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    microbatches=2,
    attn_impl="blocked",
    sp_prefill=True,
    skip_shapes=("long_500k",),
)
