"""Architecture registry: the 10 assigned configs + tiny smoke variants.

Exact numbers from the assignment brief (sources in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

from .base import FULL_ATTENTION_SKIP, SHAPES, ArchConfig, ShapeConfig
from .whisper_medium import WHISPER_MEDIUM
from .arctic_480b import ARCTIC_480B
from .qwen2_moe_a2_7b import QWEN2_MOE_A2_7B
from .gemma3_27b import GEMMA3_27B
from .qwen3_1_7b import QWEN3_1_7B
from .qwen1_5_32b import QWEN1_5_32B
from .qwen2_7b import QWEN2_7B
from .mamba2_370m import MAMBA2_370M
from .internvl2_26b import INTERNVL2_26B
from .zamba2_7b import ZAMBA2_7B

ARCHS = {c.name: c for c in (
    WHISPER_MEDIUM, ARCTIC_480B, QWEN2_MOE_A2_7B, GEMMA3_27B, QWEN3_1_7B,
    QWEN1_5_32B, QWEN2_7B, MAMBA2_370M, INTERNVL2_26B, ZAMBA2_7B,
)}


def get_arch(name: str) -> ArchConfig:
    return ARCHS[name]


def cells():
    """All (arch, shape) dry-run cells, including documented skips."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            skip = shape.name in arch.skip_shapes
            # encoder-only archs would skip decode shapes; all ten assigned
            # archs have decoders, so only the long_500k rule applies here.
            out.append((arch, shape, skip))
    return out


def tiny(arch: ArchConfig, **overrides) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(arch.n_layers, 4 if arch.family != "hybrid" else 7),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(arch.n_kv_heads, 2) if arch.n_kv_heads < arch.n_heads
        else 4,
        d_head=32,
        d_ff=256,
        vocab=512,
        param_dtype="float32",
        compute_dtype="float32",
        scan_layers=arch.scan_layers,
        microbatches=1,
    )
    if arch.n_experts:
        small.update(n_experts=8, top_k=min(arch.top_k, 2),
                     d_ff=64,
                     d_ff_shared=128 if arch.n_shared_experts else 0,
                     d_ff_dense=128 if arch.moe_dense_residual else 0,
                     # capacity >= T*k at smoke sizes: no token drops, so
                     # prefill/decode consistency is exact
                     capacity_factor=8.0)
    if arch.ssm_state:
        small.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if arch.enc_layers:
        small.update(enc_layers=2, enc_seq=24)
    if arch.vis_tokens:
        small.update(vis_tokens=8)
    if arch.shared_attn_every:
        small.update(shared_attn_every=3)
    if arch.local_per_global:
        small.update(local_per_global=arch.local_per_global, local_window=16)
    small.update(overrides)
    return dataclasses.replace(arch, **small)
