"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ArchConfig

QWEN2_MOE_A2_7B = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_ff_shared=5632,       # 4 x 1408 fused shared expert
    qkv_bias=True,
    moe_strategy="tp",      # 60 % 16 != 0 -> shard expert d_ff instead
    microbatches=4,
    attn_impl="blocked",
    # sp_prefill measured at +406%% on prefill_32k: the seq-sharded
    # residual stream forces resharding around the MoE token-sort dispatch
    # (argsort/scatter over the flattened token dim) — kept OFF.
    sp_prefill=False,
    skip_shapes=("long_500k",),
)
