"""zamba2-7b [hybrid]: 81 blocks d_model=3584, Mamba2 backbone
(ssm_state=64) + SHARED attention block (32H kv=32, d_ff=14336) invoked
periodically with tied parameters. [arXiv:2411.15242; unverified]"""
from .base import ArchConfig

ZAMBA2_7B = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,             # shared block FFN
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,        # 112 SSD heads
    ssm_chunk=128,
    shared_attn_every=6,
    microbatches=4,
    attn_impl="blocked",
    sp_prefill=True,
    # long_500k RUNS: bounded SSM state; shared attn layers decode O(seq).
)
