"""whisper-medium [audio]: enc-dec, conv frontend stubbed to precomputed
frames. 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig

WHISPER_MEDIUM = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,            # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    enc_layers=24,
    enc_seq=1500,           # 30 s of audio after the conv stub
    act="gelu",
    rope_theta=0.0,         # absolute positional embeddings, no RoPE
    microbatches=2,
    attn_impl="blocked",
    sp_prefill=True,
    skip_shapes=("long_500k",),   # pure full attention (DESIGN.md §4)
)
