"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, MHA) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ArchConfig

QWEN1_5_32B = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    microbatches=8,
    attn_impl="blocked",  # §Perf B1: -97%% memory term
    sp_prefill=True,       # §Perf B3
    skip_shapes=("long_500k",),
)
