"""Architecture + shape configuration schema.

Every assigned architecture is an :class:`ArchConfig`; every assigned input
shape is a :class:`ShapeConfig`.  A (arch × shape) pair is a dry-run /
roofline *cell*.  Reduced ("tiny") variants of each arch drive the CPU smoke
tests; the full configs are exercised only via ``launch/dryrun.py``
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None

    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # sliding-window pattern: number of local layers per global layer
    # (0 = all-global/full attention)
    local_per_global: int = 0
    local_window: int = 1024

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_shared: int = 0        # fused shared-expert hidden size
    moe_dense_residual: bool = False
    d_ff_dense: int = 0         # parallel dense-residual FFN hidden size
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (zamba2): shared attention block every N blocks (0 = none)
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0            # precomputed audio frames (conv stub output)

    # VLM (internvl): precomputed vision patch embeddings (ViT stub output)
    vis_tokens: int = 0

    norm_eps: float = 1e-6
    act: str = "silu"           # silu (gated) | gelu (whisper-style)
    tie_embeddings: bool = False

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"  # AdamW moment dtype (bf16 for the giants)

    # distribution hints (baseline; the perf pass iterates on these)
    moe_strategy: str = "tp"    # "ep": experts over model axis; "tp": d_ff
    remat: str = "block"        # none | block | dots
    scan_layers: bool = True
    # §Perf knobs (baseline values; EXPERIMENTS.md §Perf flips them)
    attn_impl: str = "naive"    # naive | blocked (XLA online-softmax flash)
    attn_chunk: int = 1024      # KV chunk for the blocked path
    sp: bool = False            # sequence-parallel residual stream (TP-SP)
    sp_prefill: bool = False    # enable SP for prefill cells only (fwd-only
                                # SP wins; train SP was refuted — §Perf)
    accum_constraint: bool = False  # pin grad-accumulator sharding to params
    fused_qkv: bool = False     # one QKV projection: 1 bwd AR instead of 3
    fused_gate_up: bool = False  # one gate|up matmul: 1 bwd AR instead of 2
    ssm_proj_tp: bool = True    # shard mamba in/out_proj over the model
                                # axis (False: replicate — §Perf Z probe)
    # microbatches for grad accumulation at the production shapes
    microbatches: int = 1

    # shapes this arch must skip (with the reason recorded in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else (
            self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, K, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * (H * dh) + 2 * D * (K * dh) + (H * dh) * D
        dense_ffn = 3 * D * F
        per_layer = 0
        if self.family in ("dense", "encdec", "vlm"):
            per_layer = attn + dense_ffn + 2 * D
        elif self.family == "moe":
            moe = 3 * D * F * self.n_experts + D * self.n_experts
            if self.n_shared_experts:
                moe += 3 * D * self.d_ff_shared
            if self.moe_dense_residual:
                moe += 3 * D * self.d_ff_dense
            per_layer = attn + moe + 2 * D
        elif self.family == "ssm":
            per_layer = self._ssm_block_params() + D
        elif self.family == "hybrid":
            per_layer = self._ssm_block_params() + D
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            total += attn + 3 * D * F + 2 * D     # one shared attn+ffn block
        if self.family == "encdec":
            total += self.enc_layers * (attn + dense_ffn + 2 * D)
            total += self.n_layers * (attn + D)   # cross-attention
            total += (self.enc_seq + 8192) * D    # absolute pos tables
        total += V * D                            # embeddings
        if not self.tie_embeddings:
            total += V * D                        # lm head
        return total

    def _ssm_block_params(self) -> int:
        D, di = self.d_model, self.d_inner
        conv_dim = di + 2 * self.ssm_groups * self.ssm_state
        in_proj = D * (2 * di + 2 * self.ssm_groups * self.ssm_state
                       + self.ssm_heads)
        return (in_proj + self.ssm_conv * conv_dim + 3 * self.ssm_heads
                + di + di * D)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top-k of routed experts)."""
        if self.family != "moe":
            return self.n_params()
        D, F = self.d_model, self.d_ff
        routed_all = 3 * D * F * self.n_experts
        routed_active = 3 * D * F * self.top_k
        return self.n_params() - self.n_layers * (routed_all - routed_active)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs whose attention is pure full attention skip long_500k (quadratic
# history, no sub-quadratic structure) — recorded in DESIGN.md §4.
FULL_ATTENTION_SKIP = ("long_500k",)
