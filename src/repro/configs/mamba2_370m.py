"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, ssm_state=128,
vocab=50280, SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from .base import ArchConfig

MAMBA2_370M = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,              # no attention; placeholder
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,        # 32 SSD heads
    ssm_chunk=128,
    tie_embeddings=True,
    microbatches=2,
    # long_500k RUNS: O(1) decode state.
)
