"""internvl2-26b [vlm]: InternLM2-20b backbone, 48L d_model=6144 48H (kv=8)
d_ff=16384 vocab=92553; InternViT frontend is a stub providing precomputed
patch embeddings. [arXiv:2404.16821; hf]"""
from .base import ArchConfig

INTERNVL2_26B = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=92553,
    vis_tokens=256,         # ViT stub output per image
    microbatches=8,
    attn_impl="blocked",
    sp_prefill=True,
    skip_shapes=("long_500k",),
)
