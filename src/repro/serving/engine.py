"""Batched serving engine with a durable request log.

The serving loop is the paper's operation shape one level up:
  * prefill + decode steps are the **traversal** — pure compute, no
    persistence, fully re-executable;
  * a finished request's result is the **destination**: it is committed to
    the durable request log with flush(record) → fence → publish, and only
    then acknowledged;
  * after a crash, recovery = read the committed log (completed requests
    survive, ack'd exactly once) and re-enqueue the in-flight ones —
    all-or-nothing, dependency-closed: durable linearizability of the
    request stream.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..persistence.manifest import StagedIO


class RequestLog:
    def __init__(self, root, seed: int = 0):
        self.io = StagedIO(Path(root), seed=seed)
        self._n = len(self.committed())

    def commit(self, results: Dict[int, list]) -> None:
        """Commit a batch of finished requests (one fence for the batch —
        the batched-map fence elision from core/batched.py)."""
        rel = f"log_{self._n:06d}.json"
        self.io.write(rel, json.dumps(results).encode())
        self.io.flush(rel)
        self.io.fence()
        self._n += 1

    def committed(self) -> Dict[int, list]:
        out = {}
        for p in sorted(Path(self.io.root).glob("log_*.json")):
            try:
                out.update({int(k): v
                            for k, v in json.loads(p.read_text()).items()})
            except json.JSONDecodeError:
                continue    # torn log record: trimmed by recovery semantics
        return out


class ServeEngine:
    def __init__(self, model, params, *, max_len: int, log_dir,
                 batch_size: int = 4):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.log = RequestLog(log_dir)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))
        self._decode = jax.jit(model.decode_step)

    def _greedy_batch(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["vis"] = jnp.zeros((B, cfg.vis_tokens, cfg.d_model),
                                     jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                        jnp.float32)
        logits, caches = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        prefix = cfg.vis_tokens if cfg.family == "vlm" else 0
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(S + prefix + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)        # [B, n_new]

    def serve(self, requests: Dict[int, np.ndarray], n_new: int = 8,
              *, crash_after_batches: Optional[int] = None) -> Dict[int, list]:
        """Serve a request dict {rid: prompt tokens[S]}; returns committed
        results.  Already-committed rids are skipped (exactly-once)."""
        done = self.log.committed()
        todo = [rid for rid in sorted(requests) if rid not in done]
        batches = 0
        for i in range(0, len(todo), self.batch):
            rids = todo[i:i + self.batch]
            prompts = np.stack([requests[r] for r in rids])
            gen = self._greedy_batch(prompts, n_new)     # the traversal
            self.log.commit({int(r): gen[j].tolist()     # the destination
                             for j, r in enumerate(rids)})
            batches += 1
            if crash_after_batches is not None and \
                    batches >= crash_after_batches:
                self.log.io.crash(evict="none")
                break
        return self.log.committed()
