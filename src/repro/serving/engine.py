"""Batched serving engine with a durable request log.

The serving loop is the paper's operation shape one level up:
  * prefill + decode steps are the **traversal** — pure compute, no
    persistence, fully re-executable;
  * a finished request's result is the **destination**: it is committed to
    the durable request log with flush(record) → fence → publish, and only
    then acknowledged;
  * after a crash, recovery = read the committed log (completed requests
    survive, ack'd exactly once) and re-enqueue the in-flight ones —
    all-or-nothing, dependency-closed: durable linearizability of the
    request stream.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..persistence.index import MembershipIndex
from ..persistence.manifest import StagedIO


class RequestLog:
    """Durable request log + a JAX-native dedup index.

    The committed-rid set is mirrored into a durable-map
    :class:`~repro.persistence.index.MembershipIndex` (rebuilt from the
    log on restart, extended by one plan/commit batch per commit), so
    the exactly-once check in :meth:`ServeEngine.serve` is a batched,
    persistence-free lookup — the journey — instead of a Python dict
    probe per request."""

    def __init__(self, root, seed: int = 0, capacity: int = 1 << 15):
        self.io = StagedIO(Path(root), seed=seed)
        self._dedup = MembershipIndex(capacity, n_buckets=256)
        self._oob: set = set()     # rids outside the map's int32 key space
        self._folded: set = set()  # log filenames already in the index
        self._n = 0
        self.refresh()

    def _index_rids(self, rids) -> None:
        in_range = [r for r in map(int, rids) if 0 <= r < 2**31 - 1]
        self._oob.update(r for r in map(int, rids)
                         if not 0 <= r < 2**31 - 1)
        self._dedup.add(in_range)

    def refresh(self) -> None:
        """Fold commits made by other RequestLog instances on the same log
        dir into the dedup index.  Incremental: only log records not yet
        folded are parsed, so a refresh with nothing new is free."""
        for p in sorted(Path(self.io.root).glob("log_*.json")):
            if p.name in self._folded:
                continue
            try:
                rids = [int(k) for k in json.loads(p.read_text())]
            except json.JSONDecodeError:
                continue    # torn log record: trimmed by recovery semantics
            self._folded.add(p.name)
            self._index_rids(rids)
        self._n = max(self._n, len(self._folded))

    def is_committed(self, rids: Sequence[int]) -> np.ndarray:
        """Batched exactly-once probe over the dedup map (bool[len(rids)]).
        Rids representable as int32 go through the durable map; the rare
        out-of-range rid falls back to a Python-set probe (the old
        dict-based dedup accepted arbitrary ints)."""
        rids = [int(r) for r in rids]
        out = np.zeros(len(rids), np.bool_)
        in_range = [(i, r) for i, r in enumerate(rids)
                    if 0 <= r < 2**31 - 1]
        if in_range:
            idx, ks = zip(*in_range)
            out[list(idx)] = self._dedup.contains(list(ks))
        for i, r in enumerate(rids):
            if not 0 <= r < 2**31 - 1:
                out[i] = r in self._oob
        return out

    def commit(self, results: Dict[int, list]) -> None:
        """Commit a batch of finished requests (one fence for the batch —
        the batched-map fence elision from core/batched.py)."""
        rel = f"log_{self._n:06d}.json"
        self.io.write(rel, json.dumps(results).encode())
        self.io.flush(rel)
        self.io.fence()
        self._folded.add(rel)
        self._n += 1
        self._index_rids(results)

    def committed(self) -> Dict[int, list]:
        out = {}
        for p in sorted(Path(self.io.root).glob("log_*.json")):
            try:
                out.update({int(k): v
                            for k, v in json.loads(p.read_text()).items()})
            except json.JSONDecodeError:
                continue    # torn log record: trimmed by recovery semantics
        return out


class ServeEngine:
    def __init__(self, model, params, *, max_len: int, log_dir,
                 batch_size: int = 4):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.log = RequestLog(log_dir)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))
        self._decode = jax.jit(model.decode_step)

    def _greedy_batch(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["vis"] = jnp.zeros((B, cfg.vis_tokens, cfg.d_model),
                                     jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                        jnp.float32)
        logits, caches = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        prefix = cfg.vis_tokens if cfg.family == "vlm" else 0
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(S + prefix + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)        # [B, n_new]

    def serve(self, requests: Dict[int, np.ndarray], n_new: int = 8,
              *, crash_after_batches: Optional[int] = None) -> Dict[int, list]:
        """Serve a request dict {rid: prompt tokens[S]}; returns committed
        results.  Already-committed rids are skipped (exactly-once)."""
        self.log.refresh()    # pick up commits from other engine instances
        rids = sorted(requests)
        todo = [rid for rid, done in zip(rids, self.log.is_committed(rids))
                if not done]
        batches = 0
        for i in range(0, len(todo), self.batch):
            rids = todo[i:i + self.batch]
            prompts = np.stack([requests[r] for r in rids])
            gen = self._greedy_batch(prompts, n_new)     # the traversal
            self.log.commit({int(r): gen[j].tolist()     # the destination
                             for j, r in enumerate(rids)})
            batches += 1
            if crash_after_batches is not None and \
                    batches >= crash_after_batches:
                self.log.io.crash(evict="none")
                break
        return self.log.committed()
