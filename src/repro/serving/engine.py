"""Batched serving engine with a durable request log.

The serving loop is the paper's operation shape one level up:
  * prefill + decode steps are the **traversal** — pure compute, no
    persistence, fully re-executable;
  * a finished request's result is the **destination**: it is committed to
    the durable request log with flush(record) → fence → publish, and only
    then acknowledged;
  * after a crash, recovery = read the committed log (completed requests
    survive, ack'd exactly once) and re-enqueue the in-flight ones —
    all-or-nothing, dependency-closed: durable linearizability of the
    request stream.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..persistence.index import MembershipIndex
from ..persistence.manifest import StagedIO


class RequestLog:
    """Durable request log + a JAX-native dedup index.

    The committed-rid set is mirrored into a durable-map
    :class:`~repro.persistence.index.MembershipIndex` (rebuilt from the
    log on restart, extended by one plan/commit batch per commit), so
    the exactly-once check in :meth:`ServeEngine.serve` is a batched,
    persistence-free lookup — the journey — instead of a Python dict
    probe per request."""

    def __init__(self, root, seed: int = 0, capacity: int = 1 << 15):
        self.io = StagedIO(Path(root), seed=seed)
        self._dedup = MembershipIndex(capacity, n_buckets=256)
        self._folded: set = set()  # log filenames already in the index
        self._torn: dict = {}      # torn filename -> (size, mtime_ns) seen
        self._results: Dict[int, list] = {}   # rid -> committed result
        self._n = 0                # next log index: 1 + highest seen
        self.refresh()
        # recovery: a restart is quiescent (no concurrent committer is
        # mid-fence), so a torn record seen at startup is a permanent
        # crash leftover — trim it.  Torn files that appear *later* are
        # another live instance's in-flight commit and must be left
        # alone (they heal via the refresh() signature check).
        for name in list(self._torn):
            (Path(self.io.root) / name).unlink(missing_ok=True)
            del self._torn[name]

    @staticmethod
    def _log_index(name: str) -> Optional[int]:
        try:
            return int(name[len("log_"):-len(".json")])
        except ValueError:
            return None

    def refresh(self) -> None:
        """Fold commits made by other RequestLog instances on the same log
        dir into the dedup index.  Incremental: only log records not yet
        folded (and not known torn) are parsed, so a refresh with nothing
        new is free.  A torn record is skipped while its on-disk (size,
        mtime) signature is unchanged, but re-parsed once it changes — a
        record caught mid-write by a slow concurrent committer heals
        instead of being poisoned forever.  ``_n`` advances past every
        existing log index — torn records included — so a commit never
        reuses the slot of a record that is already on disk."""
        for p in sorted(Path(self.io.root).glob("log_*.json")):
            if p.name in self._folded:
                continue
            try:
                st = p.stat()
            except FileNotFoundError:
                continue
            sig = (st.st_size, st.st_mtime_ns)
            if self._torn.get(p.name) == sig:
                continue    # unchanged since the failed parse: still torn
            idx = self._log_index(p.name)
            if idx is not None:
                self._n = max(self._n, idx + 1)
            try:
                rec = {int(k): v
                       for k, v in json.loads(p.read_text()).items()}
            except json.JSONDecodeError:
                # torn log record: trimmed by recovery semantics
                self._torn[p.name] = sig
                continue
            self._torn.pop(p.name, None)
            self._folded.add(p.name)
            self._results.update(rec)
            self._dedup.add(rec)

    def is_committed(self, rids: Sequence[int]) -> np.ndarray:
        """Batched exactly-once probe over the dedup map (bool[len(rids)]).
        Arbitrary-int rids are fine: the index stores int32-representable
        rids in the durable map and falls back to a Python-set probe for
        the rare out-of-range one (the old dict-based dedup accepted
        arbitrary ints)."""
        return self._dedup.contains([int(r) for r in rids])

    def _claim_slot(self) -> str:
        """Atomically reserve the next free log slot (O_CREAT|O_EXCL), so
        genuinely concurrent instances can never claim the same filename.
        The zero-byte placeholder is a torn record until the fence lands
        the payload; a crash in between leaves it torn, which recovery
        semantics already skip (and ``_n`` derivation steps over)."""
        while True:
            rel = f"log_{self._n:06d}.json"
            self._n += 1
            try:
                fd = os.open(Path(self.io.root) / rel,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                continue     # slot taken by another instance: skip it
            os.close(fd)
            return rel

    def commit(self, results: Dict[int, list]) -> None:
        """Commit a batch of finished requests (one fence for the batch —
        the batched-map fence elision from core/batched.py) into an
        atomically claimed slot, so a concurrent RequestLog instance's
        commit is never overwritten."""
        rel = self._claim_slot()
        self.io.write(rel, json.dumps(results).encode())
        self.io.flush(rel)
        self.io.fence()
        self._folded.add(rel)
        rec = {int(k): list(v) for k, v in results.items()}
        self._results.update(rec)
        self._dedup.add(rec)

    def committed(self) -> Dict[int, list]:
        """All committed results, incrementally maintained: refresh()
        parses each durable log record exactly once and retains its
        rid -> result payload, so this is O(new records), not a full
        re-parse of the log per call.  Values are copied out so caller
        mutation cannot diverge the cache from the durable records."""
        self.refresh()
        return {k: list(v) for k, v in self._results.items()}


class ServeEngine:
    def __init__(self, model, params, *, max_len: int, log_dir,
                 batch_size: int = 4):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.log = RequestLog(log_dir)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))
        self._decode = jax.jit(model.decode_step)

    def _greedy_batch(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["vis"] = jnp.zeros((B, cfg.vis_tokens, cfg.d_model),
                                     jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                        jnp.float32)
        logits, caches = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        prefix = cfg.vis_tokens if cfg.family == "vlm" else 0
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(S + prefix + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)        # [B, n_new]

    def serve(self, requests: Dict[int, np.ndarray], n_new: int = 8,
              *, crash_after_batches: Optional[int] = None) -> Dict[int, list]:
        """Serve a request dict {rid: prompt tokens[S]}; returns committed
        results.  Already-committed rids are skipped (exactly-once)."""
        self.log.refresh()    # pick up commits from other engine instances
        rids = sorted(requests)
        todo = [rid for rid, done in zip(rids, self.log.is_committed(rids))
                if not done]
        batches = 0
        for i in range(0, len(todo), self.batch):
            rids = todo[i:i + self.batch]
            prompts = np.stack([requests[r] for r in rids])
            gen = self._greedy_batch(prompts, n_new)     # the traversal
            self.log.commit({int(r): gen[j].tolist()     # the destination
                             for j, r in enumerate(rids)})
            batches += 1
            if crash_after_batches is not None and \
                    batches >= crash_after_batches:
                self.log.io.crash(evict="none")
                break
        return self.log.committed()
