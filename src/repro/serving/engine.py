"""Batched serving engine with a durable request log.

The serving loop is the paper's operation shape one level up:
  * prefill + decode steps are the **traversal** — pure compute, no
    persistence, fully re-executable;
  * a finished request's result is the **destination**: it is committed to
    the durable request log with flush(record) → fence → publish, and only
    then acknowledged;
  * after a crash, recovery = read the committed log (completed requests
    survive, ack'd exactly once) and re-enqueue the in-flight ones —
    all-or-nothing, dependency-closed: durable linearizability of the
    request stream.
"""
from __future__ import annotations

import json
import os
import random
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.metrics import get_registry
from ..obs.spans import PersistListener, Tracer
from ..persistence.index import MembershipIndex
from ..persistence.manifest import StagedIO


class RequestLog:
    """Durable request log + a JAX-native dedup index.

    The committed-rid set is mirrored into a durable-map
    :class:`~repro.persistence.index.MembershipIndex` (updated by one
    *mixed* plan/commit round per commit: new rids insert, expired rids
    delete, in a single batch), so the exactly-once check in
    :meth:`ServeEngine.serve` is a batched, persistence-free lookup —
    the journey — instead of a Python dict probe per request.

    Restart is O(retention window), not O(log length): the caches and
    the dedup map are seeded from the newest published
    :meth:`snapshot` and only the post-snapshot record suffix is
    replayed; :meth:`took_effect`/:meth:`descriptor` then answer a
    recovering client's "did my op land?" from the map, with zero
    record parsing."""

    # upper bound on the filesystem timestamp granule (1-10 ms coarse
    # clock on modern Linux, but a full second on ext3/HFS+/some network
    # mounts; leave headroom): an mtime younger than this never
    # authorizes the refresh() fast path
    _RACY_NS = 2_000_000_000

    # base grace interval granted to a concurrent committer before a torn
    # placeholder seen at restart is trimmed; attempt k waits
    # base * 2**k (capped at _TRIM_BACKOFF_MAX_S, jittered) so retries
    # never run in lockstep with the writer they are yielding to
    _TRIM_BACKOFF_S = 0.01
    _TRIM_BACKOFF_MAX_S = 0.08
    _TRIM_RETRIES = 4

    def __init__(self, root, seed: int = 0, capacity: int = 1 << 15,
                 shards: Optional[int] = None, rebalance: bool = False,
                 ordered_dedup: bool = False,
                 registry=None, tracer: Optional[Tracer] = None,
                 timeline=None, obs: bool = True):
        """``shards`` (optional) backs the dedup index with the
        bucket-range-sharded durable map
        (:class:`repro.core.sharded.ShardedDurableMap`) across that many
        devices — same exactly-once semantics, commits stay
        per-shard-local.  ``capacity`` is only the *seed* pool size:
        under live traffic the dedup map grows itself via the bounded
        migration rounds of :mod:`repro.core.migrate`
        (:attr:`dedup_migrations` counts the growth events), so a
        long-running server never hits a dedup ceiling.  ``rebalance``
        (sharded only) additionally lets skewed rid streams re-split the
        shard boundaries under live traffic via
        :class:`repro.core.rebalance.RebalancingShardedMap`
        (:attr:`dedup_rebalances` counts completions).

        ``ordered_dedup`` instead backs the index with the
        batch-parallel *ordered* engine
        (:class:`repro.persistence.index.OrderedMembershipIndex` over
        :mod:`repro.core.ordered`): committed rids live in a sorted
        bottom-level list under volatile towers, and
        :meth:`expired_rids` becomes an ordered-by-rid horizon trim
        (one top-k walk + one tower-descended range scan) instead of
        the insertion-order window — identical semantics for the
        monotone rid streams the engine issues.  Mutually exclusive
        with ``shards`` (the ordered pool is single-device).

        ``registry``/``tracer`` plug the log into an explicit NVTrace
        metrics registry and span tracer (default: the process-wide
        ones); ``timeline`` (an :class:`repro.obs.timeline.
        EventTimeline`) additionally gets snapshot/truncate,
        dedup-migration/rebalance and open/recovery annotations so a
        latency excursion in a windowed series is attributable to its
        cause; ``obs=False`` disables the span tracer and the
        persistence-event listener — the zero-instrumentation baseline
        the overhead bench compares against."""
        self.io = StagedIO(Path(root), seed=seed)
        self.metrics = registry if registry is not None else get_registry()
        self.tracer = tracer if tracer is not None else Tracer(
            registry=self.metrics, enabled=obs)
        if obs and self.io.faults is None:
            # persistence-instruction counts per span ride the same
            # `faults` hook surface CrashPlan uses; a crash plan attached
            # later simply replaces the listener for that run
            PersistListener(tracer=self.tracer,
                            registry=self.metrics).attach(self.io)
        self._rng = random.Random(0x5eed ^ seed)
        self._ordered = bool(ordered_dedup)
        if ordered_dedup:
            assert shards is None, \
                "ordered_dedup is single-device (no shards)"
            from ..persistence.index import OrderedMembershipIndex
            self._dedup = OrderedMembershipIndex(capacity)
        else:
            self._dedup = MembershipIndex(capacity, n_buckets=256,
                                          n_shards=shards,
                                          auto_rebalance=rebalance)
        self._folded: set = set()  # log filenames already in the index
        self._torn: dict = {}      # torn filename -> (size, mtime_ns) seen
        self._results: Dict[int, list] = {}   # rid -> committed result
        self._n = 0                # next log index: 1 + highest seen
        self._dir_mtime: Optional[int] = None  # log dir mtime at last scan
        self._snap_horizon = 0     # records below this index are covered
                                   # by the loaded snapshot
        self._snap_name: Optional[str] = None  # newest published snapshot
        self._stale: set = set()   # snapshot-covered leftovers (a crash
                                   # mid-truncation): trimmed at restart
        self.records_parsed = 0    # log records read+parsed by this
                                   # instance (restart-replay observability)
        self.timeline = timeline
        t0 = time.perf_counter_ns()
        self._load_snapshot()
        t1 = time.perf_counter_ns()
        self.refresh()
        t2 = time.perf_counter_ns()
        # recovery: a restart is *usually* quiescent, but the torn
        # placeholder may be another live instance's in-flight commit —
        # grant the writer a bounded, jittered exponential backoff to
        # land the payload instead of failing the restart.  Torn files
        # that appear *later* are always left alone (they heal via the
        # refresh() signature check).
        for name in list(self._torn):
            self._trim_torn(name)
        # finish any truncation a crash interrupted: records (and older
        # snapshots) the loaded snapshot supersedes
        for name in sorted(self._stale):
            self._unlink_quiet(name)
        self._stale.clear()
        t3 = time.perf_counter_ns()
        # per-phase restart breakdown — the flight recorder dumps this
        # on a post-crash reload so recovery cost is explainable, not
        # just a total (see docs/observability.md)
        self.restart_timing = {
            "load_snapshot_us": (t1 - t0) / 1e3,
            "replay_us": (t2 - t1) / 1e3,
            "trim_us": (t3 - t2) / 1e3,
            "total_us": (t3 - t0) / 1e3,
            "records_parsed": self.records_parsed,
            "snapshot_loaded": self._snap_name is not None,
        }
        for ph in ("load_snapshot", "replay", "trim"):
            self.metrics.histogram(
                "restart_phase_us", lo=1.0, hi=1e8, growth=1.25,
                phase=ph).record(self.restart_timing[ph + "_us"])
        if timeline is not None:
            timeline.annotate("log_open",
                              total_us=self.restart_timing["total_us"],
                              records_parsed=self.records_parsed)

    @staticmethod
    def _log_index(name: str) -> Optional[int]:
        try:
            return int(name[len("log_"):-len(".json")])
        except ValueError:
            return None

    def _load_snapshot(self) -> None:
        """Restart fast path: seed the caches *and* the durable-map dedup
        index from the newest published snapshot — one JSON read plus one
        batched map round — so the scan that follows replays only the
        post-snapshot record suffix.  Restart cost is O(window), not
        O(log length).  A torn/alien snapshot file falls back to the
        next-newest one (the publish rename makes each snapshot
        all-or-nothing, so this only triggers on outside interference)."""
        try:
            with os.scandir(self.io.root) as it:
                snaps = sorted(e.name for e in it
                               if e.name.startswith("snap_")
                               and e.name.endswith(".json"))
        except FileNotFoundError:
            return
        for name in reversed(snaps):
            try:
                data = json.loads((Path(self.io.root) / name).read_text())
                horizon = int(data["horizon"])
                rec = {int(k): list(v) for k, v in data["results"].items()}
            except (OSError, json.JSONDecodeError, KeyError, TypeError,
                    ValueError):
                continue
            self._results.update(rec)
            self._dedup.update(rec, ())
            self._snap_horizon = horizon
            self._snap_name = name
            self._n = max(self._n, horizon)
            break
        # superseded older snapshots ride the restart trim
        self._stale.update(n for n in snaps if n != self._snap_name)

    def _backoff(self, attempt: int) -> None:
        """Bounded exponential backoff with jitter: attempt *k* sleeps
        ``base * 2**k`` capped at ``_TRIM_BACKOFF_MAX_S``, scaled by a
        uniform [0.5, 1.0) jitter so concurrent restarting instances
        (and the writer being yielded to) never phase-lock."""
        span = min(self._TRIM_BACKOFF_S * (1 << attempt),
                   self._TRIM_BACKOFF_MAX_S)
        time.sleep(span * (0.5 + self._rng.random() / 2))

    def _trim_torn(self, name: str) -> None:
        """Trim one torn record seen at restart, tolerating a concurrent
        creation race.  Each of the ``_TRIM_RETRIES`` attempts grants a
        growing, jittered grace interval (:meth:`_backoff`), re-checks
        whether the writer finished (a mid-commit record *heals* instead
        of being trimmed), then tries the unlink.  Exhausting the budget
        leaves the file in the torn set — it heals or trims later —
        never failing the restart itself.  Retries and heals are
        counted on the registry (``serving_trim_retries_total`` /
        ``serving_trim_heals_total``)."""
        for attempt in range(self._TRIM_RETRIES):
            self._backoff(attempt)
            self._try_fold(name)
            if name not in self._torn:
                self.metrics.counter("serving_trim_heals_total").inc()
                return              # healed: the writer finished
            try:
                self.io.unlink(name)
            except OSError:
                self.metrics.counter("serving_trim_retries_total").inc()
                continue            # grace grows; writer may still land
            del self._torn[name]
            self.metrics.counter("serving_trims_total").inc()
            return

    def _unlink_quiet(self, name: str) -> None:
        """Best-effort trim of one superseded file; a failure just leaves
        the file for the next truncation pass to retry."""
        try:
            self.io.unlink(name)
        except OSError:
            pass
        self._folded.discard(name)

    def refresh(self) -> None:
        """Fold commits made by other RequestLog instances on the same log
        dir into the dedup index.  Incremental twice over: the directory
        scan is skipped entirely while the log dir's mtime is unchanged
        since the last scan (record files are only ever *created*, so new
        commits always bump it) and no torn record is pending a re-check
        — a refresh with nothing new is a single ``stat``, keeping
        ``serve()`` O(new records) instead of O(total historical
        records).  When the scan does run, only log records not yet
        folded (and not known torn) are parsed."""
        now = self._fs_now()     # BEFORE the stat/scan: see guard below
        if now is None:          # log dir itself is gone
            return
        try:
            dir_mtime = os.stat(self.io.root).st_mtime_ns
        except FileNotFoundError:
            return
        if dir_mtime == self._dir_mtime:
            # nothing was created/renamed/removed; known torn records can
            # still *heal* (their content changes without touching the
            # dir mtime), so re-stat just those — O(torn), usually zero
            self._check_torn()
            return
        self._scan()
        # The racy-timestamp guard (à la git's index): directory mtimes
        # come from the filesystem's coarse clock, so a record created in
        # the same clock granule as ``dir_mtime`` — even *after* this
        # scan's directory listing — leaves the mtime unchanged.  Cache
        # the mtime (enabling the fast path above) only if its granule
        # had already closed before this scan started (``now`` is taken
        # before the stat, which precedes the listing); otherwise leave
        # the cache invalid so the next refresh rescans.  ``now`` is read
        # from the *filesystem's* clock (a sentinel-file utime), not the
        # local one — on network mounts the two can disagree by more than
        # the granule.
        self._dir_mtime = (dir_mtime
                           if now - dir_mtime > self._RACY_NS else None)

    def _fs_now(self) -> Optional[int]:
        """The log-dir filesystem's current time: utime a sentinel file
        and read its mtime back.  Updating an *existing* file never
        touches the parent directory's mtime, so the probe is invisible
        to the fast-path check (only its one-time creation bumps it).
        Returns None when the log dir itself has been removed."""
        clock = Path(self.io.root) / ".clock"
        try:
            os.utime(clock)
        except FileNotFoundError:
            try:
                # the sentinel is a clock probe, not durable data: its
                # one-time creation must not register as a crash site
                # persistlint: waive(raw-durable-io) — mtime-clock sentinel
                clock.touch()
            except FileNotFoundError:
                return None
        return os.stat(clock).st_mtime_ns

    def _scan(self) -> None:
        """One pass over the log dir, O(directory entries): already-folded
        names are dropped before the (slot-order) sort and never stat'd
        or re-parsed, so only *new* records cost anything."""
        try:
            with os.scandir(self.io.root) as it:
                fresh = [e.name for e in it
                         if e.name.startswith("log_")
                         and e.name.endswith(".json")
                         and e.name not in self._folded]
        except FileNotFoundError:
            return
        for name in sorted(fresh):       # slot order = linearization order
            self._try_fold(name)

    def _check_torn(self) -> None:
        """Re-stat only the known-torn records; a stable signature costs
        one stat, a changed one re-parses (heals)."""
        for name in sorted(self._torn):
            self._try_fold(name)

    def _try_fold(self, name: str) -> None:
        """Stat/parse one log record and fold it into the caches if it is
        whole.  A torn record is skipped while its on-disk (size, mtime)
        signature is unchanged, but re-parsed once it changes — a record
        caught mid-write by a slow concurrent committer heals instead of
        being poisoned forever.  ``_n`` advances past every seen log
        index — torn records included — so a commit never reuses the
        slot of a record that is already on disk."""
        idx = self._log_index(name)
        if idx is not None and idx < self._snap_horizon:
            # covered by the loaded snapshot: content already folded.
            # The file is an interrupted-truncation leftover — queue it
            # for the restart trim and never re-scan it.
            self._stale.add(name)
            self._folded.add(name)
            self._torn.pop(name, None)
            return
        p = Path(self.io.root) / name
        try:
            st = p.stat()
        except FileNotFoundError:
            return
        sig = (st.st_size, st.st_mtime_ns)
        if self._torn.get(name) == sig:
            return      # unchanged since the failed parse: still torn
        if idx is not None:
            self._n = max(self._n, idx + 1)
        self.records_parsed += 1   # per-instance shim; registry mirror:
        self.metrics.counter("serving_records_parsed_total").inc()
        try:
            rec, evict = self._parse_record(p.read_text())
        except (json.JSONDecodeError, UnicodeDecodeError):
            # torn log record — truncated payloads fail to parse,
            # garbled ones may not even decode as UTF-8; both are the
            # same torn-record state, trimmed by recovery semantics
            self._torn[name] = sig
            return
        self._torn.pop(name, None)
        self._folded.add(name)
        self._apply_record(rec, evict)

    @staticmethod
    def _parse_record(text: str):
        """Decode one log record.  Plain records are a rid -> result dict
        (the pre-eviction format, still written when nothing is evicted);
        records carrying evictions are ``{"results": …, "evict": [rids]}``
        — distinguishable because plain records only have integer keys."""
        data = json.loads(text)
        if "results" in data and set(data) <= {"results", "evict"}:
            return ({int(k): v for k, v in data["results"].items()},
                    [int(r) for r in data.get("evict", [])])
        return {int(k): v for k, v in data.items()}, []

    def _apply_record(self, rec: Dict[int, list], evict: Sequence[int]):
        """Fold one record into the caches and the dedup map: new rids in,
        evicted rids out — one mixed plan/commit round on the durable
        map (record order is the linearization order)."""
        self._results.update(rec)
        for r in evict:
            self._results.pop(r, None)
        self._dedup.update(rec, evict)

    @property
    def dedup_migrations(self) -> int:
        """Online growth migrations the dedup map has run (observability
        for the serving path: growth is supposed to be rare and
        amortized — a hot counter here means the seed capacity or the
        eviction ``retain`` window is mis-sized)."""
        return self._dedup.migrations

    @property
    def dedup_rebalances(self) -> int:
        """Live cross-shard re-splits the dedup map has completed (only
        nonzero when the log was opened with ``rebalance=True``)."""
        return self._dedup.rebalances

    def is_committed(self, rids: Sequence[int]) -> np.ndarray:
        """Batched exactly-once probe over the dedup map (bool[len(rids)]).
        Arbitrary-int rids are fine: the index stores int32-representable
        rids in the durable map and falls back to a Python-set probe for
        the rare out-of-range one (the old dict-based dedup accepted
        arbitrary ints)."""
        return self._dedup.contains([int(r) for r in rids])

    def _claim_slot(self) -> str:
        """Atomically reserve the next free log slot (O_CREAT|O_EXCL), so
        genuinely concurrent instances can never claim the same filename.
        The zero-byte placeholder is a torn record until the fence lands
        the payload; a crash in between leaves it torn, which recovery
        semantics already skip (and ``_n`` derivation steps over)."""
        while True:
            rel = f"log_{self._n:06d}.json"
            self._n += 1
            try:
                # atomic claim needs O_CREAT|O_EXCL, which StagedIO's
                # staged write cannot express; the zero-byte placeholder
                # is torn-by-construction until the staged commit lands
                # persistlint: waive(raw-durable-io) — O_EXCL slot claim
                fd = os.open(Path(self.io.root) / rel,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                continue     # slot taken by another instance: skip it
            os.close(fd)
            return rel

    def commit(self, results: Dict[int, list],
               evict: Sequence[int] = ()) -> None:
        """Commit a batch of finished requests and, in the *same* record
        and the same mixed plan/commit round on the dedup map, evict
        expired rids (one fence for the whole batch — the batched-map
        fence elision from core/batched.py) into an atomically claimed
        slot, so a concurrent RequestLog instance's commit is never
        overwritten.  An evicted rid leaves the exactly-once window: its
        result is dropped from the committed cache and a later request
        with that rid is served afresh."""
        with self.tracer.span("commit", n_results=len(results),
                              n_evict=len(evict)):
            rel = self._claim_slot()
            rec = {int(k): list(v) for k, v in results.items()}
            evict = sorted({int(r) for r in evict})
            if evict:
                payload = json.dumps({"results": rec, "evict": evict})
            else:
                payload = json.dumps(rec)   # legacy-compatible record
            self.io.write(rel, payload.encode())
            with self.tracer.span("flush_fence"):
                self.io.flush(rel)
                self.io.fence()
            self._folded.add(rel)
            m0, r0 = self._dedup.migrations, self._dedup.rebalances
            self._apply_record(rec, evict)
            if self.timeline is not None:
                # annotate live-traffic dedup growth/re-splits only (a
                # restart replay folds records through _apply_record
                # directly and stays silent)
                if self._dedup.migrations > m0:
                    self.timeline.annotate(
                        "dedup_migration",
                        rounds=self._dedup.migrations - m0)
                if self._dedup.rebalances > r0:
                    self.timeline.annotate(
                        "dedup_rebalance",
                        rounds=self._dedup.rebalances - r0)
        self.metrics.counter("serving_commits_total").inc()
        self.metrics.counter("serving_committed_rids_total").inc(len(rec))
        self.metrics.counter("serving_evicted_rids_total").inc(len(evict))

    def expired_rids(self, retain: int) -> List[int]:
        """Rids past the newest ``retain`` committed ones, in commit
        order (restart replays records in slot order, so the retention
        horizon survives recovery).  In ``ordered_dedup`` mode the
        window is ordered-by-rid instead: the sorted bottom list
        answers with one top-k walk + one tower-descended range scan
        (:meth:`repro.persistence.index.OrderedMembershipIndex.
        expired`) — the same rids for the engine's monotone streams."""
        if self._ordered:
            return [int(r) for r in self._dedup.expired(max(retain, 0))]
        done = list(self._results)
        if retain <= 0:
            return done
        return done[:-retain] if len(done) > retain else []

    def committed(self) -> Dict[int, list]:
        """All committed results, incrementally maintained: refresh()
        parses each durable log record exactly once and retains its
        rid -> result payload, so this is O(new records), not a full
        re-parse of the log per call.  Values are copied out so caller
        mutation cannot diverge the cache from the durable records."""
        self.refresh()
        return {k: list(v) for k, v in self._results.items()}

    # ---------------- detectable recovery ------------------------------ #
    def snapshot(self, truncate: bool = True) -> Optional[str]:
        """Publish a durable restart snapshot: the committed-results
        window plus its log horizon, written with the same flush → fence
        → atomic-publish discipline as a log record.  With ``truncate``
        (default) the records it covers — and the previous snapshot —
        are then unlinked, so a restart replays only the post-snapshot
        suffix: O(retention window), independent of log length.  The
        horizon never covers a torn record (it may still heal into a
        commit), and a crash anywhere in here is safe: before the
        publish the old snapshot still rules; after it, leftover covered
        records are re-trimmed by the next restart.  Snapshots are meant
        to be taken by the log's owning serving instance; other
        instances keep folding records as usual and adopt the snapshot
        on their own restart.  Returns the published snapshot filename,
        or None if nothing new is covered."""
        self.refresh()
        horizon = self._n
        for name in self._torn:
            idx = self._log_index(name)
            if idx is not None:
                horizon = min(horizon, idx)
        if horizon <= self._snap_horizon:
            return None
        with self.tracer.span("snapshot", horizon=horizon):
            payload = json.dumps(
                {"format": 1, "horizon": horizon,
                 "results": {str(k): list(v)
                             for k, v in self._results.items()}})
            final = f"snap_{horizon:08d}.json"
            self.io.write("snap.tmp", payload.encode())
            with self.tracer.span("flush_fence"):
                self.io.flush("snap.tmp")
                self.io.fence()
            with self.tracer.span("publish"):
                self.io.publish("snap.tmp", final)
            old_snap, self._snap_name = self._snap_name, final
            self._snap_horizon = horizon
            if self.timeline is not None:
                self.timeline.annotate("snapshot", horizon=horizon,
                                       n_results=len(self._results))
            if truncate:
                n_trimmed = self._truncate(horizon, old_snap)
                if self.timeline is not None:
                    self.timeline.annotate("truncate", horizon=horizon,
                                           n_trimmed=n_trimmed)
        self.metrics.counter("serving_snapshots_total").inc()
        return final

    def _truncate(self, horizon: int, old_snap: Optional[str]) -> int:
        """Unlink everything the just-published snapshot supersedes.
        Crash-safe by construction: every leftover is either below the
        published horizon (restart re-collects and trims it) or an older
        snapshot shadowed by the newer one.  Returns the number of
        files trimmed (timeline observability)."""
        n = 0
        for name in sorted(self._folded):
            idx = self._log_index(name)
            if idx is not None and idx < horizon:
                self._unlink_quiet(name)
                n += 1
        for name in sorted(self._stale):
            self._unlink_quiet(name)
            n += 1
        self._stale.clear()
        if old_snap is not None:
            self._unlink_quiet(old_snap)
            n += 1
        return n

    def took_effect(self, rids: Sequence[int]) -> np.ndarray:
        """Per-op detectable recovery ("Tracking in Order to Recover"):
        did each rid's operation take effect?  Answered from the durable
        dedup map in one batched lookup — no log replay, even
        immediately after a restart (the snapshot seeds the map with the
        whole window).  A rid evicted past the retention window answers
        False: its descriptor left the exactly-once window together with
        its result."""
        return self.is_committed(rids)

    def descriptor(self, rid: int) -> dict:
        """One rid's operation descriptor: whether it took effect and,
        if so, its committed result — what a recovering client reads
        instead of re-submitting blind."""
        took = bool(self.is_committed([rid])[0])
        res = self._results.get(int(rid))
        return {"rid": int(rid), "took_effect": took,
                "result": list(res) if took and res is not None else None}


def _stack_batch(prompts: List[np.ndarray]) -> np.ndarray:
    """Stack one equal-length batch of 1-D prompt token arrays.  The
    length uniformity is asserted, not papered over: a shorter row
    right-padded into a longer batch would attend over the pad tokens
    and its generation would change with batch composition — serve()
    groups requests by prompt length precisely so this never happens."""
    S = int(prompts[0].shape[0])
    assert all(int(p.shape[0]) == S for p in prompts), \
        "serve() must batch equal-length prompts"
    return np.stack(prompts).astype(np.int32)


class ServeEngine:
    def __init__(self, model, params, *, max_len: int, log_dir,
                 batch_size: int = 4, retain: Optional[int] = None,
                 log_shards: Optional[int] = None,
                 log_rebalance: bool = False,
                 ordered_dedup: bool = False,
                 snapshot_every: Optional[int] = None,
                 registry=None, timeline=None, obs: bool = True):
        """``retain`` bounds the exactly-once window: when set, each
        commit also evicts all but the newest ``retain`` committed rids
        from the durable dedup index — one mixed insert/delete round —
        so the serving map does not grow without bound under production
        traffic.  ``log_shards`` opts the request-log dedup map into the
        bucket-range-sharded backend (multi-device deployments);
        ``log_rebalance`` further lets it re-split its shard boundaries
        under live traffic when the rid stream skews (see
        :class:`repro.core.rebalance.RebalancingShardedMap`);
        ``ordered_dedup`` instead runs the dedup index on the ordered
        engine so retention eviction is an ordered-by-rid horizon trim
        (see :class:`RequestLog`).
        ``snapshot_every`` publishes a truncating
        :meth:`RequestLog.snapshot` after that many commits, keeping a
        restart O(retention window) instead of O(served history).
        ``registry``/``timeline``/``obs`` select the NVTrace metrics
        registry, the event timeline for snapshot/truncate/growth
        annotations, and toggle span/listener instrumentation (see
        :class:`RequestLog`); per-request serve latency lands in the
        ``serve_request_us`` histogram either way."""
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch = batch_size
        self.retain = retain
        self.snapshot_every = snapshot_every
        self._commits_since_snap = 0
        self.log = RequestLog(log_dir, shards=log_shards,
                              rebalance=log_rebalance,
                              ordered_dedup=ordered_dedup,
                              registry=registry, timeline=timeline,
                              obs=obs)
        self.metrics = self.log.metrics
        self.tracer = self.log.tracer
        self.timeline = self.log.timeline
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len))
        self._decode = jax.jit(model.decode_step)

    def _greedy_batch(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["vis"] = jnp.zeros((B, cfg.vis_tokens, cfg.d_model),
                                     jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                        jnp.float32)
        logits, caches = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        prefix = cfg.vis_tokens if cfg.family == "vlm" else 0
        for i in range(n_new):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(S + prefix + i))
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)        # [B, n_new]

    def serve(self, requests: Dict[int, np.ndarray], n_new: int = 8,
              *, crash_after_batches: Optional[int] = None) -> Dict[int, list]:
        """Serve a request dict {rid: prompt tokens[S]} and return the
        committed results for exactly the requested rids.  Ragged prompt
        lengths are handled by grouping requests into equal-length
        batches (shortest first, rid order within a group): a causal
        model's generation for a prompt is then independent of which
        other requests share its batch — right-padding mixed lengths
        instead would leak pad tokens into the shorter rows' attention.
        Already-committed rids are skipped (exactly-once) and answered
        from the log."""
        with self.tracer.span("route", n_requests=len(requests)):
            self.log.refresh()  # pick up other engine instances' commits
            rids = sorted(requests)
            todo = [rid for rid, done
                    in zip(rids, self.log.is_committed(rids)) if not done]
            groups: Dict[int, List[int]] = {}
            for rid in todo:
                groups.setdefault(int(requests[rid].shape[0]), []).append(rid)
        self.metrics.counter("serving_requests_total").inc(len(rids))
        self.metrics.counter("serving_dedup_hits_total").inc(
            len(rids) - len(todo))
        lat_hist = self.metrics.histogram("serve_request_us",
                                          lo=1.0, hi=1e8, growth=1.25)
        crashed = False
        batches = 0
        for length in sorted(groups):
            for i in range(0, len(groups[length]), self.batch):
                t_batch = time.perf_counter_ns()
                batch_rids = groups[length][i:i + self.batch]
                with self.tracer.span("plan", n=len(batch_rids),
                                      prompt_len=length):
                    prompts = _stack_batch(
                        [requests[r] for r in batch_rids])
                    gen = self._greedy_batch(prompts, n_new)  # traversal
                # never evict a rid this call is serving: its result was
                # just paid for and belongs in this call's return value
                expired = ([r for r in self.log.expired_rids(self.retain)
                            if r not in requests]
                           if self.retain is not None else ())
                self.log.commit({int(r): gen[j].tolist()  # the destination
                                 for j, r in enumerate(batch_rids)},
                                evict=expired)
                self._commits_since_snap += 1
                # every request in a (synchronous) batch experiences the
                # batch's wall time — that is its serve latency
                dur_us = (time.perf_counter_ns() - t_batch) / 1e3
                for _ in batch_rids:
                    lat_hist.record(dur_us)
                self.metrics.counter("serving_batches_total").inc()
                if self.snapshot_every is not None and \
                        self._commits_since_snap >= self.snapshot_every:
                    self.log.snapshot()
                    self._commits_since_snap = 0
                batches += 1
                if crash_after_batches is not None and \
                        batches >= crash_after_batches:
                    self.log.io.crash(evict="none")
                    crashed = True
                    break
            if crashed:
                break
        committed = self.log.committed()
        return {rid: committed[rid] for rid in requests if rid in committed}

    def took_effect(self, rids: Sequence[int]) -> np.ndarray:
        """Recovering-client probe: which of ``rids`` durably took
        effect (see :meth:`RequestLog.took_effect`) — answered without
        log replay."""
        self.log.refresh()
        return self.log.took_effect(rids)
