"""Activation sharding constraints (with_sharding_constraint hooks).

The model code calls :func:`constrain` at layout-critical points (post-QKV,
attention scores, block boundaries).  When no mesh is registered (unit
tests, single-device runs) the hooks are no-ops, so the model stays
mesh-agnostic; launch/dryrun + launch/train register the active mesh.

Divisibility-guarded like sharding/specs.py: an axis that does not divide
its dim is dropped from the constraint rather than relying on GSPMD
padding.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


class use_mesh:
    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        self.prev = _ACTIVE_MESH
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)


def _guard(dim: int, axes):
    if axes is None:
        return None
    mesh = _ACTIVE_MESH
    size = 1
    axes_t = axes if isinstance(axes, tuple) else (axes,)
    for a in axes_t:
        if a not in mesh.axis_names:
            return None
        size *= mesh.shape[a]
    return axes if dim % size == 0 and dim >= size else None


def constrain(x: jax.Array, *axes) -> jax.Array:
    """constrain(x, batch_axes, None, 'model', None) — guarded per-dim."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    spec = P(*[_guard(d, a) for d, a in zip(x.shape, axes)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes() -> Optional[Tuple[str, ...]]:
    mesh = _ACTIVE_MESH
    if mesh is None:
        return None
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain_like_params(tree, cfg):
    """Pin a params-shaped tree (e.g. the gradient accumulator) to the
    parameter sharding rules — without this the scan-carry accumulator's
    sharding is compiler-chosen and was observed to replicate over the
    model axis, inflating the gradient all-reduce 16× (§Perf)."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return tree
    from .specs import param_spec

    def one(path, leaf):
        names = tuple(getattr(p, "key", getattr(p, "name", str(p)))
                      for p in path)
        spec = param_spec(names if names else ("?",), leaf.shape, cfg, mesh)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)
