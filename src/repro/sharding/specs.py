"""PartitionSpec rules: DP / FSDP(ZeRO) / TP / EP / SP on the production mesh.

Baseline strategy (the §Perf pass iterates on it):

  * **DP**: the batch dim of activations over ``("pod","data")`` (multi-pod)
    or ``("data",)``; gradient reduction is implicit in GSPMD.
  * **TP** over ``"model"``: attention heads (Q and KV projections), FFN
    hidden, vocab (embedding + logits).
  * **FSDP/ZeRO** over ``"data"``: the *other* matrix dim of every large
    parameter is sharded over the data axis, so parameters and optimizer
    slots are stored fully sharded; XLA all-gathers them per layer inside
    the scanned block (overlappable) and reduce-scatters gradients.
  * **EP** over ``"model"`` (arctic: 128 % 16 == 0): expert dim sharded,
    token all-to-all induced by GSPMD; qwen2-moe (60 experts) uses the TP
    strategy (expert d_ff over ``"model"``) instead — divisibility rules in
    DESIGN.md §5.
  * **SP**: decode KV caches shard the KV-head dim over ``"model"`` when it
    divides, otherwise the *sequence* dim (flash-decode style); long_500k
    (batch=1) shards sequence over ``"data"`` too.

Every rule is divisibility-guarded: a dim that an axis does not divide is
left unsharded rather than relying on GSPMD padding (keeps memory_analysis
honest).  What got replicated is queryable via ``explain()`` for the
roofline notes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.mamba2 import SSMCache  # noqa: F401 (pytree registration)


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _ok(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return dim % size == 0 and dim >= size


def _spec(mesh: Mesh, shape, *axes) -> P:
    """Divisibility-guarded PartitionSpec.

    Rules are written for the parameter's natural rank; scanned stacks add
    a leading [n_layers] dim, so axes are aligned to the TRAILING dims and
    leading extra dims stay unsharded (the 62-layer stacked-params bug from
    the baseline dry-run — EXPERIMENTS.md §Perf #0)."""
    lead = max(0, len(shape) - len(axes))
    out = [None] * lead
    for dim, ax in zip(shape[lead:], axes[-(len(shape) - lead):] if
                       len(shape) > lead else ()):
        out.append(ax if ax is not None and _ok(dim, mesh, ax) else None)
    return P(*out)


# --------------------------------------------------------------------- #
# parameters                                                             #
# --------------------------------------------------------------------- #
def param_spec(path: Tuple[str, ...], shape, cfg, mesh: Mesh,
               *, infer: bool = False) -> P:
    """Sharding rule for one parameter, keyed on its tree path.

    ``infer=True`` (prefill/decode cells): drop the ZeRO/FSDP storage axis
    — inference has no optimizer state, so params are stored model-sharded
    and replicated over the data axes, eliminating the per-layer parameter
    all-gathers entirely (§Perf B4)."""
    name = path[-1]
    fsdp = None if infer else "data"   # ZeRO storage axis
    tp = "model"

    if name in ("embed",):
        # feature-dim sharding only: a vocab-sharded table turns the token
        # gather into an involuntary full rematerialization under GSPMD
        # (observed in the baseline dry-run; EXPERIMENTS.md §Perf #0)
        return _spec(mesh, shape, None, tp)          # [V, D]
    if name == "lm_head":
        return _spec(mesh, shape, fsdp, tp)          # [D, V]
    if name in ("enc_pos", "dec_pos"):
        return _spec(mesh, shape, None, fsdp)
    if name in ("wq", "wk", "wv", "wqkv"):
        return _spec(mesh, shape, fsdp, tp)          # [D, (H+2K)*dh]
    if name == "wo":
        return _spec(mesh, shape, tp, fsdp)          # [H*dh, D]
    if name in ("bq", "bk", "bv", "bqkv"):
        return _spec(mesh, shape, tp)
    if name in ("w_gate", "w_up", "w_down", "w_gate_up") \
            and "experts" in path:
        if cfg.moe_strategy == "ep":
            # EP: experts over model, ZeRO d_model/d_ff over data
            if name == "w_down":                     # [E, F, D]
                return _spec(mesh, shape, tp, fsdp, None)
            return _spec(mesh, shape, tp, fsdp, None)  # [E, D, F]
        # TP: expert hidden over model, ZeRO d_model over data
        if name == "w_down":                         # [E, F, D]
            return _spec(mesh, shape, None, tp, fsdp)
        return _spec(mesh, shape, None, fsdp, tp)    # [E, D, F]
    if name in ("w_gate", "w_up", "w_gate_up"):
        return _spec(mesh, shape, fsdp, tp)          # [D, F] / [D, 2F]
    if name == "w_down":
        return _spec(mesh, shape, tp, fsdp)          # [F, D]
    if name == "router":
        return _spec(mesh, shape, fsdp, None)        # [D, E]
    ssm_tp = tp if getattr(cfg, "ssm_proj_tp", True) else None
    if name == "in_proj":
        return _spec(mesh, shape, fsdp, ssm_tp)      # [D, di+cdim+H]
    if name == "out_proj":
        return _spec(mesh, shape, ssm_tp, fsdp)      # [di, D]
    if name == "out_norm":
        return _spec(mesh, shape, ssm_tp)            # [di]
    if name == "conv_w":
        return _spec(mesh, shape, None, ssm_tp)      # [ck, cdim]
    if name == "conv_b":
        return _spec(mesh, shape, ssm_tp)
    # norms, scalars, per-head vectors: replicate
    return P()


def params_shardings(params_shape, cfg, mesh: Mesh, *, infer: bool = False):
    """Tree of NamedSharding matching a params(-shaped) tree.

    ``params_shape``: pytree of ShapeDtypeStruct or arrays.  Works for
    optimizer state too (same leaf paths modulo slot nesting — the rule only
    inspects the last path components that name the parameter)."""
    def one(path, leaf):
        names = tuple(getattr(p, "key", getattr(p, "name", str(p)))
                      for p in path)
        # optimizer slots nest under mu/nu/vr/vc/v — strip them
        names = tuple(n for n in names if n not in
                      ("mu", "nu", "vr", "vc", "v"))
        shape = leaf.shape
        spec = param_spec(names if names else ("?",), shape, cfg, mesh,
                          infer=infer)
        # factored Adafactor slots drop the last dim; re-guard rank
        if len(spec) > len(shape):
            spec = P(*spec[:len(shape)])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# --------------------------------------------------------------------- #
# activations / inputs / caches                                          #
# --------------------------------------------------------------------- #
def batch_spec(mesh: Mesh, global_batch: int, rank: int = 2) -> P:
    ba = batch_axes(mesh)
    if not _ok(global_batch, mesh, ba):
        ba = ("data",) if _ok(global_batch, mesh, ("data",)) else None
    return P(ba, *([None] * (rank - 1)))


def attn_cache_spec(cfg, mesh: Mesh, batch: int) -> P:
    """[L, B, S, K, dh] KV cache: heads over model when divisible, else
    sequence over model; batch over data axes; batch=1 also shards the
    sequence over data (long-context SP)."""
    ba = batch_axes(mesh)
    K = cfg.n_kv_heads
    heads_ok = K % mesh.shape["model"] == 0
    if batch == 1:
        seq_ax = "data" if heads_ok else ("data", "model")
        return P(None, None, seq_ax, "model" if heads_ok else None, None)
    bax = ba if batch % _axsize(mesh, ba) == 0 else (
        ("data",) if batch % mesh.shape["data"] == 0 else None)
    if heads_ok:
        return P(None, bax, None, "model", None)
    return P(None, bax, "model", None, None)


def _axsize(mesh, axes):
    s = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        s *= mesh.shape[a]
    return s


def ssm_cache_spec(cfg, mesh: Mesh, batch: int):
    """SSMCache(state=[L,B,H,P,N], conv=[L,B,ck-1,cdim]) sharding."""
    ba = batch_axes(mesh)
    bax = ba if batch % _axsize(mesh, ba) == 0 else None
    h_ax = "model" if cfg.ssm_heads % mesh.shape["model"] == 0 else None
    cd_ax = "model" if (cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state) \
        % mesh.shape["model"] == 0 else None
    return SSMCache(state=P(None, bax, h_ax, None, None),
                    conv=P(None, bax, None, cd_ax))


def caches_shardings(cfg, mesh: Mesh, batch: int):
    """Sharding tree matching Model.init_caches output."""
    fam = cfg.family
    kv = lambda: {"k": NamedSharding(mesh, attn_cache_spec(cfg, mesh, batch)),
                  "v": NamedSharding(mesh, attn_cache_spec(cfg, mesh, batch))}
    if fam in ("dense", "vlm", "moe"):
        return kv()
    if fam == "ssm":
        sp = ssm_cache_spec(cfg, mesh, batch)
        return SSMCache(state=NamedSharding(mesh, sp.state),
                        conv=NamedSharding(mesh, sp.conv))
    if fam == "hybrid":
        sp = ssm_cache_spec(cfg, mesh, batch)
        return {"ssm": SSMCache(state=NamedSharding(mesh, sp.state),
                                conv=NamedSharding(mesh, sp.conv)),
                "attn": kv()}
    if fam == "encdec":
        return {"self": kv(), "cross": kv()}
    raise ValueError(fam)
