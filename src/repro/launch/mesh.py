"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a 2-pod outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small host mesh for unit tests (requires device count >= product)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_map_mesh(n_shards: int):
    """1-D mesh for the sharded durable map (core/sharded.py): the map's
    bucket ranges partition along the single ``"shards"`` axis.  Requires
    ``n_shards`` devices (force host devices for CPU testing with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes)."""
    return jax.make_mesh((n_shards,), ("shards",))


def make_map_splits(n_buckets: int, n_shards: int, loads=None):
    """Contiguous bucket-range boundaries (``n_shards + 1`` ints) for
    the sharded durable map — the construction half of cross-shard
    rebalancing (``ShardedDurableMap.rebalance`` consumes these).

    Without ``loads`` this is the even partition.  With ``loads`` (one
    nonnegative weight per *global* bucket, e.g. per-bucket chain
    lengths or flush counters from ``ShardCommitStats.bucket_flushes``)
    the boundaries split the cumulative load into ``n_shards`` equal
    quantiles, so a skewed key distribution lands ranges of equal
    *work* rather than equal width.  Every range is kept non-empty.

    >>> make_map_splits(64, 4)
    (0, 16, 32, 48, 64)
    >>> make_map_splits(8, 2, loads=[12.0, 0, 0, 0, 0, 0, 0, 0])
    (0, 1, 8)
    """
    if loads is None:
        from ..core.sharded import even_splits
        return even_splits(n_buckets, n_shards)
    import numpy as np
    loads = np.asarray(loads, np.float64)
    if loads.shape != (n_buckets,):
        raise ValueError(f"loads must have shape ({n_buckets},)")
    cum = np.cumsum(loads + 1e-12)        # epsilon: empty buckets still
    total = cum[-1]                       # advance the quantile walk
    bounds = [0]
    for s in range(1, n_shards):
        b = int(np.searchsorted(cum, total * s / n_shards, side="left"))
        b = min(max(b, bounds[-1] + 1), n_buckets - (n_shards - s))
        bounds.append(b)
    bounds.append(n_buckets)
    return tuple(bounds)


def replan_splits(splits, loads, *, threshold: float = 1.5):
    """Split re-planning: should the current bucket-range boundaries
    move, given the cumulative per-bucket load since they were set?

    ``splits`` are the current ``n_shards + 1`` boundaries, ``loads``
    one nonnegative weight per global bucket (e.g. the accumulated
    ``CommitStats.bucket_flushes``).  Returns ``(new_splits, imbalance)``
    where ``imbalance`` is the hottest shard's load over the mean
    per-shard load (1.0 = perfectly balanced) and ``new_splits`` is the
    load-quantile re-plan from :func:`make_map_splits` — or ``None``
    when no move is warranted: the imbalance is within ``threshold``,
    there is no load at all, or the re-plan reproduces the current
    boundaries (a single ultra-hot bucket cannot be split further;
    returning ``None`` then prevents trigger thrashing).  This is the
    decision function behind
    :class:`repro.core.rebalance.AutoRebalancePolicy`.

    >>> replan_splits((0, 2, 4), [10.0, 10.0, 10.0, 10.0])
    (None, 1.0)
    >>> replan_splits((0, 2, 4), [40.0, 0.0, 0.0, 0.0])
    ((0, 1, 4), 2.0)
    """
    import numpy as np
    splits = tuple(int(b) for b in splits)
    n_shards = len(splits) - 1
    n_buckets = splits[-1]
    loads = np.asarray(loads, np.float64)
    if loads.shape != (n_buckets,):
        raise ValueError(f"loads must have shape ({n_buckets},)")
    per = np.asarray([loads[a:b].sum()
                      for a, b in zip(splits, splits[1:])])
    total = float(per.sum())
    if total <= 0:
        return None, 1.0
    imbalance = float(per.max() / (total / n_shards))
    if imbalance <= threshold:
        return None, imbalance
    new = tuple(make_map_splits(n_buckets, n_shards, loads=loads))
    if new == splits:
        return None, imbalance
    return new, imbalance


# TPU v5e hardware constants (roofline terms, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
