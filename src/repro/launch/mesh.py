"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a 2-pod outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small host mesh for unit tests (requires device count >= product)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_map_mesh(n_shards: int):
    """1-D mesh for the sharded durable map (core/sharded.py): the map's
    bucket ranges partition along the single ``"shards"`` axis.  Requires
    ``n_shards`` devices (force host devices for CPU testing with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes)."""
    return jax.make_mesh((n_shards,), ("shards",))


# TPU v5e hardware constants (roofline terms, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
