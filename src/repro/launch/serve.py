"""Serving driver: batched requests against any assigned arch (reduced or
full config) with the durable request log.

    PYTHONPATH=src python -m repro.launch.serve --arch tiny:qwen2-7b \
        --requests 8 --new-tokens 8 [--crash-after 1]
"""
from __future__ import annotations

import argparse
import json
import tempfile

import jax
import numpy as np

from ..configs.registry import get_arch, tiny
from ..models.model import build_model
from ..serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny:qwen2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--log-dir", default=None)
    ap.add_argument("--crash-after", type=int, default=None,
                    help="crash after N committed batches (test recovery)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (tiny(get_arch(args.arch[5:])) if args.arch.startswith("tiny:")
           else get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    requests = {i: rng.integers(0, cfg.vocab,
                                size=args.prompt_len).astype(np.int32)
                for i in range(args.requests)}
    log_dir = args.log_dir or tempfile.mkdtemp(prefix="serve_log_")
    max_len = args.prompt_len + args.new_tokens + (
        cfg.vis_tokens if cfg.family == "vlm" else 0)
    eng = ServeEngine(model, params, max_len=max_len, log_dir=log_dir,
                      batch_size=args.batch_size)
    out = eng.serve(requests, n_new=args.new_tokens,
                    crash_after_batches=args.crash_after)
    print(json.dumps({"arch": cfg.name, "committed": len(out),
                      "log_dir": log_dir,
                      "sample": {str(k): out[k] for k in list(out)[:3]}},
                     indent=1))
    if args.crash_after is not None:
        print("crashed after", args.crash_after,
              "batches; re-run with --log-dir", log_dir, "to recover")


if __name__ == "__main__":
    main()
