"""Fault-tolerant end-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tiny:qwen3-1.7b \
        --steps 60 --ckpt-every 10 --ckpt-dir /tmp/ckpt [--crash-at 25]

Features exercised here (and by tests/test_train_loop.py):
  * NVTraverse checkpoint commit every k steps (delta shards + one fence +
    atomic manifest publish) — the paper's destination-not-journey rule;
  * crash injection at any step / commit sub-phase; restart resumes from
    the newest committed manifest with the data pipeline cursor restored —
    the continued run must be bit-identical to an uninterrupted one;
  * elastic restart: ``--mesh dxm`` may differ across restarts (manifests
    are layout-agnostic);
  * heartbeat + straggler hook: each step writes a heartbeat; a step
    exceeding ``--step-deadline`` is logged as a straggler event (on a
    real cluster the elastic controller would re-mesh; here it feeds the
    log so the policy is testable);
  * optional bf16 gradient compression with error feedback for the
    cross-pod axis (multi-pod meshes).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from ..configs.base import ShapeConfig
from ..configs.registry import get_arch, tiny
from ..data.pipeline import TokenPipeline
from ..models.model import build_model
from ..persistence.checkpoint import CheckpointManager
from ..training.optimizer import make_optimizer
from ..training.train_loop import make_train_step


def parse_arch(spec: str):
    if spec.startswith("tiny:"):
        return tiny(get_arch(spec[5:]))
    return get_arch(spec)


def run_training(*, arch: str, steps: int, ckpt_dir: str,
                 ckpt_every: int = 10, global_batch: int = 8,
                 seq_len: int = 64, crash_at: int = -1,
                 crash_phase: str = "between",
                 step_deadline: float = 120.0,
                 policy: str = "nvtraverse", seed: int = 0) -> dict:
    cfg = parse_arch(arch)
    shape = ShapeConfig("train", seq_len, global_batch, "train")
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    train_step = jax.jit(make_train_step(model, cfg, opt),
                         donate_argnums=(0, 1))
    pipeline = TokenPipeline(cfg, shape, seed=seed,
                             microbatches=max(1, cfg.microbatches))
    mgr = CheckpointManager(ckpt_dir, policy=policy)
    hb_path = Path(ckpt_dir) / "heartbeat.json"
    log = []

    # ---- restore-or-init ------------------------------------------------ #
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    start_step = 0
    man, restored = mgr.restore({"params": params, "opt": opt_state})
    if man is not None:
        params, opt_state = restored["params"], restored["opt"]
        start_step = man.step
        pipeline.restore(man.aux.get("pipeline"))
        log.append(f"resumed from committed step {man.step}")

    step = start_step
    losses = {}
    stragglers = []
    while step < steps:
        t0 = time.time()
        batch = pipeline.next_batch()
        params, opt_state, metrics = train_step(
            params, opt_state, batch, np.int32(step))
        loss = float(metrics["loss"])
        step += 1
        dt = time.time() - t0
        if dt > step_deadline:
            stragglers.append({"step": step, "seconds": dt})
        hb_path.parent.mkdir(parents=True, exist_ok=True)
        hb_path.write_text(json.dumps(
            {"step": step, "t": time.time(), "loss": loss}))
        losses[step] = loss

        if crash_at == step and crash_phase == "between":
            mgr.io.crash(evict="none")
            return {"crashed_at": step, "losses": losses, "log": log}

        if step % ckpt_every == 0 or step == steps:
            crash_after = (crash_phase if crash_at == step
                           and crash_phase in ("shards", "manifest")
                           else None)
            man = mgr.save(step, {"params": params, "opt": opt_state},
                           aux={"pipeline": pipeline.snapshot(),
                                "arch": cfg.name, "loss": loss},
                           crash_after=crash_after)
            if man is None:             # injected crash mid-commit
                mgr.io.crash(evict="none")
                return {"crashed_at": step, "losses": losses, "log": log}

    return {"final_step": step, "losses": losses, "log": log,
            "stragglers": stragglers,
            "final_loss": losses.get(step),
            "io": mgr.io.counters.snapshot()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny:qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--crash-at", type=int, default=-1)
    ap.add_argument("--crash-phase", default="between",
                    choices=["between", "shards", "manifest"])
    ap.add_argument("--policy", default="nvtraverse",
                    choices=["nvtraverse", "izraelevitz"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run_training(arch=args.arch, steps=args.steps,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       global_batch=args.global_batch,
                       seq_len=args.seq_len, crash_at=args.crash_at,
                       crash_phase=args.crash_phase, policy=args.policy,
                       seed=args.seed)
    print(json.dumps({k: v for k, v in out.items() if k != "losses"},
                     indent=1))
    if out.get("final_loss") is not None:
        print(f"final loss: {out['final_loss']:.4f}")
    else:
        print("final loss: n/a (already at target step)")


if __name__ == "__main__":
    main()
