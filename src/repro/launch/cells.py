"""Dry-run cell construction: (arch × shape × mesh) → a jit-able step
function + ShapeDtypeStruct inputs + in/out shardings.

A *cell* lowers exactly what the assignment specifies:
  * ``train_*``   → ``train_step`` (grad-accum scan + optimizer update);
  * ``prefill_*`` → forward over the prompt, logits + KV caches out;
  * ``decode_*`` / ``long_*`` → ``serve_step`` (ONE new token against a
    KV cache of seq_len).

ShapeDtypeStructs only — no device allocation ever happens here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, ArchConfig, ShapeConfig
from ..configs.registry import get_arch
from ..models.model import build_model
from ..sharding import specs as SH
from ..training.optimizer import make_optimizer
from ..training.train_loop import make_train_step


@dataclasses.dataclass
class Cell:
    arch: ArchConfig
    shape: ShapeConfig
    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_structs(cfg, B: int, S: int, *, train: bool):
    """Model input batch (token count S; +1 labels column for training)."""
    cols = S + 1 if train else S
    batch = {"tokens": _sds((B, cols), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vis"] = _sds((B, cfg.vis_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def _batch_shardings(cfg, mesh, batch_structs, B, *, microbatched=False):
    def sh(leaf):
        spec = SH.batch_spec(
            mesh, B, rank=len(leaf.shape) - (1 if microbatched else 0))
        if microbatched:   # [M, B/M, ...]: DP shard rides on dim 1
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)
    return jax.tree.map(sh, batch_structs)


def make_cell(arch_name, shape_name, mesh: Mesh) -> Cell:
    """arch_name/shape_name may be names or (ArchConfig, ShapeConfig)
    instances (the dry-run cost pass passes reduced-depth overrides)."""
    cfg = get_arch(arch_name) if isinstance(arch_name, str) else arch_name
    shape = SHAPES[shape_name] if isinstance(shape_name, str) else shape_name
    if shape.kind == "prefill" and getattr(cfg, "sp_prefill", False) \
            and not cfg.sp:
        cfg = dataclasses.replace(cfg, sp=True)   # §Perf B3: fwd-only SP
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    key_struct = _sds((2,), jnp.uint32)
    params_struct = jax.eval_shape(model.init, key_struct)
    # inference cells store params without the ZeRO axis (no optimizer
    # state to shard; kills the per-layer param all-gathers — §Perf B4) —
    # guarded: only when the model-sharded copy fits comfortably per chip
    # (arctic-480b at 960GB/16 = 60GB per chip must stay ZeRO-sharded).
    import numpy as _np
    param_bytes = sum(int(_np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree.leaves(params_struct))
    per_chip_replicated = param_bytes / mesh.shape["model"]
    infer = shape.kind != "train" and per_chip_replicated < 6e9
    params_sh = SH.params_shardings(params_struct, cfg, mesh, infer=infer)

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        opt_sh = SH.params_shardings(opt_struct, cfg, mesh)
        batch = _batch_structs(cfg, B, S, train=True)
        M = max(1, cfg.microbatches)
        if M > 1:   # pre-shaped [M, B/M, ...]; dim 1 carries the DP shard
            batch = jax.tree.map(
                lambda l: _sds((M, l.shape[0] // M) + l.shape[1:], l.dtype),
                batch)
        batch_sh = _batch_shardings(cfg, mesh, batch, B // M,
                                    microbatched=(M > 1))
        step_struct = _sds((), jnp.int32)
        train_step = make_train_step(model, cfg, opt)
        repl = NamedSharding(mesh, P())
        return Cell(
            arch=cfg, shape=shape, fn=train_step,
            args=(params_struct, opt_struct, batch, step_struct),
            in_shardings=(params_sh, opt_sh, batch_sh, repl),
            out_shardings=(params_sh, opt_sh, {"loss": repl}),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        batch = _batch_structs(cfg, B, S, train=False)
        batch_sh = _batch_shardings(cfg, mesh, batch, B)
        max_len = S + (cfg.vis_tokens if cfg.family == "vlm" else 0)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_len)

        return Cell(
            arch=cfg, shape=shape, fn=prefill_fn,
            args=(params_struct, batch),
            in_shardings=(params_sh, batch_sh),
            out_shardings=None,       # compiler-chosen for prefill outputs
        )

    # decode / long-context decode: serve_step (one token, cache of len S)
    caches_struct = jax.eval_shape(lambda: model.init_caches(B, S))
    caches_sh = SH.caches_shardings(cfg, mesh, B)
    tokens_struct = _sds((B,), jnp.int32)
    tok_spec = SH.batch_spec(mesh, B, rank=1)
    repl = NamedSharding(mesh, P())
    logits_sh = NamedSharding(
        mesh, P(tok_spec[0], None,
                "model" if _vocab_divisible(cfg, mesh) else None))

    def serve_step(params, tokens, caches, pos):
        return model.decode_step(params, tokens, caches, pos)

    return Cell(
        arch=cfg, shape=shape, fn=serve_step,
        args=(params_struct, tokens_struct, caches_struct,
              _sds((), jnp.int32)),
        in_shardings=(params_sh, NamedSharding(mesh, tok_spec),
                      caches_sh, repl),
        out_shardings=(logits_sh, caches_sh),
        donate_argnums=(2,),
    )


def _vocab_divisible(cfg, mesh) -> bool:
    from ..models.model import padded_vocab
    return padded_vocab(cfg) % mesh.shape["model"] == 0


def lower_cell(cell: Cell, mesh: Mesh):
    """lower() the cell inside its mesh context (also registers the mesh
    with the activation-constraint hooks in sharding/constraints.py)."""
    from ..sharding.constraints import use_mesh
    with mesh, use_mesh(mesh):
        jitted = jax.jit(cell.fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        return jitted.lower(*cell.args)
