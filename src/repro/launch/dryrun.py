import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  This module is the ONLY place the 512
# placeholder devices exist; tests and benches see the real device count.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input-shape × mesh) cell:
    lowered  = jax.jit(step, in_shardings, out_shardings).lower(*specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / collective-bytes(HLO) → JSON

Meshes: single-pod (16, 16) ("data","model") and multi-pod (2, 16, 16)
("pod","data","model") — 512 chips.  The multi-pod pass proves the "pod"
axis shards; the roofline table (EXPERIMENTS.md §Roofline) reads the
single-pod JSONs.

Usage:
    python -m repro.launch.dryrun --cells all --mesh both
    python -m repro.launch.dryrun --cells gemma3-27b:train_4k --mesh single
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path


def parse_collectives(hlo_text: str) -> dict:
    """Sum collective bytes from the post-SPMD optimized HLO.

    Shapes in the partitioned module are PER-DEVICE.  Bytes-on-the-wire
    model (ring algorithms, n >> 1): all-gather ≈ result bytes;
    reduce-scatter ≈ operand bytes ≈ result×n/n; all-reduce ≈ 2× operand;
    all-to-all / collective-permute ≈ operand bytes.
    """
    dtb = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
           "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
           "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
    ops = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}
    pat = re.compile(
        r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
        r"all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)\(")
    out = {k: {"count": 0, "bytes": 0.0} for k in ops}
    for m in pat.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in dtb:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op]["count"] += 1
        out[op]["bytes"] += n * dtb[dt] * ops[op]
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _compile_once(cfg, shape, mesh):
    from repro.launch.cells import make_cell, lower_cell
    t0 = time.time()
    compiled = lower_cell(make_cell(cfg, shape, mesh), mesh).compile()
    return compiled, round(time.time() - t0, 1)


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    out = {k: float(v) for k, v in cost.items()
           if isinstance(v, (int, float))
           and ("flops" in k or "bytes" in k)}
    out["collectives"] = parse_collectives(compiled.as_text())
    return out


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             outdir: Path, *, cost_pass: bool = True) -> dict:
    """Dual-pass dry-run for one cell.

    * memory pass — scanned stacks, full depth: the deployable program.
      ``memory_analysis()`` proves the per-device footprint; this is also
      the lower+compile that MUST succeed for deliverable (e).
    * cost pass (single-pod only) — XLA's cost analysis counts while-loop
      bodies once, so scanned numbers undercount by ~n_layers.  The cost
      pass lowers the stack UNROLLED at two reduced depths L1 < L2 (one
      and two pattern-periods) and extrapolates linearly to full depth
      (layers are homogeneous), then scales by the microbatch count for
      train cells.  Raw L1/L2 numbers are recorded alongside.
    """
    import dataclasses as dc
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_production_mesh

    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind}
    if shape_name in cfg.skip_shapes:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §4)")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["n_devices"] = int(mesh.devices.size)

    # ---- memory pass ---------------------------------------------------- #
    compiled, secs = _compile_once(cfg, shape, mesh)
    rec["compile_s"] = secs
    mem = compiled.memory_analysis()
    print(mem)  # proves it fits (per-device bytes)
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            rec[attr] = int(v)
    rec["scanned_cost"] = _cost_of(compiled)
    del compiled

    # ---- cost pass (single-pod roofline numbers) ------------------------ #
    if cost_pass and mesh_kind == "single":
        period = max(cfg.shared_attn_every,
                     cfg.local_per_global + 1 if cfg.local_per_global else 0,
                     2)
        L1, L2 = period, 2 * period
        M = max(1, cfg.microbatches) if shape.kind == "train" else 1
        sh1 = (dc.replace(shape, global_batch=shape.global_batch // M)
               if M > 1 else shape)
        raws = {}
        for L in (L1, L2):
            c = dc.replace(cfg, n_layers=L, scan_layers=False,
                           microbatches=1,
                           enc_layers=L if cfg.enc_layers else 0)
            compiled, secs = _compile_once(c, sh1, mesh)
            raws[L] = _cost_of(compiled)
            raws[L]["compile_s"] = secs
            del compiled
        rec["cost_raw"] = {str(k): v for k, v in raws.items()}

        def extrap(key_fn):
            c1, c2 = key_fn(raws[L1]), key_fn(raws[L2])
            delta = (c2 - c1) / (L2 - L1)
            return (c1 + delta * (cfg.n_layers - L1)) * M

        rec["cost"] = {
            "flops": extrap(lambda r: r.get("flops", 0.0)),
            "bytes_accessed": extrap(lambda r: r.get("bytes accessed", 0.0)),
            "collective_bytes": extrap(
                lambda r: r["collectives"]["total_bytes"]),
            "collective_detail": {
                op: extrap(lambda r, op=op: r["collectives"][op]["bytes"])
                for op in ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute")},
            "method": f"unrolled L1={L1},L2={L2} linear extrapolation, xM={M}",
        }
        print({k: v for k, v in rec["cost"].items() if k != "collective_detail"})
    rec["status"] = "ok"
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="all",
                    help="'all' or comma-separated arch:shape pairs")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import SHAPES
    from repro.configs.registry import ARCHS

    outdir = Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    if args.cells == "all":
        wanted = [(a, s) for a in sorted(ARCHS) for s in SHAPES]
    else:
        wanted = [tuple(c.split(":")) for c in args.cells.split(",")]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    failures = 0
    for arch, shape in wanted:
        for mk in meshes:
            name = f"{arch}__{shape}__{mk}"
            path = outdir / f"{name}.json"
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {name}: cached ({prev['status']})")
                    continue
            print(f"[dryrun] {name}: lowering...", flush=True)
            try:
                rec = run_cell(arch, shape, mk, outdir)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
                failures += 1
            path.write_text(json.dumps(rec, indent=1))
            print(f"[dryrun] {name}: {rec['status']} "
                  f"(lower {rec.get('lower_s', '-')}s, "
                  f"compile {rec.get('compile_s', '-')}s)", flush=True)
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
