"""Subprocess worker for the compile-attribution part of ``bench_nvt``'s
``obs`` section.

Run as ``python -m benchmarks.obs_worker N_DEV``: forces ``N_DEV`` host
platform devices (the flag must land before jax initializes, which is
why this is a subprocess of the parent bench) and exercises both
recompile triggers the :class:`repro.obs.compile.CompileTracker` knows
how to attribute on the live sharded-map path:

  * **resplit_width_change** — the zipf-skewed stream from the
    ``rebalance_live`` bench drives a :class:`RebalancingShardedMap`
    with the auto policy armed; the re-split changes the max range
    width, the ``shard_map`` closures miss their cache, and the first
    calls on the new geometry are timed inside the rebalance engine's
    ``reason("resplit_width_change")`` blocks.
  * **capacity_ladder** — an explicit ``migrate_to(capacity=2x)`` drain
    afterwards, recorded under ``reason("capacity_ladder")``.

Stdout is one JSON document: per-trigger ``{events, stall_us}`` totals
(``compile``), the individual :class:`CompileEvent` records, how many
re-splits actually completed, and the post-stream ``map_load_imbalance``
gauge — everything the parent needs to attribute the ROADMAP's re-split
recompile tax.
"""
import json
import os
import re
import sys
import time


def main() -> None:
    n_dev = int(sys.argv[1])
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       os.environ.get("XLA_FLAGS", "")).strip()
    os.environ["XLA_FLAGS"] = (
        inherited
        + f" --xla_force_host_platform_device_count={n_dev}").strip()
    import numpy as np
    from repro.core import batched as B
    from repro.core.rebalance import (AutoRebalancePolicy,
                                      RebalancingShardedMap)
    from repro.obs.compile import get_tracker
    from repro.obs.metrics import get_registry

    S, NB = n_dev, 128
    CAP, BATCH, ROUNDS = 1 << 15, 1024, 24
    rng = np.random.default_rng(5)

    # same adversarial stream as benchmarks/rebalance_worker.py: zipf
    # ranks mapped onto keys sorted by global bucket, so the hot keys
    # concentrate in the low ranges and the auto policy must re-split
    domain = np.arange(1, 20001, dtype=np.int32)
    by_bucket = domain[np.argsort(B.bucket_of_np(domain, NB),
                                  kind="stable")]

    def draw(n):
        ranks = np.minimum(rng.zipf(1.3, size=n), domain.size) - 1
        return by_bucket[ranks]

    trk = get_tracker()
    trk.reset()
    m = RebalancingShardedMap(
        S, capacity=CAP, n_buckets=NB, rounds_per_update=2,
        policy=AutoRebalancePolicy(threshold=1.3, min_load=4096,
                                   check_every=2))
    t0 = time.perf_counter()
    for _ in range(ROUNDS):
        ops = rng.integers(0, 2, BATCH).astype(np.int32)
        m.update(ops, draw(BATCH),
                 rng.integers(0, 1000, BATCH).astype(np.int32))
    if m.rebalancing:
        m.run_rebalance()
    stream_s = time.perf_counter() - t0

    # one explicit capacity-ladder step on the (now re-split) inner map:
    # the new pool's shapes miss every warm signature and the drain's
    # first calls land under reason("capacity_ladder")
    m2, _ = m.map.migrate_to(capacity=2 * CAP)

    json.dump({
        "devices": S,
        "n_buckets": NB,
        "batches": ROUNDS,
        "stream_s": stream_s,
        "rebalances": m.rebalances_completed,
        "splits_final": list(m.splits),
        "final_capacity": m2.capacity,
        "compile": trk.stats(),
        "events": [ev.to_dict() for ev in trk.events],
        "load_imbalance_gauge": get_registry().gauge(
            "map_load_imbalance").value,
    }, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
