"""Subprocess worker for the sharded bench section of ``bench_nvt``.

Run as ``python -m benchmarks.sharded_worker N_DEV``: forces ``N_DEV``
host platform devices (the flag must land *before* jax initializes,
which is why this is a subprocess and not a function of the parent
bench), replays the same mixed-workload points as the single-device
``bench_nvt`` section (PR 2: 20k-op batches at 0/20/50%% update ratio
over a 10k-key pre-populated map, identical seeds), and compares the
bucket-range-sharded map against the single-device plan/commit engine:

  * state identity: gathered per-key values + liveness, aggregate
    flush/fence counts, per-op ok flags, and lookup results must all
    match the single-device engine bit for bit, and the stacked
    per-bucket flush counters must equal the single-device engine's
    (same global bucket for every key — the sharded map is a
    bucket-permutation-equivalent layout, not a re-hash);
  * persistence locality: ``foreign_ops`` (valid ops a shard received
    for buckets outside its own range) must be 0 on every shard;
  * ``chain_stats`` per workload point (max/mean chain length, load
    factor) as the baseline for future resize/rehash work.

Prints one JSON document on stdout; the parent merges it under
``BENCH_nvt.json["sharded"][str(N_DEV)]``.
"""
import json
import os
import re
import sys
import time


def main() -> None:
    n_dev = int(sys.argv[1])
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       os.environ.get("XLA_FLAGS", "")).strip()
    os.environ["XLA_FLAGS"] = (
        inherited
        + f" --xla_force_host_platform_device_count={n_dev}").strip()
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import batched as B
    from repro.core.sharded import ShardedDurableMap, items_of_state
    from benchmarks.run import (NVT_MIXED_SEED, NVT_N_OPS, NVT_NB,
                                NVT_PREPOP, NVT_RATIOS, nvt_mixed_point)

    NB, N_OPS, PREPOP = NVT_NB, NVT_N_OPS, NVT_PREPOP

    def timed(fn, reps=3):
        fn()                                   # compile (excluded)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return out, best

    # single-device reference, pre-populated exactly as bench_nvt does
    st0 = B.make_state(1 << 16, NB)
    pre_ks = jnp.arange(1, PREPOP + 1)
    pre_ops = jnp.zeros(PREPOP, jnp.int32)
    st_pre, _, _ = B.update_parallel(st0, pre_ops, pre_ks, pre_ks, NB)
    jax.block_until_ready(st_pre)

    rng_m = np.random.default_rng(NVT_MIXED_SEED)
    points = {}
    all_identical = True
    for ratio in NVT_RATIOS:
        upd_ops, upd_ks, upd_vs, look_ks = nvt_mixed_point(rng_m, ratio)
        n_upd = upd_ops.size

        # ---- single-device side ---------------------------------- #
        def single_side():
            st = st_pre
            if n_upd:
                st, ok, stats = B.update_parallel(
                    st, jnp.asarray(upd_ops), jnp.asarray(upd_ks),
                    jnp.asarray(upd_vs), NB)
            else:
                ok, stats = jnp.zeros(0, jnp.bool_), None
            return jax.block_until_ready(
                (st, ok, B.lookup(st, jnp.asarray(look_ks), NB))), stats

        ((st_s, ok_s, (f_s, v_s)), stats_s), t_single = timed(single_side)

        # ---- sharded side (fresh map per trial, same prepop) ------ #
        def make_sharded():
            m = ShardedDurableMap(n_dev, capacity=1 << 16, n_buckets=NB)
            m.insert(np.asarray(pre_ks, np.int32), np.asarray(pre_ks, np.int32))
            return m

        m = make_sharded()

        def sharded_side():
            if n_upd:
                ok, stats = m.update(upd_ops, upd_ks, upd_vs)
            else:
                ok, stats = np.zeros(0, np.bool_), None
            return (ok, m.lookup(look_ks)), stats

        # timing on a throwaway map (updates mutate); identity checked
        # on a final fresh run so timing reps don't triple-apply ops
        sharded_side()                          # compile
        best = float("inf")
        for _ in range(3):
            m = make_sharded()
            t0 = time.perf_counter()
            out = sharded_side()
            best = min(best, time.perf_counter() - t0)
        t_sharded = best
        m = make_sharded()
        (ok_m, (f_m, v_m)), stats_m = sharded_side()

        ident = (
            bool(np.array_equal(np.asarray(ok_s), ok_m))
            and bool(np.array_equal(np.asarray(f_s), f_m))
            and bool(np.array_equal(np.asarray(v_s), v_m))
            and items_of_state(st_s) == m.items()
            and int(st_s.flushes) == m.flushes
            and int(st_s.fences) == m.fences
        )
        foreign = (int(np.sum(np.asarray(stats_m.foreign_ops)))
                   if stats_m is not None else 0)
        buckets_identical = (
            bool(np.array_equal(np.asarray(stats_s.bucket_flushes),
                                np.asarray(stats_m.bucket_flushes)))
            if stats_m is not None else True)
        ident = ident and foreign == 0 and buckets_identical
        all_identical = all_identical and ident

        mx, mean = m.chain_stats()
        n_live = sum(1 for live, _ in m.items().values() if live)
        points[str(ratio)] = {
            "update_ratio": ratio,
            "batch_ops": N_OPS,
            "single_us_per_op": t_single / N_OPS * 1e6,
            "sharded_us_per_op": t_sharded / N_OPS * 1e6,
            "state_identical": ident,
            "foreign_ops": foreign,
            "bucket_flushes_identical": buckets_identical,
            "coalesced_fences_global": (stats_m.global_coalesced_fences
                                        if stats_m is not None else 0),
            "chain_stats": {
                "max_chain": mx,
                "mean_chain": mean,
                "load_factor": n_live / NB,
            },
        }

    json.dump({"devices": n_dev,
               "n_shards": n_dev,
               "state_identical": all_identical,
               "points": points}, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
