"""LoadScope bench: open/closed-loop load against the serving stack.

    PYTHONPATH=src python -m benchmarks.loadtest [--quick]
        [--out BENCH_nvt.json] [--flight LOADTEST_flight.json]

Runs the deterministic load harness (`repro.obs.loadgen`) at two zipf
skews plus a uniform mix, in both open and closed loop, and merges a
``serving_load`` section into BENCH_nvt.json:

* per point: rolling p50/p99 + ops/s series (windowed telemetry), the
  lifetime quantiles, sustained ops/s, the event timeline and the
  p99-excursion → annotated-event attribution;
* a crash point: torn-payload crash mid-commit, flight-recorder dump
  (written to ``--flight``) and the per-phase restart breakdown;
* a sharded point (``log_shards=2``) when >= 2 devices are visible;
* in full (non ``--quick``) mode additionally a tiny-model
  ``ServeEngine`` point (update = traversal + commit, read = dedup
  hit).

The section merges like every other bench section: partial runs update
only ``serving_load``.  CI's loadtest lane asserts on the result (see
docs/benchmarks.md) and ``tools/bench_history.py`` tracks the scalars
across runs.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path


def _merge(out_json: str, section: dict) -> None:
    from benchmarks.run import _load_report
    report = _load_report(out_json)
    report["serving_load"] = section
    Path(out_json).write_text(json.dumps(report, indent=1,
                                         sort_keys=True))


def _slim(rep: dict) -> dict:
    """The stored form of one point: full series/timeline/excursions,
    minus the per-window throughput duplicate (count/ops_s already ride
    the latency series)."""
    rep = dict(rep)
    rep.pop("throughput", None)
    return rep


def _point(key: str, root: Path, spec, flight_path=None, engine=None,
           rows=None):
    from repro.obs.loadgen import LoadHarness
    t0 = time.time()
    rep = LoadHarness(str(root / key), spec,
                      flight_path=flight_path, engine=engine).run()
    print(f"# loadtest {key}: p50={rep['p50_us']:.0f}us "
          f"p99={rep['p99_us']:.0f}us "
          f"sustained={rep['sustained_ops_s']:.0f} ops/s "
          f"excursions={rep['n_excursions']} "
          f"attributed={rep['n_attributed_excursions']} "
          f"({time.time() - t0:.1f}s)", file=sys.stderr)
    if rows is not None:
        rows.append((f"loadtest_{key}_p99", rep["p99_us"],
                     f"ops_s={rep['sustained_ops_s']:.0f}"))
    return _slim(rep)


def bench_serving_load(rows=None, out_json: str = "BENCH_nvt.json",
                       quick: bool = False,
                       flight_path: str = "LOADTEST_flight.json") -> dict:
    import jax

    from repro.obs.loadgen import LoadSpec

    n_closed = 160 if quick else 400
    n_open = 120 if quick else 300
    # closed-loop snapshot cadence tuned so most windows hold only
    # plain commits and the periodic truncating snapshot towers over
    # them — the excursion the timeline must attribute
    closed_kw = dict(n_ops=n_closed, update_frac=0.6, batch=4,
                     window_us=10_000.0, retain=128, snapshot_every=20,
                     warmup_ops=6)
    open_kw = dict(n_ops=n_open, mode="open", rate_ops_s=400.0,
                   update_frac=0.6, batch=4, window_us=20_000.0,
                   retain=128, snapshot_every=20, warmup_ops=6)

    points = {}
    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        for skew in (1.1, 1.5):
            points[f"closed_zipf{skew}"] = _point(
                f"closed_zipf{skew}", root,
                LoadSpec(seed=11, dist="zipf", skew=skew, **closed_kw),
                rows=rows)
            points[f"open_zipf{skew}"] = _point(
                f"open_zipf{skew}", root,
                LoadSpec(seed=13, dist="zipf", skew=skew, **open_kw),
                rows=rows)
        points["closed_uniform"] = _point(
            "closed_uniform", root,
            LoadSpec(seed=17, dist="uniform", **closed_kw), rows=rows)

        # crash point: torn-payload crash mid-commit, flight dump +
        # per-phase restart breakdown on the reload
        points["closed_crash"] = _point(
            "closed_crash", root,
            LoadSpec(seed=19, dist="zipf", skew=1.3,
                     crash_at_op=n_closed // 2, crash_evict="torn",
                     **closed_kw),
            flight_path=flight_path, rows=rows)

        sharded: dict
        if jax.device_count() >= 2:
            points["closed_zipf1.3_shards2"] = _point(
                "closed_zipf1.3_shards2", root,
                LoadSpec(seed=23, dist="zipf", skew=1.3, shards=2,
                         rebalance=True, **closed_kw),
                rows=rows)
            sharded = {"devices": jax.device_count(), "ran": True}
        else:
            sharded = {"devices": jax.device_count(), "ran": False,
                       "note": "log_shards point needs >= 2 devices"}

        if not quick:
            points["engine_closed_zipf1.3"] = _engine_point(root, rows)

    n_exc = sum(p["n_excursions"] for p in points.values())
    n_att = sum(p["n_attributed_excursions"] for p in points.values())
    section = {
        "quick": quick,
        "flight_dump": flight_path,
        "points": points,
        "sharded": sharded,
        "attribution": {
            "n_excursions_total": n_exc,
            "n_attributed_total": n_att,
            # the acceptance witness: at least one p99 excursion is
            # explained by a concrete annotated event
            "any_attributed": n_att >= 1,
        },
    }
    _merge(out_json, section)
    return section


def _engine_point(root: Path, rows):
    """Full-stack point: the same spec driven through a tiny-model
    ServeEngine (updates pay prefill/decode + commit; reads are dedup
    hits answered from the log)."""
    import jax

    from repro.configs.registry import get_arch, tiny
    from repro.models.model import build_model
    from repro.obs.loadgen import LoadSpec
    from repro.serving.engine import ServeEngine

    cfg = tiny(get_arch("qwen2-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = LoadSpec(n_ops=60, seed=29, dist="zipf", skew=1.3,
                    update_frac=0.5, batch=2, window_us=100_000.0,
                    retain=64, snapshot_every=None, warmup_ops=3)

    def factory(registry, timeline):
        return ServeEngine(model, params, max_len=24,
                           log_dir=str(root / "engine"), batch_size=2,
                           retain=64, snapshot_every=10,
                           registry=registry, timeline=timeline)

    return _point("engine_closed_zipf1.3", root, spec, engine=factory,
                  rows=rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (shorter streams, same shape)")
    ap.add_argument("--out", default="BENCH_nvt.json")
    ap.add_argument("--flight", default="LOADTEST_flight.json")
    args = ap.parse_args()
    rows = []
    bench_serving_load(rows, out_json=args.out, quick=args.quick,
                       flight_path=args.flight)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
