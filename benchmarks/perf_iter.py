import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# must precede all jax-importing code (see launch/dryrun.py)

"""§Perf hillclimbing harness: measure one (cell × config-variant).

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --cell gemma3-27b:train_4k --tag sp_blocked \
        --set attn_impl=blocked sp=true accum_constraint=true

Runs the same dual-pass measurement as the dry-run (scanned memory pass +
unrolled-L1/L2 cost pass) with the overridden config and appends the
result to benchmarks/results/perf/<cell>__<tag>.json.  The roofline terms
per variant feed the hypothesis→change→measure→validate log in
EXPERIMENTS.md §Perf.
"""
import argparse
import dataclasses as dc
import json
from pathlib import Path


def coerce(cfg, key, val):
    f = {f.name: f for f in dc.fields(cfg)}[key]
    t = f.type if isinstance(f.type, type) else type(getattr(cfg, key))
    if t is bool or isinstance(getattr(cfg, key), bool):
        return val.lower() in ("1", "true", "yes")
    if isinstance(getattr(cfg, key), int):
        return int(val)
    if isinstance(getattr(cfg, key), float):
        return float(val)
    return val


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)     # arch:shape
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--outdir", default="benchmarks/results/perf")
    args = ap.parse_args()

    from repro.configs import registry
    from repro.launch import dryrun

    arch, shape = args.cell.split(":")
    cfg = registry.get_arch(arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = coerce(cfg, k, v)
    cfg2 = dc.replace(cfg, **overrides)
    registry.ARCHS[arch] = cfg2       # run_cell reads the registry
    try:
        rec = dryrun.run_cell(arch, shape, "single", Path(args.outdir))
    finally:
        registry.ARCHS[arch] = cfg
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    out = Path(args.outdir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{arch}__{shape}__{args.tag}.json"
    path.write_text(json.dumps(rec, indent=1))

    # quick roofline summary
    from repro.roofline.analysis import analyze_cell
    row = analyze_cell(rec)
    if row:
        print(json.dumps({k: row[k] for k in
                          ("t_compute_s", "t_memory_s", "t_collective_s",
                           "dominant", "roofline_fraction", "temp_gb",
                           "args_gb")}, indent=1))


if __name__ == "__main__":
    main()
