"""Subprocess worker for the ``rebalance_live`` section of ``bench_nvt``.

Run as ``python -m benchmarks.rebalance_worker N_DEV``: forces ``N_DEV``
host platform devices (the flag must land *before* jax initializes,
which is why this is a subprocess and not a function of the parent
bench) and drives a zipf-skewed mixed stream through a
:class:`repro.core.rebalance.RebalancingShardedMap` with the auto
policy armed.  The zipf ranks are mapped onto keys *sorted by global
bucket*, so the hottest keys concentrate in the low bucket ranges —
the adversarial case for an even split — and the policy must notice
and re-split under the live stream.

Recorded per device count (merged under
``BENCH_nvt.json["rebalance_live"][str(N_DEV)]``):

  * ``rebalances`` / ``rounds`` / ``pulls``: how much re-split work the
    stream triggered and how it was amortized;
  * ``trigger_imbalance`` → ``final_imbalance``: hottest shard's load
    over the mean per-shard load (1.0 = balanced) at trigger time vs
    over a fixed post-stream probe phase on the final boundaries — the
    re-split must not make balance worse;
  * ``state_identical``: final per-key content equals BOTH a plain
    (never-rebalanced) sharded map driven through the identical stream
    and a python-dict oracle — the live re-split is invisible to
    semantics;
  * ``foreign_ops_total`` (must be 0) and ``locality_ok``: every flush
    of post-rebalance traffic lands inside its new owner range;
  * ``us_per_op`` for the live map vs ``plain_us_per_op`` for the
    never-rebalanced reference (the rebalance overhead actually paid).
"""
import json
import os
import re
import sys
import time


def main() -> None:
    n_dev = int(sys.argv[1])
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       os.environ.get("XLA_FLAGS", "")).strip()
    os.environ["XLA_FLAGS"] = (
        inherited
        + f" --xla_force_host_platform_device_count={n_dev}").strip()
    import numpy as np
    from repro.core import batched as B
    from repro.core.rebalance import (AutoRebalancePolicy,
                                      RebalancingShardedMap)
    from repro.core.sharded import ShardedDurableMap

    S, NB = n_dev, 128
    CAP, BATCH, ROUNDS, POST = 1 << 15, 1024, 24, 6
    rng = np.random.default_rng(5)

    # zipf rank -> key, hottest ranks in the lowest global buckets: the
    # skew aligns with contiguous ranges, so an even split is maximally
    # imbalanced and the load-quantile re-plan has something to fix
    domain = np.arange(1, 20001, dtype=np.int32)
    by_bucket = domain[np.argsort(B.bucket_of_np(domain, NB),
                                  kind="stable")]

    def draw(n):
        ranks = np.minimum(rng.zipf(1.3, size=n), domain.size) - 1
        return by_bucket[ranks]

    m = RebalancingShardedMap(
        S, capacity=CAP, n_buckets=NB, rounds_per_update=2,
        policy=AutoRebalancePolicy(threshold=1.3, min_load=4096,
                                   check_every=2))
    plain = ShardedDurableMap(S, capacity=CAP, n_buckets=NB)
    model = {}
    t_live = t_plain = 0.0
    foreign = 0
    n_ops = 0

    def one_batch():
        ops = rng.integers(0, 2, BATCH).astype(np.int32)
        ks = draw(BATCH)
        vs = rng.integers(0, 1000, BATCH).astype(np.int32)
        return ops, ks, vs

    for _ in range(ROUNDS):
        ops, ks, vs = one_batch()
        n_ops += BATCH
        t0 = time.perf_counter()
        ok, stats = m.update(ops, ks, vs)
        t_live += time.perf_counter() - t0
        t0 = time.perf_counter()
        ok_p, _ = plain.update(ops, ks, vs)
        t_plain += time.perf_counter() - t0
        assert bool((ok == ok_p).all()), "live rebalance changed results"
        foreign += int(np.sum(np.asarray(stats.foreign_ops)))
        for o, k, v, okk in zip(ops, ks, vs, ok):
            if o == B.OP_INSERT and bool(okk):
                model[int(k)] = int(v)
            elif o == B.OP_DELETE and bool(okk):
                model.pop(int(k), None)
    if m.rebalancing:                    # finish a tail re-split so the
        m.run_rebalance()                # post phase probes final splits

    # post phase: fixed probe traffic on the final boundaries (policy
    # disarmed) for the final imbalance + locality numbers
    m.policy = None
    locality_ok = True
    for _ in range(POST):
        ops, ks, vs = one_batch()
        n_ops += BATCH
        t0 = time.perf_counter()
        ok, stats = m.update(ops, ks, vs)
        t_live += time.perf_counter() - t0
        t0 = time.perf_counter()
        ok_p, _ = plain.update(ops, ks, vs)
        t_plain += time.perf_counter() - t0
        assert bool((ok == ok_p).all())
        foreign += int(np.sum(np.asarray(stats.foreign_ops)))
        bf = np.asarray(stats.bucket_flushes)
        for s in range(S):
            lo, hi = m.splits[s], m.splits[s + 1]
            if int(np.asarray(stats.coalesced_flushes)[s]) != \
                    int(bf[lo:hi].sum()):
                locality_ok = False
        for o, k, v, okk in zip(ops, ks, vs, ok):
            if o == B.OP_INSERT and bool(okk):
                model[int(k)] = int(v)
            elif o == B.OP_DELETE and bool(okk):
                model.pop(int(k), None)

    live_m = {k: v for k, (l, v) in m.items().items() if l}
    live_p = {k: v for k, (l, v) in plain.items().items() if l}
    ident = live_m == live_p == model

    json.dump({
        "devices": S,
        "n_buckets": NB,
        "batch_ops": BATCH,
        "batches": ROUNDS + POST,
        "rebalances": m.rebalances_completed,
        "rounds": m.rounds_total,
        "pulls": m.pulls_total,
        "trigger_imbalance": m.last_trigger_imbalance,
        "final_imbalance": m.imbalance(),
        "splits_final": list(m.splits),
        "us_per_op": t_live / n_ops * 1e6,
        "plain_us_per_op": t_plain / n_ops * 1e6,
        "state_identical": bool(ident),
        "foreign_ops_total": foreign,
        "locality_ok": bool(locality_ok),
    }, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
