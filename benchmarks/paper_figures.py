"""Paper-figure benchmarks (Figures 5a–f NVRAM, 6g–o DRAM).

The container has no Optane, so wall-clock throughput is replaced by the
calibrated cost model over *exact* instruction/flush/fence counts from the
simulator (the counts are the mechanism behind the paper's speedups; the
latency weights are Optane/DRAM literature values).  Derived throughput:

    t_op      = reads·t_rd + writes·t_wr + cas·t_cas
                + flushes·t_flush + fences·t_fence
    agg(T)    = T / (t_op(T) )   with per-thread counts measured at
                thread count T via the interleaving scheduler (contention
                shows up as extra restarts/CASes, as on real hardware).

Profiles (ns): NVRAM (Cascade Lake + Optane DC, clwb/sfence) and DRAM
(AMD Opteron, clflush) — constants chosen from the paper's platform
descriptions (§5.1) and public Optane latency measurements.
"""
from __future__ import annotations

import numpy as np

from repro.core.bst import ExternalBST
from repro.core.harris_list import HarrisList
from repro.core.hash_table import HashTable
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.scheduler import Interleaver
from repro.core.skiplist import SkipList
from repro.core.traversal import run_operation

PROFILES = {
    # t_read, t_write, t_cas, t_flush, t_fence  (ns)
    "nvram": dict(rd=10.0, wr=15.0, cas=25.0, flush=250.0, fence=100.0),
    "dram": dict(rd=8.0, wr=10.0, cas=20.0, flush=100.0, fence=60.0),
}

POLICIES = ("volatile", "izraelevitz", "nvtraverse")


def op_time_ns(counters, profile) -> float:
    p = PROFILES[profile]
    c = counters
    return (c.reads * p["rd"] + c.writes * p["wr"] + c.cas * p["cas"]
            + c.flushes * p["flush"] + c.fences * p["fence"])


def _make(structure, mem):
    return {"list": lambda: HarrisList(mem),
            "hash": lambda: HashTable(mem, n_buckets=64),
            "bst": lambda: ExternalBST(mem),
            "skiplist": lambda: SkipList(mem)}[structure]()


def run_workload(structure: str, policy: str, *, size: int,
                 update_pct: int, n_ops: int = 400, seed: int = 0,
                 profile: str = "nvram") -> dict:
    """Sequential cost measurement (single-thread counts)."""
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 19)
    ds = _make(structure, mem)
    pol = get_policy(policy)
    keys = rng.permutation(2 * size)[:size]
    for k in keys:
        run_operation(ds, get_policy("nvtraverse"), "insert", (int(k), 1))
    mem.persist_all()
    mem.counters.reset()
    for _ in range(n_ops):
        r = rng.random()
        k = int(rng.integers(0, 2 * size))
        if r < update_pct / 200:
            run_operation(ds, pol, "insert", (k, 1))
        elif r < update_pct / 100:
            run_operation(ds, pol, "delete", (k,))
        else:
            run_operation(ds, pol, "find", (k,))
    t_ns = op_time_ns(mem.counters, profile) / n_ops
    return {"t_op_us": t_ns / 1e3,
            "mops_per_thread": 1e3 / t_ns,
            "flushes_per_op": mem.counters.flushes / n_ops,
            "fences_per_op": mem.counters.fences / n_ops}


def run_threaded(structure: str, policy: str, *, size: int, threads: int,
                 update_pct: int = 20, seed: int = 0,
                 profile: str = "nvram") -> dict:
    """Concurrent run: contention (restarts/extra CAS) measured via the
    interleaver; throughput = threads / t_op(measured counts)."""
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 19)
    ds = _make(structure, mem)
    for k in rng.permutation(2 * size)[:size]:
        run_operation(ds, get_policy("nvtraverse"), "insert", (int(k), 1))
    mem.persist_all()
    mem.counters.reset()
    ops = []
    n_ops = 8 * threads
    for _ in range(n_ops):
        r = rng.random()
        k = int(rng.integers(0, 2 * size))
        if r < update_pct / 200:
            ops.append(("insert", (k, 1)))
        elif r < update_pct / 100:
            ops.append(("delete", (k,)))
        else:
            ops.append(("find", (k,)))
    # `threads` ops in flight at a time
    for i in range(0, n_ops, threads):
        Interleaver(ds, get_policy(policy), ops[i:i + threads],
                    seed=seed + i).run()
    t_ns = op_time_ns(mem.counters, profile) / n_ops
    return {"t_op_us": t_ns / 1e3,
            "agg_mops": threads * 1e3 / t_ns}


# ----------------------------------------------------------------------- #
# one function per paper figure                                            #
# ----------------------------------------------------------------------- #
def fig5a_list_scalability(rows):
    for threads in (1, 2, 4, 8):
        for pol in POLICIES:
            r = run_threaded("list", pol, size=256, threads=threads)
            rows.append((f"fig5a,list,threads={threads},{pol}",
                         r["t_op_us"], f"agg_mops={r['agg_mops']:.3f}"))


def fig5b_list_size(rows):
    for size in (128, 256, 1024, 4096):
        for pol in POLICIES:
            r = run_workload("list", pol, size=size, update_pct=20)
            rows.append((f"fig5b,list,size={size},{pol}", r["t_op_us"],
                         f"fences_per_op={r['fences_per_op']:.1f}"))


def fig5c_list_updates(rows):
    for upd in (0, 5, 20, 50, 100):
        for pol in POLICIES:
            r = run_workload("list", pol, size=256, update_pct=upd)
            rows.append((f"fig5c,list,upd={upd},{pol}", r["t_op_us"],
                         f"mops={r['mops_per_thread']:.3f}"))


def _fig5_structure(rows, fig, structure, size=2048):
    for upd in (0, 20, 50, 100):
        for pol in POLICIES:
            r = run_workload(structure, pol, size=size, update_pct=upd)
            rows.append((f"{fig},{structure},upd={upd},{pol}",
                         r["t_op_us"],
                         f"flushes_per_op={r['flushes_per_op']:.1f}"))


def fig5d_hash(rows):
    _fig5_structure(rows, "fig5d", "hash")


def fig5e_bst(rows):
    _fig5_structure(rows, "fig5e", "bst")


def fig5f_skiplist(rows):
    _fig5_structure(rows, "fig5f", "skiplist", size=1024)


def fig6_dram(rows):
    """DRAM figures (6g–o): same sweeps under the DRAM cost profile."""
    for structure, size in (("list", 1024), ("hash", 4096), ("bst", 4096),
                            ("skiplist", 1024)):
        for upd in (0, 20, 100):
            for pol in POLICIES:
                r = run_workload(structure, pol, size=size, update_pct=upd,
                                 profile="dram")
                rows.append((f"fig6,{structure},upd={upd},{pol}",
                             r["t_op_us"],
                             f"mops={r['mops_per_thread']:.3f}"))


ALL_FIGURES = [fig5a_list_scalability, fig5b_list_size, fig5c_list_updates,
               fig5d_hash, fig5e_bst, fig5f_skiplist, fig6_dram]
