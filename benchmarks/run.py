"""Benchmark harness (deliverable d): one function per paper figure plus
framework benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig5a,...]
"""
from __future__ import annotations

import argparse
import sys
import time

# bench_nvt workload shape, shared with benchmarks/sharded_worker.py so
# the sharded section always mirrors the single-device mixed section
NVT_NB = 1024
NVT_N_OPS = 20_000
NVT_PREPOP = 10_000
NVT_MIXED_SEED = 1
NVT_RATIOS = (0, 20, 50)


def nvt_mixed_point(rng, ratio):
    """One mixed-workload point: updates (inserts with fresh + duplicate
    keys interleaved with deletes of mostly-present keys), the rest
    lookups.  The single draw sequence both bench sections consume —
    callers must draw points in NVT_RATIOS order from a fresh
    ``default_rng(NVT_MIXED_SEED)`` for the sections to coincide.
    Returns numpy ``(upd_ops, upd_ks, upd_vs, look_ks)``."""
    import numpy as np
    n_upd = NVT_N_OPS * ratio // 100
    n_look = NVT_N_OPS - n_upd
    upd_ops = rng.integers(0, 2, size=n_upd).astype(np.int32)
    upd_ks = rng.integers(1, 2 * NVT_PREPOP, size=n_upd).astype(np.int32)
    look_ks = rng.integers(1, 2 * NVT_PREPOP, size=n_look).astype(np.int32)
    return upd_ops, upd_ks, upd_ks * 3, look_ks


def _load_report(out_json):
    """Existing bench report, or {} — a truncated file (e.g. an
    interrupted earlier run) self-heals instead of wedging every
    subsequent bench run."""
    import json
    from pathlib import Path
    try:
        return json.loads(Path(out_json).read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def bench_paper_figures(rows, only=None):
    from benchmarks.paper_figures import ALL_FIGURES
    for fn in ALL_FIGURES:
        name = fn.__name__.split("_")[0]
        if only and name not in only:
            continue
        t0 = time.time()
        fn(rows)
        print(f"# {fn.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr)


def bench_batched_hashmap(rows):
    """Wall-clock throughput of the jitted durable hash map (CPU)."""
    import jax.numpy as jnp
    from repro.core import batched as B
    NB = 1024
    st0 = B.make_state(1 << 16, NB)
    ks = jnp.arange(1, 20_001)
    B.insert(st0, ks, ks, NB)[0].cursor.block_until_ready()   # compile
    t0 = time.perf_counter()
    st, _ = B.insert(st0, ks, ks, NB)
    st.cursor.block_until_ready()
    t_insert = (time.perf_counter() - t0) / 20_000 * 1e6
    q = jnp.arange(1, 50_001)
    B.lookup(st, q, NB)[0].block_until_ready()   # compile
    t0 = time.perf_counter()
    for _ in range(5):
        B.lookup(st, q, NB)[0].block_until_ready()
    t_lookup = (time.perf_counter() - t0) / (5 * 50_000) * 1e6
    rows.append(("batched_hashmap,insert", t_insert,
                 f"fences_per_op={float(st.fences)/20_000:.2f}"))
    rows.append(("batched_hashmap,lookup", t_lookup,
                 "fences_per_op=0.00"))


def bench_nvt(rows, out_json="BENCH_nvt.json"):
    """The PR's headline comparison, machine-readable.

    (a) sequential-scan vs plan/commit insert engines on a 20k-op batch —
        identical per-op fence accounting, coalesced batch fences
        reported alongside;
    (b) nvt_probe Pallas kernel (streamed bucket tiles, interpret mode on
        CPU) vs the XLA reference on a table larger than the old
        whole-table-in-VMEM cap (2 MB), with a bit-exactness check;
    (c) paper-style mixed workloads (§5): 20k-op batches at 0/20/50%
        update ratio (updates split evenly between inserts and deletes,
        the rest lookups) against a pre-populated map — sequential mixed
        oracle (``apply`` + ``lookup``) vs one ``update_parallel`` round
        + the same lookup, with a bit-identical state/ok check.
    """
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import batched as B
    from repro.kernels.nvt_probe.ops import nvt_probe
    from repro.kernels.nvt_probe.ref import tiles_from_keys

    NB, N_OPS = NVT_NB, NVT_N_OPS
    st0 = B.make_state(1 << 16, NB)
    ks = jnp.arange(1, N_OPS + 1)

    def timed(fn, reps=3):
        fn()                                   # compile (excluded)
        best = float("inf")
        for _ in range(reps):                  # best-of-reps: robust to
            t0 = time.perf_counter()           # scheduler/GC noise
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return out, best

    (st_scan, _), t_scan = timed(
        lambda: jax.block_until_ready(B.insert(st0, ks, ks, NB)))
    (st_par, _, stats), t_par = timed(
        lambda: jax.block_until_ready(B.insert_parallel(st0, ks, ks, NB)))
    state_equal = all(
        bool(jnp.array_equal(getattr(st_scan, f), getattr(st_par, f)))
        for f in st_scan._fields)

    # (b) streamed probe on a 4 MB table (old single-tile cap: 2 MB)
    PNB, CAP, Q, BLOCK_NB = 4096, 256, 256, 512
    rng = np.random.default_rng(0)
    keys = rng.choice(np.arange(1, 1 << 20), size=PNB * CAP // 4,
                      replace=False).astype(np.int32)
    kt, vt = tiles_from_keys(keys, PNB, CAP)
    queries = jnp.asarray(rng.integers(1, 1 << 20, size=Q).astype(np.int32))
    (fx, vx), t_xla = timed(lambda: jax.block_until_ready(
        nvt_probe(kt, vt, queries, impl="xla")))
    (fp, vp), t_pal = timed(lambda: jax.block_until_ready(
        nvt_probe(kt, vt, queries, impl="pallas", interpret=True,
                  block_q=128, block_nb=BLOCK_NB)))
    bit_exact = bool(jnp.array_equal(fx, fp) and jnp.array_equal(vx, vp))

    # (c) mixed workloads at paper update ratios over a pre-populated map
    rng_m = np.random.default_rng(NVT_MIXED_SEED)
    PREPOP = NVT_PREPOP
    pre_ks = jnp.arange(1, PREPOP + 1)
    st_pre, _, _ = B.update_parallel(
        st0, jnp.zeros(PREPOP, jnp.int32), pre_ks, pre_ks, NB)
    jax.block_until_ready(st_pre)
    mixed = {}
    for ratio in NVT_RATIOS:
        upd_ops, upd_ks, upd_vs, look_ks = map(
            jnp.asarray, nvt_mixed_point(rng_m, ratio))
        n_upd = int(upd_ops.shape[0])
        n_look = int(look_ks.shape[0])

        def scan_side():
            st = st_pre
            if n_upd:
                st, ok = B.apply(st, upd_ops, upd_ks, upd_vs, NB)
            else:
                ok = jnp.zeros(0, jnp.bool_)
            return jax.block_until_ready(
                (st, ok, B.lookup(st, look_ks, NB)))

        def par_side():
            st = st_pre
            if n_upd:
                st, ok, stats = B.update_parallel(st, upd_ops, upd_ks,
                                                  upd_vs, NB)
            else:
                ok, stats = jnp.zeros(0, jnp.bool_), None
            return jax.block_until_ready(
                (st, ok, B.lookup(st, look_ks, NB))), stats

        (st_s, ok_s, look_s), t_s = timed(scan_side, reps=5)
        ((st_m, ok_m, look_m), stats_m), t_m = timed(par_side, reps=5)
        ident = all(
            bool(jnp.array_equal(getattr(st_s, f), getattr(st_m, f)))
            for f in st_s._fields) and bool(jnp.array_equal(ok_s, ok_m)) \
            and all(bool(jnp.array_equal(a, b))
                    for a, b in zip(look_s, look_m))
        # chain shape after the round: the baseline future resize/rehash
        # work compares against (load factor = live keys per bucket)
        max_chain, mean_chain = B.chain_stats(st_m, NB)
        mixed[str(ratio)] = {
            "update_ratio": ratio,
            "batch_ops": N_OPS,
            "n_updates": n_upd,
            "n_lookups": n_look,
            "scan_us_per_op": t_s / N_OPS * 1e6,
            "parallel_us_per_op": t_m / N_OPS * 1e6,
            "speedup": t_s / t_m,
            "state_identical": ident,
            "coalesced_fences": (int(stats_m.coalesced_fences)
                                 if stats_m is not None else 0),
            "chain_stats": {
                "max_chain": int(max_chain),
                "mean_chain": float(mean_chain),
                "load_factor": int(st_m.live.sum()) / NB,
            },
        }

    # merge (don't rewrite): a partial run must not discard sections
    # other benches own, e.g. the sharded section of --only sharded
    report = _load_report(out_json)
    report.update({
        "insert": {
            "batch_ops": N_OPS,
            "n_buckets": NB,
            "scan_us_per_op": t_scan / N_OPS * 1e6,
            "parallel_us_per_op": t_par / N_OPS * 1e6,
            "speedup": t_scan / t_par,
            "state_identical": state_equal,
            "fences_scan": int(st_scan.fences),
            "fences_parallel": int(st_par.fences),
            "fences_per_op": float(st_par.fences) / N_OPS,
            "coalesced_fences": int(stats.coalesced_fences),
            "coalesced_flushes": int(stats.coalesced_flushes),
            "max_conflict_group": int(stats.max_group),
        },
        "mixed": mixed,
        "probe": {
            "n_buckets": PNB,
            "bucket_cap": CAP,
            "table_bytes": int(PNB * CAP * 4),
            "old_vmem_cap_bytes": 2 * 1024 * 1024,
            "block_nb": BLOCK_NB,
            "queries": Q,
            "xla_us_per_query": t_xla / Q * 1e6,
            "pallas_interpret_us_per_query": t_pal / Q * 1e6,
            "bit_exact": bit_exact,
        },
    })
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}", file=sys.stderr)
    ins = report["insert"]
    rows.append(("nvt,insert_scan", ins["scan_us_per_op"],
                 f"fences_per_op={ins['fences_per_op']:.2f}"))
    rows.append(("nvt,insert_parallel", ins["parallel_us_per_op"],
                 f"speedup={ins['speedup']:.1f}x;"
                 f"coalesced_fences={ins['coalesced_fences']}"))
    for ratio, m in mixed.items():
        rows.append((f"nvt,mixed_{ratio}pct_parallel",
                     m["parallel_us_per_op"],
                     f"speedup={m['speedup']:.1f}x;"
                     f"state_identical={m['state_identical']}"))
    rows.append(("nvt,probe_xla", report["probe"]["xla_us_per_query"],
                 f"table_mb={PNB*CAP*4/2**20:.0f}"))
    rows.append(("nvt,probe_pallas_interpret",
                 report["probe"]["pallas_interpret_us_per_query"],
                 f"bit_exact={bit_exact}"))


def bench_nvt_ordered(rows, out_json="BENCH_nvt.json"):
    """OrderedNVT: the plan/commit engine on the sorted bottom list.

    (a) mixed insert/delete batch over a pre-populated ordered map —
        sequential scan oracle (:func:`repro.core.ordered.apply_ordered`,
        one head-to-predecessor walk per op) vs one
        ``update_parallel_ordered`` round descending the volatile
        towers, with a bit-identical state/ok/accounting check *and* a
        pure-dict+sorted oracle content check;
    (b) volatile tower (re)build cost — the Property 2 reconstruction
        the recovery path pays;
    (c) ordered reads on a seeded zipf workload: ``range_query`` (every
        answer checked against the sorted-dict oracle) and ``top_k``
        us/query.

    The batch here is sized so the O(n²)-walk scan oracle stays a
    few-second bench; the 20k-op acceptance identity runs in
    ``tests/test_ordered.py`` (slow lane).
    """
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import ordered as O

    CAP = 1 << 13
    PREPOP = 2_000
    N_OPS = 4_000
    KEYSPACE = 40_000
    rng = np.random.default_rng(NVT_MIXED_SEED)
    pre = np.sort(rng.choice(np.arange(1, KEYSPACE), PREPOP,
                             replace=False)).astype(np.int32)
    st0 = O.make_ordered(CAP)
    st0, ok0, _ = O.update_parallel_ordered(
        st0, np.zeros(PREPOP, np.int32), pre, pre * 3)
    assert bool(np.asarray(ok0).all())
    model: dict = {}
    O.oracle_apply(model, np.zeros(PREPOP, np.int32), pre, pre * 3,
                   capacity=CAP)
    jax.block_until_ready(st0)

    # (a) one mixed batch: ~half hits (deletes/duplicate inserts), half
    # fresh keys — duplicate-key groups and shared predecessors included
    ops = rng.integers(0, 2, N_OPS).astype(np.int32)
    ks = np.where(rng.random(N_OPS) < 0.5,
                  rng.choice(pre, N_OPS),
                  rng.integers(1, KEYSPACE, N_OPS)).astype(np.int32)
    vs = rng.integers(0, 10_000, N_OPS).astype(np.int32)

    def timed(fn, reps=3):
        fn()                                   # compile (excluded)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return out, best

    towers0, t_towers = timed(lambda: O.build_towers(st0))
    (st_s, ok_s), t_scan = timed(lambda: jax.block_until_ready(
        O.apply_ordered(st0, jnp.asarray(ops), jnp.asarray(ks),
                        jnp.asarray(vs))), reps=2)
    (st_p, ok_p, stats), t_par = timed(lambda: jax.block_until_ready(
        O.update_parallel_ordered(st0, ops, ks, vs, towers=towers0)))
    ident = all(
        bool(jnp.array_equal(getattr(st_s, f), getattr(st_p, f)))
        for f in st_s._fields) and bool(jnp.array_equal(ok_s, ok_p))
    ok_m = O.oracle_apply(model, ops, ks, vs, capacity=CAP)
    dict_ident = (O.items_host(st_p) == model
                  and bool(np.array_equal(np.asarray(ok_p),
                                          np.asarray(ok_m, bool))))

    # (c) ordered reads over the post-batch state, seeded zipf spans
    towers = O.build_towers(st_p)
    spans = []
    for _ in range(64):
        lo = int((rng.zipf(1.3) * 37) % KEYSPACE)
        spans.append((lo, lo + int(rng.integers(50, 2_000))))
    range_ident = True
    for lo, hi in spans:
        want = O.oracle_range(model, lo, hi)
        total, rk, rv = O.range_query(st_p, lo, hi, 1024, towers)
        got = list(zip(np.asarray(rk)[:len(want)].tolist(),
                       np.asarray(rv)[:len(want)].tolist()))
        range_ident &= (int(total) == len(want) and got == want)

    def range_all():
        for lo, hi in spans:
            out = O.range_query(st_p, lo, hi, 1024, towers)
        return jax.block_until_ready(out)

    _, t_range = timed(range_all)
    cnt, tk_keys, tk_vals = O.top_k(st_p, 128)
    alive = sorted(O.live_items(st_p))
    topk_ident = (np.asarray(tk_keys)[:int(cnt)].tolist()
                  == alive[-int(cnt):])
    _, t_topk = timed(lambda: jax.block_until_ready(
        O.top_k(st_p, 128)))

    report = _load_report(out_json)
    report["ordered"] = {
        "capacity": CAP,
        "prepop": PREPOP,
        "batch_ops": N_OPS,
        "scan_us_per_op": t_scan / N_OPS * 1e6,
        "parallel_us_per_op": t_par / N_OPS * 1e6,
        "speedup": t_scan / t_par,
        "state_identical": bool(ident),
        "dict_oracle_identical": bool(dict_ident),
        "fences_scan": int(st_s.fences),
        "fences_parallel": int(st_p.fences),
        "coalesced_fences": int(stats.coalesced_fences),
        "max_conflict_group": int(stats.max_group),
        "conflict_groups": int(stats.conflict_groups),
        "tower_build_us": t_towers * 1e6,
        "range": {
            "queries": len(spans),
            "max_items": 1024,
            "us_per_query": t_range / len(spans) * 1e6,
            "identical": bool(range_ident),
        },
        "top_k": {
            "k": 128,
            "us_per_call": t_topk * 1e6,
            "identical": bool(topk_ident),
        },
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_json}", file=sys.stderr)
    o = report["ordered"]
    rows.append(("ordered,mixed_scan", o["scan_us_per_op"],
                 f"batch={N_OPS}"))
    rows.append(("ordered,mixed_parallel", o["parallel_us_per_op"],
                 f"speedup={o['speedup']:.1f}x;"
                 f"state_identical={o['state_identical']};"
                 f"dict_oracle_identical={o['dict_oracle_identical']}"))
    rows.append(("ordered,range_query", o["range"]["us_per_query"],
                 f"identical={o['range']['identical']}"))
    rows.append(("ordered,top_k", o["top_k"]["us_per_call"],
                 f"identical={o['top_k']['identical']}"))


def bench_nvt_migrate(rows, out_json="BENCH_nvt.json"):
    """Online-growth section: a map seeded at capacity C absorbs 8C
    inserts under live mixed traffic, growing itself through the bounded
    migration rounds of :mod:`repro.core.migrate` — per point we record
    migrations run, amortized rounds per op, wall time per op,
    chain/load-factor shape before and after growth, and a per-key
    content-identity check against a python-dict oracle driven through
    the same stream.  Points: update ratio 0/20/50% × uniform vs skewed
    (zipf) update keys.  Merged under ``out_json["migrate"]``."""
    import json
    import numpy as np
    from repro.core.migrate import MigratingMap

    C, NB0, BATCH = 2048, 64, 512
    TOTAL = 8 * C
    migrate = {}
    for dist in ("uniform", "skewed"):
        for ratio in NVT_RATIOS:
            rng = np.random.default_rng(NVT_MIXED_SEED + ratio)
            m = MigratingMap(capacity=C, n_buckets=NB0,
                             rounds_per_update=2)
            model = {}
            next_key = 1
            chain0 = None
            t_map = 0.0       # time in m.update() only — the dict
            inserted = 0      # oracle + chain sampling stay untimed so
            n_ops = 0         # us_per_op is comparable to the sections
            while inserted < TOTAL:       # that time bare engine calls
                n_upd = BATCH * ratio // 100
                n_ins = BATCH - n_upd
                n_ops += BATCH
                ks_ins = np.arange(next_key, next_key + n_ins,
                                   dtype=np.int32)
                next_key += n_ins
                inserted += n_ins
                seen = max(1, next_key - 1)
                if dist == "uniform":
                    ks_upd = rng.integers(
                        1, seen + 1, size=n_upd).astype(np.int32)
                else:
                    ks_upd = (rng.zipf(1.3, size=n_upd)
                              % seen + 1).astype(np.int32)
                ops = np.concatenate([
                    np.zeros(n_ins, np.int32),
                    rng.integers(0, 2, size=n_upd).astype(np.int32)])
                ks = np.concatenate([ks_ins, ks_upd])
                vs = (ks * 3).astype(np.int32)
                t0 = time.perf_counter()
                ok = m.update(ops, ks, vs)
                t_map += time.perf_counter() - t0
                for o, k, v, okk in zip(ops, ks, vs, ok):
                    k = int(k)
                    if o == 0:
                        if bool(okk):
                            model[k] = int(v)
                    elif bool(okk):
                        del model[k]
                if m.migrations_completed == 0 and not m.migrating:
                    # keep the newest pre-growth shape: the last sample
                    # before the first migration is the seed table at
                    # its fullest — the "before" of the chain comparison
                    from repro.core import batched as B
                    mx0, mean0 = B.chain_stats(m.state, m.n_buckets)
                    chain0 = (int(mx0), float(mean0),
                              len(model) / m.n_buckets)
            from repro.core import batched as B
            items = m.items()
            live = {k for k, (l, _) in items.items() if l}
            ident = live == set(model) and all(
                items[k][1] == v for k, v in model.items())
            mx1, mean1 = B.chain_stats(m.state, m.n_buckets)
            migrate[f"{dist}_{ratio}"] = {
                "distribution": dist,
                "update_ratio": ratio,
                "seed_capacity": C,
                "inserts_absorbed": TOTAL,
                "final_capacity": m.capacity,
                "final_n_buckets": m.n_buckets,
                "migrations": m.migrations_completed,
                "rounds": m.rounds_total,
                "rounds_per_op": m.rounds_total / n_ops,
                "pulls": m.pulls_total,
                "us_per_op": t_map / n_ops * 1e6,
                "state_identical": bool(ident),
                "chain_stats_before": {
                    "max_chain": chain0[0],
                    "mean_chain": chain0[1],
                    "load_factor": chain0[2],
                } if chain0 else None,
                "chain_stats_after": {
                    "max_chain": int(mx1),
                    "mean_chain": float(mean1),
                    "load_factor": len(live) / m.n_buckets,
                },
            }
    report = _load_report(out_json)
    report["migrate"] = {
        "seed_capacity": C,
        "seed_n_buckets": NB0,
        "growth_factor": 8,
        "note": "us_per_op includes jit compiles for newly reached "
                "capacities; the first point pays most of them",
        "points": migrate,
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# merged migrate section into {out_json}", file=sys.stderr)
    for name, p in migrate.items():
        rows.append((f"nvt,migrate_{name}", p["us_per_op"],
                     f"migrations={p['migrations']};"
                     f"rounds_per_op={p['rounds_per_op']:.4f};"
                     f"state_identical={p['state_identical']}"))


def _run_worker(module: str, n_dev: int) -> dict:
    """Run one forced-host-device bench worker subprocess (the
    ``--xla_force_host_platform_device_count`` flag must land before
    jax initializes, and this process's jax is already up) and parse
    its single-JSON-document stdout."""
    import json
    import os
    import subprocess

    env = dict(os.environ)
    env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else "src")
    proc = subprocess.run(
        [sys.executable, "-m", module, str(n_dev)],
        capture_output=True, text=True, env=env)
    if proc.returncode != 0:
        print(proc.stderr, file=sys.stderr)
        raise RuntimeError(f"{module} ({n_dev} devices) failed")
    return json.loads(proc.stdout)


def bench_nvt_sharded(rows, out_json="BENCH_nvt.json",
                      device_counts=(1, 2, 4, 8)):
    """Sharded durable map vs the single-device plan/commit engine on
    1/2/4/8 forced host devices (same mixed-workload points as the
    single-device section).  Each device count runs in a subprocess —
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` must land
    before jax initializes, and this process's jax is already up.
    Results (state-identity check, per-point timing, chain_stats,
    persistence-locality counters) merge into ``out_json["sharded"]``.
    """
    import json

    sharded = {}
    for n_dev in device_counts:
        print(f"# sharded worker: {n_dev} host devices...",
              file=sys.stderr)
        sharded[str(n_dev)] = _run_worker("benchmarks.sharded_worker",
                                          n_dev)
    report = _load_report(out_json)
    report["sharded"] = sharded
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# merged sharded section into {out_json}", file=sys.stderr)
    for n_dev, res in sharded.items():
        p = res["points"]["50"]
        rows.append((f"nvt,sharded_{n_dev}dev_mixed50",
                     p["sharded_us_per_op"],
                     f"vs_single={p['single_us_per_op']:.3f}us;"
                     f"state_identical={res['state_identical']};"
                     f"max_chain={p['chain_stats']['max_chain']}"))


def bench_nvt_rebalance_live(rows, out_json="BENCH_nvt.json",
                             device_counts=(2, 4)):
    """Live cross-shard rebalancing under a zipf-skewed mixed stream
    (benchmarks/rebalance_worker.py per forced-host-device count): the
    auto policy must trigger at least one re-split under live traffic,
    final per-key content must match a never-rebalanced map + a dict
    oracle, every flush must stay in its owner range, and the final
    per-shard imbalance must not exceed the trigger imbalance.  Results
    merge into ``out_json["rebalance_live"]``."""
    import json

    section = {}
    for n_dev in device_counts:
        print(f"# rebalance_live worker: {n_dev} host devices...",
              file=sys.stderr)
        section[str(n_dev)] = _run_worker("benchmarks.rebalance_worker",
                                          n_dev)
    report = _load_report(out_json)
    report["rebalance_live"] = {
        "note": "us_per_op includes the shard_map recompiles a re-split "
                "forces (new max range width) plus drain rounds, "
                "amortized over a short stream; plain_us_per_op is the "
                "never-rebalanced floor on the same traffic",
        **section,
    }
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# merged rebalance_live section into {out_json}",
          file=sys.stderr)
    for n_dev, p in section.items():
        rows.append((f"nvt,rebalance_live_{n_dev}dev", p["us_per_op"],
                     f"rebalances={p['rebalances']};"
                     f"imbalance={p['trigger_imbalance']:.2f}"
                     f"->{p['final_imbalance']:.2f};"
                     f"state_identical={p['state_identical']}"))


def bench_nvt_restart(rows, out_json="BENCH_nvt.json",
                      sizes=(1_000, 10_000, 100_000)):
    """Serving-restart latency: O(1) with snapshots vs O(history).

    For each size we build a request log with that many committed rids
    (batched records, a 512-rid retention window evicting in the same
    records), in two variants: no snapshots (restart replays every
    record) and periodic truncating snapshots via
    :meth:`repro.serving.engine.RequestLog.snapshot` (restart seeds
    from the newest snapshot and replays only the suffix — the builds
    end on a snapshot boundary, so the suffix is empty).  Restart time
    is best-of-3 ``RequestLog(root)`` construction after a warmup
    restart (jit/compile excluded — steady-state restart is what a
    serving fleet pays).  ``flat_ratio_snap`` (largest/smallest
    snapshot-restart time) is the O(1) claim; ``records_parsed`` makes
    the replayed-suffix length machine-checkable, and
    ``took_effect_no_replay`` asserts a recovering client's probe
    parses zero additional records.  Merged under
    ``out_json["restart"]``."""
    import json
    import tempfile
    from pathlib import Path
    from repro.serving.engine import RequestLog

    BATCH, RETAIN, SNAP_EVERY = 50, 512, 10     # rids/record, window,
    points = {}                                  # commits per snapshot
    with tempfile.TemporaryDirectory() as d:
        for n in sizes:
            n_commits = n // BATCH
            assert n_commits % SNAP_EVERY == 0   # end on a snap boundary
            pt = {"committed_rids": n, "records_written": n_commits}
            for variant in ("nosnap", "snap"):
                root = Path(d) / f"{variant}_{n}"
                log = RequestLog(root)
                rid = 0
                for c in range(n_commits):
                    log.commit({rid + i: [rid + i] for i in range(BATCH)},
                               evict=log.expired_rids(RETAIN))
                    rid += BATCH
                    if variant == "snap" and (c + 1) % SNAP_EVERY == 0:
                        log.snapshot()
                RequestLog(root)                 # warmup (jit compiles)
                best = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    fresh = RequestLog(root)
                    best = min(best, time.perf_counter() - t0)
                pt[f"{variant}_restart_ms"] = best * 1e3
                pt[f"{variant}_records_parsed"] = fresh.records_parsed
                # detectable recovery: the probe answers from the map,
                # no further record parsing
                parsed0 = fresh.records_parsed
                alive = bool(fresh.took_effect([rid - 1])[0])
                evicted = bool(fresh.took_effect([0])[0])
                pt[f"{variant}_took_effect_no_replay"] = (
                    alive and not evicted
                    and fresh.records_parsed == parsed0)
            points[str(n)] = pt
    snap_ms = [points[str(n)]["snap_restart_ms"] for n in sizes]
    nosnap_ms = [points[str(n)]["nosnap_restart_ms"] for n in sizes]
    section = {
        "batch_rids_per_record": BATCH,
        "retain": RETAIN,
        "snap_every_commits": SNAP_EVERY,
        "points": points,
        "flat_ratio_snap": max(snap_ms) / min(snap_ms),
        "growth_ratio_nosnap": nosnap_ms[-1] / nosnap_ms[0],
        "took_effect_no_replay": all(
            points[str(n)][f"{v}_took_effect_no_replay"]
            for n in sizes for v in ("nosnap", "snap")),
    }
    report = _load_report(out_json)
    report["restart"] = section
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# merged restart section into {out_json}", file=sys.stderr)
    for n in sizes:
        pt = points[str(n)]
        rows.append((f"nvt,restart_snap_{n}",
                     pt["snap_restart_ms"] * 1e3,
                     f"records_parsed={pt['snap_records_parsed']};"
                     f"nosnap_ms={pt['nosnap_restart_ms']:.1f}"))
    rows.append(("nvt,restart_flat_ratio",
                 section["flat_ratio_snap"],
                 f"nosnap_growth={section['growth_ratio_nosnap']:.1f}x;"
                 f"took_effect_no_replay="
                 f"{section['took_effect_no_replay']}"))


def bench_nvt_obs(rows, out_json="BENCH_nvt.json",
                  snap_path="OBS_metrics.json"):
    """NVTrace observability section: what the instrumentation *sees*
    and what it *costs*, merged under ``out_json["obs"]``.

    Four sub-reports:

    * ``serving`` — a tiny qwen2-family :class:`ServeEngine` on a fresh
      registry serves a measured request wave (after a warmup wave that
      absorbs the jit compiles); ``serve_request_us`` yields p50/p99,
      the ``span_us{phase=...}`` histograms yield the per-phase (route /
      plan / commit / flush_fence / publish / snapshot) breakdown, and
      the span persistence counts exhibit the paper's asymmetry at
      runtime: the traversal phases (``route``/``plan``) charge **zero**
      persistence instructions, the commit/snapshot phases pay all of
      them (``traversal_free_persistence``).
    * ``consistency`` — the same RequestLog workload runs once under a
      :class:`repro.obs.spans.FaultsTee` feeding both a ``PersistTrace``
      and the span listener; the tracer's lifetime totals, the
      per-finished-span sums, and the trace's per-kind event counts must
      agree exactly (the two observability layers cross-validate on an
      identical run).
    * ``overhead`` — a mixed 50%-update serving point (alternating
      single-rid ``commit`` / ``took_effect`` probe) timed best-of
      interleaved with ``obs=True`` vs ``obs=False``; the enabled /
      disabled us/op ratio is the instrumentation tax CI bounds at 5%.
    * ``compile`` — ``benchmarks/obs_worker.py`` on 2 forced host
      devices: the zipf-skewed rebalance_live stream plus one explicit
      capacity step, with every first-call XLA stall attributed to its
      trigger (re-split width change vs capacity ladder vs steady).

    The measured serving registry is also dumped to ``snap_path`` — the
    artifact the CI obs lane uploads and ``tools/metrics_dump.py``
    smoke-reads."""
    import json
    import tempfile
    from collections import Counter
    from pathlib import Path

    import jax
    import numpy as np

    from repro.analysis.trace import PersistTrace
    from repro.configs.registry import get_arch, tiny
    from repro.models.model import build_model
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.spans import FaultsTee, Tracer
    from repro.serving.engine import RequestLog, ServeEngine

    PHASES = ("route", "plan", "commit", "flush_fence", "publish",
              "snapshot")
    TRAVERSAL, PERSISTING = ("route", "plan"), ("commit", "snapshot")

    # ---- serving latency + per-phase breakdown ----------------------
    cfg = tiny(get_arch("qwen2-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def wave(base, n=24):
        return {base + i: rng.integers(0, cfg.vocab, size=12)
                .astype(np.int32) for i in range(n)}

    reg = MetricsRegistry()
    with tempfile.TemporaryDirectory() as d:
        eng = ServeEngine(model, params, max_len=32, log_dir=d,
                          batch_size=4, retain=64, snapshot_every=4,
                          registry=reg)
        eng.serve(wave(10_000, n=8), n_new=4)     # warmup: jit compiles
        reg.reset()                               # measure steady-state
        eng.serve(wave(0), n_new=4)
        lat = reg.histogram("serve_request_us", lo=1.0, hi=1e8,
                            growth=1.25)
        phases = {}
        for ph in PHASES:
            h = reg.histogram("span_us", lo=0.1, hi=1e8, growth=1.25,
                              phase=ph)
            if h.count:
                phases[ph] = {"count": h.count,
                              "p50_us": h.quantile(0.5),
                              "p99_us": h.quantile(0.99)}
        by_phase = {ph: 0 for ph in PHASES}
        for r in eng.tracer.records():
            by_phase[r["span"]] = (by_phase.get(r["span"], 0)
                                   + sum(r["counts"].values()))
        serving = {
            "requests": lat.count,
            "p50_us": lat.quantile(0.5),
            "p99_us": lat.quantile(0.99),
            "phases": phases,
            "persist_events_by_phase": by_phase,
            # the paper's claim, live: traversal phases persist nothing
            "traversal_free_persistence": (
                all(by_phase[p] == 0 for p in TRAVERSAL)
                and sum(by_phase[p] for p in PERSISTING) > 0),
        }
        reg.dump_json(snap_path)

    # ---- span counts vs PersistTrace on an identical run ------------
    reg2 = MetricsRegistry()
    tracer = Tracer(registry=reg2)
    with tempfile.TemporaryDirectory() as d:
        log = RequestLog(d, registry=reg2, tracer=tracer)
        trace = PersistTrace()
        FaultsTee(trace, log.io.faults).attach(log.io)
        rid = 0
        with tracer.span("workload"):
            for b in range(8):
                log.commit({rid + i: [rid + i] for i in range(4)},
                           evict=log.expired_rids(16))
                rid += 4
                if (b + 1) % 3 == 0:
                    log.snapshot()
        by_kind = dict(Counter(e.kind for e in trace.events))
    consistency = {
        "trace_events": by_kind,
        "tracer_totals": dict(tracer.totals),
        "span_counts": dict(tracer.span_counts),
        "span_trace_consistent": (tracer.totals == by_kind
                                  and tracer.span_counts == by_kind),
    }

    # ---- instrumentation overhead, mixed 50%-update point -----------
    # Paired interleaved measurement: the same op runs back-to-back on
    # an obs=True and an obs=False log (order alternating per op class
    # to cancel fs-commit batching effects), and the estimate is the
    # *median of per-pair differences* — commit latency on a real fs is
    # noisy enough that independently-timed runs cannot resolve a
    # few-percent delta, but paired differences can.
    STEPS, BATCH, TRIALS = 900, 4, 3

    def overhead_trial():
        lr = np.random.default_rng(7)
        with tempfile.TemporaryDirectory() as da, \
                tempfile.TemporaryDirectory() as db:
            logs = {True: RequestLog(da, registry=MetricsRegistry(),
                                     obs=True),
                    False: RequestLog(db, registry=MetricsRegistry(),
                                      obs=False)}
            for log in logs.values():
                log.commit({-1: [0]})             # warm the io path
            diff = {"c": [], "p": []}
            base = {"c": [], "p": []}
            rid = 0
            seen = {"c": 0, "p": 0}
            for step in range(STEPS):
                cls = "c" if step % 2 == 0 else "p"
                order = ((True, False) if seen[cls] % 2 == 0
                         else (False, True))
                seen[cls] += 1
                t = {}
                if cls == "c":                    # 50% updates...
                    batch = {rid + j: [rid + j] for j in range(BATCH)}
                    rid += BATCH
                    for obs in order:
                        t0 = time.perf_counter_ns()
                        logs[obs].commit(batch)
                        t[obs] = time.perf_counter_ns() - t0
                else:                             # ...50% probes
                    probes = [int(x)
                              for x in lr.integers(0, rid, size=BATCH)]
                    for obs in order:
                        t0 = time.perf_counter_ns()
                        logs[obs].took_effect(probes)
                        t[obs] = time.perf_counter_ns() - t0
                diff[cls].append(t[True] - t[False])
                base[cls].append(t[False])
            off_us = (np.median(base["c"]) + np.median(base["p"])) \
                / 2 / 1e3
            delta_us = (np.median(diff["c"]) + np.median(diff["p"])) \
                / 2 / 1e3
            return off_us, delta_us

    trials = sorted((overhead_trial() for _ in range(TRIALS)),
                    key=lambda t: t[1] / t[0])
    off_us, delta_us = trials[TRIALS // 2]        # median trial
    overhead = {
        "ops": STEPS, "batch": BATCH, "trials": TRIALS,
        "disabled_us_per_op": off_us,
        "enabled_us_per_op": off_us + delta_us,
        "delta_us_per_op": delta_us,
        "ratio": 1 + delta_us / off_us,
    }

    # ---- compile-stall attribution (2 forced host devices) ----------
    print("# obs worker: 2 host devices...", file=sys.stderr)
    compile_rep = _run_worker("benchmarks.obs_worker", 2)
    compile_rep["by_trigger"] = compile_rep.pop("compile")

    report = _load_report(out_json)
    report["obs"] = {"serving": serving, "consistency": consistency,
                     "overhead": overhead, "compile": compile_rep,
                     "metrics_snapshot": snap_path}
    with open(out_json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# merged obs section into {out_json}", file=sys.stderr)
    rows.append(("nvt,obs_serve_p50", serving["p50_us"],
                 f"p99={serving['p99_us']:.0f}us;"
                 f"traversal_free={serving['traversal_free_persistence']}"))
    rows.append(("nvt,obs_overhead_ratio", overhead["ratio"],
                 f"enabled={overhead['enabled_us_per_op']:.1f}us;"
                 f"disabled={overhead['disabled_us_per_op']:.1f}us"))
    for trig, st in sorted(compile_rep["by_trigger"].items()):
        rows.append((f"nvt,obs_compile_{trig}", st["stall_us"],
                     f"events={st['events']}"))


def bench_checkpoint(rows):
    """NVTraverse commit vs fence-per-write baseline (paper insight at
    framework scale) on a ~25M-param pytree."""
    import tempfile
    import jax.numpy as jnp
    from repro.persistence.checkpoint import CheckpointManager
    tree = {"p": {f"l{i}": jnp.zeros((256, 1024)) for i in range(24)}}
    FSYNC_US = 1000.0     # nominal NVMe fsync
    for policy in ("nvtraverse", "izraelevitz"):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, policy=policy)
            t0 = time.time()
            mgr.save(1, tree)
            tree2 = dict(tree)
            tree2["p"] = dict(tree["p"])
            tree2["p"]["l0"] = tree["p"]["l0"] + 1
            mgr.save(2, tree2)            # delta commit
            wall = (time.time() - t0) / 2 * 1e6
            c = mgr.io.counters
            derived = (f"fences={c.fences};modeled_us="
                       f"{wall + c.fences * FSYNC_US:.0f}")
            rows.append((f"checkpoint,{policy}", wall, derived))


def bench_kernels(rows):
    """Kernel microbenches: XLA-path wall time (CPU); the Pallas kernels
    are TPU-targeted and validated in interpret mode (tests/test_kernels)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssd_scan.ops import ssd_scan
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (4, 512, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (4, 512, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (4, 512, 4, 64), jnp.float32)
    flash_attention(q, k, v, impl="xla").block_until_ready()
    t0 = time.time()
    for _ in range(3):
        flash_attention(q, k, v, impl="xla").block_until_ready()
    rows.append(("kernel,attention_ref_xla_cpu", (time.time()-t0)/3*1e6,
                 "pallas_validated=interpret"))
    xh = jax.random.normal(ks[3], (2, 1024, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[4], (2, 1024, 8)))
    A = -jnp.ones((8,))
    Bm = jax.random.normal(ks[3], (2, 1024, 64)) * 0.5
    Cm = jax.random.normal(ks[4], (2, 1024, 64)) * 0.5
    ssd_scan(xh, dt, A, Bm, Cm, impl="xla").block_until_ready()
    t0 = time.time()
    for _ in range(3):
        ssd_scan(xh, dt, A, Bm, Cm, impl="xla").block_until_ready()
    rows.append(("kernel,ssd_scan_ref_xla_cpu", (time.time()-t0)/3*1e6,
                 "pallas_validated=interpret"))


def bench_roofline(rows):
    """Roofline terms per (arch × shape) cell from the dry-run artifacts
    (baseline + optimized-defaults matrices when present)."""
    from pathlib import Path
    try:
        from repro.roofline.analysis import load_table
    except Exception as e:    # dry-run not executed yet
        print(f"# roofline skipped: {e}", file=sys.stderr)
        return
    for tag, d in (("base", "benchmarks/results/dryrun"),
                   ("opt", "benchmarks/results/dryrun_opt")):
        if not Path(d).exists():
            continue
        table, _ = load_table(d)
        for r in table:
            dom_t = max(r["t_compute_s"], r["t_memory_s"],
                        r["t_collective_s"])
            rows.append((f"roofline_{tag},{r['arch']},{r['shape']}",
                         dom_t * 1e6,
                         f"dominant={r['dominant']};frac="
                         f"{r['roofline_fraction']:.3f}"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig5a,fig5b,fig5c,fig5d,fig5e,fig5f,"
                         "fig6,hashmap,batched,nvt,ordered,migrate,"
                         "sharded,rebalance_live,restart,obs,ckpt,"
                         "kernels,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    rows = []
    if only is None or any(o.startswith("fig") for o in only):
        bench_paper_figures(rows, only)
    if only is None or only & {"hashmap", "batched"}:
        bench_batched_hashmap(rows)
    if only is None or only & {"nvt", "batched"}:
        bench_nvt(rows)
    if only is None or "ordered" in only:
        bench_nvt_ordered(rows)
    if only is None or "migrate" in only:
        bench_nvt_migrate(rows)
    if only is None or "sharded" in only:
        bench_nvt_sharded(rows)
    if only is None or "rebalance_live" in only:
        bench_nvt_rebalance_live(rows)
    if only is None or "restart" in only:
        bench_nvt_restart(rows)
    if only is None or "obs" in only:
        bench_nvt_obs(rows)
    if only is None or "ckpt" in only:
        bench_checkpoint(rows)
    if only is None or "kernels" in only:
        bench_kernels(rows)
    if only is None or "roofline" in only:
        bench_roofline(rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
