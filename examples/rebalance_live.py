"""Live cross-shard rebalancing in 80 lines.

A 4-shard durable map gets hammered on keys that all hash into ONE
shard's bucket range.  The :class:`AutoRebalancePolicy` notices the
load imbalance from the per-bucket flush counters, re-plans the
boundaries as load quantiles, and re-splits the map *while the stream
keeps committing* — no operator call, no stop-the-world drain.  At the
end the map must still answer exactly like a dict.

    PYTHONPATH=src python examples/rebalance_live.py
"""
import os

# 4 host devices for the 4-shard mesh — must land before jax init
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

import numpy as np                                    # noqa: E402

from repro.core import batched as B                   # noqa: E402
from repro.core.rebalance import (AutoRebalancePolicy,  # noqa: E402
                                  RebalancingShardedMap)

S, NB = 4, 64


def main():
    print(f"=== live rebalance: {S} shards, {NB} buckets ===\n")
    # an adversarial key set: everything hashes into shard 0's range
    hot = [k for k in range(4000)
           if int(B.bucket_of_np(np.asarray([k], np.int32), NB)[0])
           < NB // S][:48]
    m = RebalancingShardedMap(
        S, capacity=8192, n_buckets=NB, rounds_per_update=2,
        policy=AutoRebalancePolicy(threshold=1.3, min_load=64,
                                   check_every=2))
    print(f"even splits {m.splits}; streaming mixed ops on {len(hot)} "
          f"keys owned entirely by shard 0...")
    rng = np.random.default_rng(0)
    model = {}
    seen_trigger = False
    for step in range(30):
        ks = np.asarray(rng.choice(hot, 48), np.int32)
        ops = rng.integers(0, 2, 48).astype(np.int32)
        vs = rng.integers(0, 1000, 48).astype(np.int32)
        ok, _ = m.update(ops, ks, vs)
        for o, k, v, okk in zip(ops, ks, vs, ok):
            if o == B.OP_INSERT and okk:
                model[int(k)] = int(v)
            elif o == B.OP_DELETE and okk:
                model.pop(int(k), None)
        if m.rebalancing and not seen_trigger:
            seen_trigger = True
            print(f"step {step:2d}: policy fired (imbalance "
                  f"{m.last_trigger_imbalance:.2f}x) — re-splitting to "
                  f"{m.splits} under traffic, frontier {m.frontier}")
        elif not m.rebalancing and seen_trigger and \
                m.rebalances_completed == 1:
            seen_trigger = False
            r = m.last_report
            print(f"step {step:2d}: rebalance complete — {r.migrated} "
                  f"keys drained in {r.rounds} bounded rounds, "
                  f"{m.pulls_total} pulled by user batches, "
                  f"foreign_ops={r.foreign_ops}")

    assert m.rebalances_completed >= 1, "the skew must trigger a re-split"
    assert m.splits[1] <= NB // S, "the hot range must have shrunk"
    live = {k: v for k, (l, v) in m.items().items() if l}
    assert live == model, "live rebalance must be invisible to content"
    f, v = m.lookup(np.asarray(hot, np.int32))
    for k, ff, vv in zip(hot, f, v):
        assert bool(ff) == (k in model) and (not ff or int(vv) == model[k])
    print(f"\nfinal splits {m.splits} after "
          f"{m.rebalances_completed} rebalance(s); "
          f"{len(live)} live keys — all answers match the dict oracle ✓")


if __name__ == "__main__":
    main()
