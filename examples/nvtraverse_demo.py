"""All five paper structures under concurrent crashes — the full gauntlet.

For each structure (list, BST, hash table, skiplist, queue): run a random
concurrent workload under the NVTraverse policy, crash at a random
instruction with a random eviction subset, recover with disconnect(root),
and check durable linearizability with the Wing&Gong-style checker.

    PYTHONPATH=src python examples/nvtraverse_demo.py
"""
import numpy as np

from repro.core.bst import ExternalBST
from repro.core.harris_list import HarrisList
from repro.core.hash_table import HashTable
from repro.core.linearizability import (check_durably_linearizable,
                                        check_queue_durably_linearizable,
                                        check_stack_durably_linearizable)
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.queue import MSQueue
from repro.core.scheduler import Interleaver
from repro.core.skiplist import SkipList
from repro.core.stack import TreiberStack
from repro.core.traversal import run_operation

STRUCTURES = {
    "harris-list": lambda mem: HarrisList(mem),
    "ellen-bst": lambda mem: ExternalBST(mem),
    "hash-table": lambda mem: HashTable(mem, n_buckets=8),
    "skiplist": lambda mem: SkipList(mem),
}


def gauntlet(name, factory, trials=6):
    pol = get_policy("nvtraverse")
    passed = 0
    for seed in range(trials):
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 17, seed=seed)
        ds = factory(mem)
        init = list(range(0, 16, 2))
        for k in init:
            run_operation(ds, pol, "insert", (k, k))
        mem.persist_all()
        ops = []
        for _ in range(16):
            op = rng.choice(["insert", "delete", "find"])
            k = int(rng.integers(0, 16))
            ops.append((op, (k, k) if op == "insert" else (k,)))
        il = Interleaver(ds, pol, ops, seed=seed)
        recs = il.run(crash_at=int(rng.integers(10, 200)), evict="random")
        if il.crashed:
            ds.disconnect()
            ok = check_durably_linearizable(
                recs, set(ds.contents()), initial_keys=init)
        else:
            ok = True
        passed += ok
    print(f"  {name:12s}: {passed}/{trials} crash trials durably "
          f"linearizable")
    assert passed == trials


def queue_gauntlet(trials=6):
    pol = get_policy("nvtraverse")
    passed = 0
    for seed in range(trials):
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 16, seed=seed)
        q = MSQueue(mem)
        ops, v = [], 100
        for _ in range(12):
            if rng.random() < 0.6:
                ops.append(("enqueue", (v,)))
                v += 1
            else:
                ops.append(("dequeue", ()))
        il = Interleaver(q, pol, ops, seed=seed)
        recs = il.run(crash_at=int(rng.integers(5, 80)), evict="random")
        if il.crashed:
            q.disconnect()
            ok = check_queue_durably_linearizable(recs, q.contents())
        else:
            ok = True
        passed += ok
    print(f"  {'ms-queue':12s}: {passed}/{trials} crash trials durably "
          f"linearizable")
    assert passed == trials


def stack_gauntlet(trials=6):
    pol = get_policy("nvtraverse")
    passed = 0
    for seed in range(trials):
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 16, seed=seed)
        st = TreiberStack(mem)
        ops, v = [], 100
        for _ in range(11):
            if rng.random() < 0.6:
                ops.append(("push", (v,)))
                v += 1
            else:
                ops.append(("pop", ()))
        il = Interleaver(st, pol, ops, seed=seed)
        recs = il.run(crash_at=int(rng.integers(5, 70)), evict="random")
        if il.crashed:
            st.disconnect()
            ok = check_stack_durably_linearizable(recs, st.contents())
        else:
            ok = True
        passed += ok
    print(f"  {'treiber-stack':12s}: {passed}/{trials} crash trials durably "
          f"linearizable")
    assert passed == trials


def main():
    print("NVTraverse demo: concurrent workloads + crashes + recovery\n")
    for name, factory in STRUCTURES.items():
        gauntlet(name, factory)
    queue_gauntlet()
    stack_gauntlet()
    print("\nall structures pass Theorem 4.2's guarantee under the "
          "interleaving/eviction adversary ✓")


if __name__ == "__main__":
    main()
