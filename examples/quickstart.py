"""Quickstart: the NVTraverse transformation in 60 lines.

Builds Harris's linked list in traversal form, runs it under the three
policies the paper compares, crashes it, recovers it, and prints the
flush/fence economy that is the paper's headline result.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.harris_list import HarrisList
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.traversal import run_operation


def main():
    print("=== NVTraverse quickstart: Harris list, 512 keys ===\n")
    stats = {}
    for policy_name in ("volatile", "izraelevitz", "nvtraverse"):
        mem = PMem(1 << 18)
        ds = HarrisList(mem)
        pol = get_policy(policy_name)
        for k in range(0, 1024, 2):
            run_operation(ds, pol, "insert", (k, k))
        mem.counters.reset()
        n_ops = 300
        for i in range(n_ops):
            k = (i * 7) % 1024
            run_operation(ds, pol, "find", (k,))
            if i % 10 == 0:
                run_operation(ds, pol, "delete", (k,))
                run_operation(ds, pol, "insert", (k, k))
        c = mem.counters
        stats[policy_name] = c.snapshot()
        print(f"{policy_name:12s}: {c.flushes/n_ops:8.1f} flushes/op "
              f"{c.fences/n_ops:8.1f} fences/op "
              f"(traverse-phase flushes: {c.traverse_flushes})")

    ratio = stats["izraelevitz"]["fences"] / max(
        1, stats["nvtraverse"]["fences"])
    print(f"\nNVTraverse uses {ratio:.1f}x fewer fences than the "
          f"Izraelevitz et al. general transform")
    print("(the paper reports 13.5x-39.6x throughput on Optane from "
          "exactly this economy)\n")

    print("=== crash + recovery (Theorem 4.2 in action) ===")
    mem = PMem(1 << 16, seed=1)
    ds = HarrisList(mem)
    pol = get_policy("nvtraverse")
    for k in range(20):
        run_operation(ds, pol, "insert", (k, k * 10))
    print("before crash:", sorted(ds.contents())[:10], "...")
    mem.crash(evict="random", p_evict=0.5)   # lose the volatile view
    ds.disconnect()                          # recovery = Supplement 1
    recovered = sorted(ds.contents())
    print("after crash+recovery:", recovered[:10], "...")
    assert recovered == list(range(20)), "completed inserts must survive"
    print("all committed operations survived the crash. ✓")


if __name__ == "__main__":
    main()
