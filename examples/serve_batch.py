"""Serving example: batched requests with a durable request log.

Serves a batch of prompts against a reduced qwen2-7b-family model, crashes
the engine mid-run, restarts it, and shows that committed results survive
(exactly-once) while in-flight requests are transparently re-executed.

    PYTHONPATH=src python examples/serve_batch.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs.registry import get_arch, tiny
from repro.models.model import build_model
from repro.serving.engine import ServeEngine


def main():
    cfg = tiny(get_arch("qwen2-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    requests = {i: rng.integers(0, cfg.vocab, size=12).astype(np.int32)
                for i in range(8)}

    tmp = tempfile.mkdtemp(prefix="serve_")
    try:
        eng = ServeEngine(model, params, max_len=32, log_dir=tmp,
                          batch_size=2)
        print("serving 8 requests, crash injected after 2 batches...")
        partial = eng.serve(requests, n_new=6, crash_after_batches=2)
        print(f"  committed before crash: {sorted(partial)}")

        print("restarting engine on the same log...")
        eng2 = ServeEngine(model, params, max_len=32, log_dir=tmp,
                           batch_size=2)
        full = eng2.serve(requests, n_new=6)
        print(f"  committed after recovery: {sorted(full)}")
        assert set(full) == set(requests)
        for rid in partial:
            assert full[rid] == partial[rid], "committed result changed!"
        print("\nfirst 3 generations:")
        for rid in range(3):
            print(f"  request {rid}: {full[rid]}")
        print("\ncommitted results survived the crash unmodified; "
              "in-flight requests were re-served exactly once ✓")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
