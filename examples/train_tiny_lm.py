"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps with NVTraverse checkpointing, inject a crash, resume, and
verify the trajectory matches an uninterrupted run.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax

from repro.configs.registry import get_arch
from repro.launch.train import run_training
import repro.launch.train as train_mod


def arch_100m():
    """~100M-parameter member of the qwen3 family."""
    base = get_arch("qwen3-1.7b")
    return dataclasses.replace(
        base, n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=1536, vocab=32000, param_dtype="float32",
        compute_dtype="float32", microbatches=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--crash-at", type=int, default=None)
    args = ap.parse_args()
    crash_at = args.crash_at or args.steps // 2 + 3

    cfg = arch_100m()
    n = cfg.n_params()
    print(f"arch: qwen3-family reduced, {n/1e6:.0f}M params, "
          f"{args.steps} steps, crash at {crash_at}\n")

    # register the custom config so run_training can find it
    train_mod.parse_arch = lambda spec: cfg

    tmp = tempfile.mkdtemp(prefix="train_tiny_")
    try:
        kw = dict(arch="custom", steps=args.steps, ckpt_every=25,
                  global_batch=8, seq_len=128, seed=1)
        print("— reference run (no crash) —")
        ref = run_training(ckpt_dir=f"{tmp}/ref", **kw)
        print(f"  final loss {ref['final_loss']:.4f}; "
              f"fsync fences: {ref['io']['fences']}")

        print(f"— crashed run (dies at step {crash_at}) —")
        first = run_training(ckpt_dir=f"{tmp}/crash", crash_at=crash_at,
                             **kw)
        print(f"  crashed at step {first['crashed_at']}")

        print("— resumed run —")
        second = run_training(ckpt_dir=f"{tmp}/crash", **kw)
        print(f"  {second['log'][0]}")
        print(f"  final loss {second['final_loss']:.4f}")

        drift = abs(second["final_loss"] - ref["final_loss"])
        print(f"\ncrash-restart drift vs uninterrupted run: {drift:.2e}")
        assert drift < 1e-5, "resumed trajectory diverged!"
        assert ref["losses"][args.steps] < ref["losses"][1], "no learning?"
        print("resumed training is bit-faithful to the uninterrupted run ✓")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
