#!/usr/bin/env python
"""PersistLint CLI: static + trace-based persistence-ordering analysis.

Runs the two `repro.analysis` passes over the repo and exits nonzero on
any unwaived static violation or any fatal trace violation:

  * --static : AST lint of src/repro (raw-durable-io,
    publish-needs-fence, traverse-phase-persistence, crash-site-kinds;
    `# persistlint: waive(<rule>) — <why>` annotations honored and
    counted).
  * --trace  : record the full persistence-instruction stream of the
    six durable-layer faultinject scenarios in no-crash mode and
    replay it against the ordering rules (missing-flush,
    publish-before-persist, traversal-phase-persistence fatal;
    redundant-flush / fence-with-nothing-pending reported non-fatal).

With neither flag, both passes run.  --layers narrows the trace pass;
--json writes the combined machine-readable report.

  PYTHONPATH=src python tools/persist_lint.py --static --trace --json out.json
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main(argv=None) -> int:
    from repro.analysis.checker import check_events
    from repro.analysis.persistlint import run_static
    from repro.analysis.trace import trace_scenario
    from repro.robustness.faultinject import SCENARIOS

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--static", action="store_true", dest="static_",
                    help="run the AST lint over src/repro")
    ap.add_argument("--trace", action="store_true",
                    help="run the dynamic trace checker")
    ap.add_argument("--layers", default=",".join(SCENARIOS),
                    help="comma-separated trace layers "
                         f"(default: {','.join(SCENARIOS)})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the combined report as JSON")
    args = ap.parse_args(argv)
    if not args.static_ and not args.trace:
        args.static_ = args.trace = True

    report = {}
    fatal = 0

    if args.static_:
        sr = run_static()
        report["static"] = sr.to_dict()
        fatal += len(sr.violations)
        print(f"[static] {sr.n_files} files, "
              f"{len(sr.violations)} violation(s), "
              f"{len(sr.waived)} waiver(s)")
        for v in sr.violations:
            print(f"  VIOLATION {v.rule} {v.file}:{v.line} — {v.msg}")
        for v in sr.waived:
            print(f"  waived    {v.rule} {v.file}:{v.line}")

    if args.trace:
        layers = [s for s in args.layers.split(",") if s]
        unknown = [s for s in layers if s not in SCENARIOS]
        if unknown:
            ap.error(f"unknown layer(s) {unknown}; "
                     f"choose from {sorted(SCENARIOS)}")
        report["trace"] = {}
        for layer in layers:
            tr = trace_scenario(layer)
            rep = check_events(tr.events)
            report["trace"][layer] = rep.to_dict()
            fatal += len(rep.violations)
            print(f"[trace:{layer}] {rep.n_events} events, "
                  f"{len(rep.violations)} violation(s), "
                  f"{len(rep.diagnostics)} diagnostic(s)")
            for f in rep.violations:
                print(f"  VIOLATION {f.rule} @{f.index} "
                      f"{f.target} — {f.detail}")
            for f in rep.diagnostics:
                print(f"  diag      {f.rule} @{f.index} "
                      f"{f.target} — {f.detail}")

    report["ok"] = fatal == 0
    if args.json:
        Path(args.json).write_text(json.dumps(report, indent=1))
        print(f"report -> {args.json}")
    print("persistlint:", "OK" if report["ok"] else f"{fatal} violation(s)")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
