#!/usr/bin/env python
"""Perf-regression gate over BENCH_nvt.json history.

    python tools/bench_history.py --bench BENCH_nvt.json \
        --history BENCH_history.json [--append --run-id <label>] \
        [--check [--strict]] [--json CHECK.json]

Two verbs, composable in one invocation:

* ``--append`` extracts the tracked scalars (``SCALARS`` below: us/op
  per engine section, serving p50/p99, sustained ops/s, overhead and
  restart ratios) from the bench report and appends one entry to
  ``BENCH_history.json`` (bounded to ``--max-entries``, oldest
  dropped).
* ``--check`` compares the current bench against the history using
  **noise bands from repeated-trial spread**: per scalar, the baseline
  is the median of the historical values and the band is
  ``max(band_k * MAD, rel_slack * |median|)`` — so a scalar with a
  noisy history gets a wide band and a stable one a floor of
  ``rel_slack`` (shared CI runners are not a metrology lab).
  Direction-aware: a lower-is-better scalar regresses only *upward*, a
  higher-is-better one only *downward*; improvements never fail.
  Scalars with fewer than ``--min-runs`` historical samples are
  reported as ``new`` and never gate.

``--check`` alone is **report-only** (exit 0, regressions printed);
``--strict`` makes regressions exit 1 — the CI lane runs report-only
for one PR before the gate becomes blocking (see docs/benchmarks.md).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from statistics import median

# (dotted path with "*" wildcards, direction).  Direction "lower":
# bigger is a regression (latency, us/op, overhead ratios); "higher":
# smaller is a regression (throughput, speedups).
SCALARS = [
    ("insert.parallel_us_per_op", "lower"),
    ("insert.speedup", "higher"),
    ("mixed.*.parallel_us_per_op", "lower"),
    ("mixed.*.speedup", "higher"),
    ("ordered.parallel_us_per_op", "lower"),
    ("ordered.speedup", "higher"),
    ("ordered.range.us_per_query", "lower"),
    ("ordered.top_k.us_per_call", "lower"),
    ("restart.flat_ratio_snap", "lower"),
    ("restart.growth_ratio_nosnap", "higher"),
    ("obs.overhead.ratio", "lower"),
    ("obs.serving.p50_us", "lower"),
    ("obs.serving.p99_us", "lower"),
    ("serving_load.points.*.p50_us", "lower"),
    ("serving_load.points.*.p99_us", "lower"),
    ("serving_load.points.*.sustained_ops_s", "higher"),
]


def _walk(node, parts, prefix):
    """Yield (dotted-name, value) for one wildcard path."""
    if not parts:
        if isinstance(node, (int, float)) and not isinstance(node, bool):
            yield prefix, float(node)
        return
    head, rest = parts[0], parts[1:]
    if not isinstance(node, dict):
        return
    keys = sorted(node) if head == "*" else ([head] if head in node
                                             else [])
    for k in keys:
        yield from _walk(node[k], rest,
                         f"{prefix}.{k}" if prefix else k)


def extract(bench: dict) -> dict:
    """``{scalar_name: (value, direction)}`` for every tracked scalar
    present in the bench report — absent sections are simply skipped,
    so partial bench runs produce partial entries."""
    out = {}
    for path, direction in SCALARS:
        for name, v in _walk(bench, path.split("."), ""):
            out[name] = (v, direction)
    return out


def load_history(path) -> dict:
    try:
        h = json.loads(Path(path).read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        return {"format": 1, "entries": []}
    h.setdefault("entries", [])
    return h


def append_entry(history: dict, scalars: dict, run_id: str,
                 max_entries: int = 50) -> None:
    history["entries"].append(
        {"run": run_id, "scalars": {k: v for k, (v, _) in
                                    sorted(scalars.items())}})
    del history["entries"][:-max_entries]


def check(scalars: dict, history: dict, *, min_runs: int = 3,
          band_k: float = 5.0, rel_slack: float = 0.5) -> dict:
    """Compare current scalars against history noise bands.

    Returns ``{"checked", "regressions": [...], "improved": [...],
    "new": [...]}``; a regression entry carries the value, baseline,
    band and the history spread it was judged against.
    """
    series = {}
    for e in history["entries"]:
        for k, v in e["scalars"].items():
            series.setdefault(k, []).append(float(v))
    regressions, improved, new, checked = [], [], [], 0
    for name, (cur, direction) in sorted(scalars.items()):
        hist = series.get(name, [])
        if len(hist) < min_runs:
            new.append(name)
            continue
        checked += 1
        base = median(hist)
        mad = median(abs(v - base) for v in hist)
        band = max(band_k * mad, rel_slack * abs(base))
        delta = cur - base if direction == "lower" else base - cur
        row = {"name": name, "direction": direction, "value": cur,
               "baseline": base, "band": band, "mad": mad,
               "n_history": len(hist)}
        if delta > band:
            regressions.append(row)
        elif delta < -band:
            improved.append(row)
    return {"checked": checked, "regressions": regressions,
            "improved": improved, "new": new}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_nvt.json")
    ap.add_argument("--history", default="BENCH_history.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--run-id", default="local")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regression (default: report-only)")
    ap.add_argument("--min-runs", type=int, default=3)
    ap.add_argument("--band-k", type=float, default=5.0)
    ap.add_argument("--rel-slack", type=float, default=0.5)
    ap.add_argument("--max-entries", type=int, default=50)
    ap.add_argument("--json", default=None,
                    help="write the check verdict to this file")
    args = ap.parse_args()

    try:
        bench = json.loads(Path(args.bench).read_text())
    except (FileNotFoundError, json.JSONDecodeError) as e:
        print(f"bench_history: cannot read {args.bench}: {e}",
              file=sys.stderr)
        return 2
    scalars = extract(bench)
    history = load_history(args.history)
    print(f"bench_history: {len(scalars)} tracked scalars in "
          f"{args.bench}, {len(history['entries'])} history entries")

    verdict = None
    if args.check:
        verdict = check(scalars, history, min_runs=args.min_runs,
                        band_k=args.band_k, rel_slack=args.rel_slack)
        for r in verdict["regressions"]:
            print(f"REGRESSION {r['name']}: {r['value']:.4g} vs "
                  f"baseline {r['baseline']:.4g} "
                  f"(band +-{r['band']:.4g}, {r['direction']}-is-better,"
                  f" n={r['n_history']})")
        for r in verdict["improved"]:
            print(f"improved   {r['name']}: {r['value']:.4g} vs "
                  f"baseline {r['baseline']:.4g}")
        print(f"bench_history: checked={verdict['checked']} "
              f"regressions={len(verdict['regressions'])} "
              f"improved={len(verdict['improved'])} "
              f"new={len(verdict['new'])}")
        if args.json:
            Path(args.json).write_text(
                json.dumps(verdict, indent=1, sort_keys=True))

    if args.append:
        append_entry(history, scalars, args.run_id,
                     max_entries=args.max_entries)
        Path(args.history).write_text(
            json.dumps(history, indent=1, sort_keys=True))
        print(f"bench_history: appended run {args.run_id!r} -> "
              f"{args.history} ({len(history['entries'])} entries)")

    if args.check and args.strict and verdict["regressions"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
