"""Docs lane: keep the prose wired to the code.

Two checks, both designed to fail CI the moment a doc rots:

1. **Link + code-pointer check** (always): every relative markdown link
   in ``docs/*.md`` (and ``ROADMAP.md``) must resolve to a real file,
   and every backticked code pointer of the form ``path/to/file.py``,
   ``file.py:symbol`` or ``file.py::test_node`` must name a file that
   exists (resolved against the repo root, ``src/repro/``, or by
   basename search under ``src/``) and — when a symbol is given — a
   ``def``/``class`` of that name inside it.

2. **Doctest smoke** (``--doctest``): runs the doctest examples
   embedded in the API docstrings of the durable-map stack
   (host-side helpers only — hashes, split planning, header
   round-trips), and fails if fewer than ``MIN_DOCTESTS`` examples ran,
   so the smoke cannot silently become empty.

    PYTHONPATH=src python tools/check_docs.py [--doctest]
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "ROADMAP.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(
    r"`([\w][\w/.-]*\.(?:py|md|json|yml))((?:::?)[\w.]+)?`")

DOCTEST_MODULES = [
    "repro.core.batched",
    "repro.core.ordered",
    "repro.core.skiplist",
    "repro.core.sharded",
    "repro.core.migrate",
    "repro.core.rebalance",
    "repro.launch.mesh",
    "repro.persistence.index",
    "repro.core.pmem",
    "repro.robustness.faultinject",
    "repro.analysis.persistlint",
    "repro.analysis.checker",
    "repro.obs.metrics",
    "repro.obs.windows",
    "repro.obs.timeline",
    "repro.obs.loadgen",
]
MIN_DOCTESTS = 6


def resolve(path: str):
    """A doc-referenced path, resolved the way a reader would: repo
    root, then the package root, then by basename anywhere in src/."""
    for base in (REPO, REPO / "src" / "repro", REPO / "src"):
        if (base / path).exists():
            return base / path
    hits = list((REPO / "src").rglob(path))
    return hits[0] if hits else None


def check_links() -> list:
    errors = []
    for doc in DOC_FILES:
        text = doc.read_text()
        rel = doc.relative_to(REPO)
        for m in LINK_RE.finditer(text):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(("http://", "https://",
                                                "mailto:")):
                continue
            if not ((doc.parent / target).exists()
                    or (REPO / target).exists()):
                errors.append(f"{rel}: broken link -> {m.group(1)}")
        for m in CODE_RE.finditer(text):
            path, sym = m.group(1), m.group(2)
            f = resolve(path)
            if f is None:
                errors.append(f"{rel}: dangling code pointer -> {path}")
                continue
            if sym and f.suffix == ".py":
                src = f.read_text()
                for part in sym.lstrip(":").split("."):
                    if not re.search(
                            rf"(?:def|class)\s+{re.escape(part)}\b"
                            rf"|^{re.escape(part)}\s*=", src, re.M):
                        errors.append(
                            f"{rel}: {path} has no symbol '{part}' "
                            f"(pointer {path}{sym})")
    return errors


def run_doctests() -> list:
    import doctest
    import importlib

    errors = []
    attempted = 0
    for name in DOCTEST_MODULES:
        try:
            mod = importlib.import_module(name)
        except Exception as e:
            errors.append(f"doctest: cannot import {name}: {e}")
            continue
        res = doctest.testmod(mod, verbose=False)
        attempted += res.attempted
        if res.failed:
            errors.append(f"doctest: {res.failed} failure(s) in {name}")
    if attempted < MIN_DOCTESTS:
        errors.append(
            f"doctest smoke shrank: only {attempted} examples ran "
            f"(expected >= {MIN_DOCTESTS}) — docstring examples were "
            f"removed without updating tools/check_docs.py")
    print(f"doctest smoke: {attempted} examples across "
          f"{len(DOCTEST_MODULES)} modules")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--doctest", action="store_true",
                    help="also run the docstring doctest smoke")
    args = ap.parse_args()
    errors = check_links()
    n_docs = len(DOC_FILES)
    if args.doctest:
        errors += run_doctests()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"docs ok: {n_docs} markdown files checked")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
