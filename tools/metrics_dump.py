#!/usr/bin/env python
"""Metrics snapshot CLI: inspect / merge / re-export NVTrace snapshots.

Reads one or more JSON snapshots produced by
``repro.obs.metrics.MetricsRegistry.snapshot`` (e.g. the
``OBS_metrics.json`` artifact the obs bench writes), merges them
(counters/histograms add — the cross-shard path), and prints either a
human summary (default), the merged snapshot JSON (``--json``), or
Prometheus text exposition (``--prom``).

  PYTHONPATH=src python tools/metrics_dump.py OBS_metrics.json
  PYTHONPATH=src python tools/metrics_dump.py shard*.json --prom
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main(argv=None) -> int:
    from repro.obs.metrics import MetricsRegistry

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshots", nargs="+", metavar="SNAP.json",
                    help="registry snapshot file(s); several merge")
    ap.add_argument("--prom", action="store_true",
                    help="print Prometheus text exposition")
    ap.add_argument("--json", action="store_true", dest="json_",
                    help="print the merged snapshot as JSON")
    ap.add_argument("--quantiles", default="0.5,0.99,0.999",
                    help="histogram quantiles for the summary table")
    args = ap.parse_args(argv)

    reg = MetricsRegistry()
    for path in args.snapshots:
        try:
            with open(path) as f:
                reg.merge_snapshot(json.load(f))
        except (OSError, ValueError, KeyError) as e:
            print(f"error: cannot read snapshot {path}: {e}",
                  file=sys.stderr)
            return 1

    if args.prom:
        sys.stdout.write(reg.to_prometheus())
        return 0
    if args.json_:
        json.dump(reg.snapshot(), sys.stdout, indent=1, sort_keys=True)
        sys.stdout.write("\n")
        return 0

    qs = [float(q) for q in args.quantiles.split(",") if q]
    for e in sorted(reg.entries(),
                    key=lambda e: (e.kind, e.name, sorted(e.labels.items()))):
        lbl = ",".join(f"{k}={v}" for k, v in sorted(e.labels.items()))
        lbl = f"{{{lbl}}}" if lbl else ""
        if e.kind in ("counter", "gauge"):
            print(f"{e.kind:9s} {e.name}{lbl} = {e.obj.value}")
        else:
            h = e.obj
            qtxt = " ".join(f"p{q * 100:g}={h.quantile(q):.3g}"
                            for q in qs)
            print(f"histogram {e.name}{lbl} count={h.count} "
                  f"sum={h.sum:.6g} {qtxt}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
