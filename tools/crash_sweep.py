"""Crash-fault-injection sweep over the six durable-layer scenarios.

Drives :mod:`repro.robustness.faultinject`: for each selected layer the
scenario is run once crash-free to enumerate every persistence site
(flush / fence / publish / trim), then re-run with a deterministic
crash injected at each site (or an evenly spaced ``--budget`` subset,
first and last site always included) under each ``--evict`` adversary
mode, and the recovery invariants are checked after every crash: no
acknowledged op lost, prefix durability, oracle equivalence.

    PYTHONPATH=src python tools/crash_sweep.py
    PYTHONPATH=src python tools/crash_sweep.py --layers log,migrate \
        --budget 12 --evict none,random --json CRASH_sweep.json
    PYTHONPATH=src python tools/crash_sweep.py --list

Exit status is nonzero if any site × eviction-mode run violates an
invariant.  ``--shards N`` sizes the rebalance layer's mesh *and* — for
N > 1 — runs the ``log``/``log2`` scenarios with their dedup index on
the sharded durable-map backend (``log_shards``-style serving); both
need that many JAX devices, e.g. XLA_FLAGS
``--xla_force_host_platform_device_count=N``.  ``--evict`` accepts the
``torn`` partial-write adversary alongside ``none``/``random``: evicted
staged files land truncated or garbled, and recovery must treat them
exactly like torn records.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    from repro.robustness.faultinject import (SCENARIOS, enumerate_sites,
                                              sweep)

    ap = argparse.ArgumentParser(
        description="crash-at-every-site sweep over the durable layers")
    ap.add_argument("--layers", default=",".join(SCENARIOS),
                    help=f"comma list of {sorted(SCENARIOS)}")
    ap.add_argument("--budget", type=int, default=None,
                    help="max sites tested per layer per evict mode "
                         "(evenly spaced; default: every site)")
    ap.add_argument("--evict", default="none,random,torn",
                    help="comma list of eviction adversary modes "
                         "(none, random, torn)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="mesh size for the rebalance layer; > 1 also "
                         "runs log/log2 with a sharded dedup index")
    ap.add_argument("--list", action="store_true",
                    help="only enumerate and print the sites, no sweep")
    ap.add_argument("--json", default=None,
                    help="write the full report to this path")
    args = ap.parse_args()

    layers = [l.strip() for l in args.layers.split(",") if l.strip()]
    unknown = [l for l in layers if l not in SCENARIOS]
    if unknown:
        ap.error(f"unknown layers {unknown}; choose from "
                 f"{sorted(SCENARIOS)}")
    evict_modes = [m.strip() for m in args.evict.split(",") if m.strip()]

    report = {"budget": args.budget, "seed": args.seed,
              "evict_modes": evict_modes, "shards": args.shards,
              "layers": {}}
    failed = False
    for layer in layers:
        if layer == "rebalance":
            kw = {"n_shards": args.shards}
        elif layer in ("log", "log2") and args.shards > 1:
            kw = {"shards": args.shards}
        else:
            kw = None
        if args.list:
            for s in enumerate_sites(SCENARIOS[layer], kw):
                print(f"{layer:10s} site {s.index:3d}  {s.kind:7s} "
                      f"{s.target}")
            continue
        rep = sweep(SCENARIOS[layer], budget=args.budget,
                    evict_modes=evict_modes, seed=args.seed,
                    scenario_kw=kw)
        report["layers"][layer] = rep
        ok = not rep["failures"]
        failed |= not ok
        print(f"layer={layer:10s} sites={rep['n_sites']:3d} "
              f"tested={len(rep['tested_sites']):3d} "
              f"runs={rep['runs']:3d} "
              f"failures={len(rep['failures'])} "
              f"{'ok' if ok else 'FAIL'}")
        for f in rep["failures"]:
            print(f"  FAIL site {f['site']} ({f['kind']} {f['target']}) "
                  f"evict={f['evict']}: {f['error']}", file=sys.stderr)
    if args.json and not args.list:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"report -> {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
