"""Sharded durable map (core/sharded.py) vs the single-device engine.

The single-shard tests run everywhere (a 1-device mesh exercises the
full routing + shard_map + valid-padding path).  The multi-shard tests
skip unless enough jax devices exist — CI runs them in the multi-device
lane under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the
subprocess smoke test gives single-device environments the same
coverage (slow lane).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as B
from repro.core.sharded import ShardedDurableMap, items_of_state

NB = 64


def _need(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})")


def _mixed_rounds(map_, ref, rounds, seed, n_lo=5, n_hi=60, key_hi=50):
    """Drive the sharded map and the single-device engine through the
    same mixed rounds; assert per-op ok, gathered per-key content,
    aggregate flush/fence accounting, and lookups stay identical."""
    rng = np.random.default_rng(seed)
    for rnd in range(rounds):
        n = int(rng.integers(n_lo, n_hi))
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, key_hi, size=n).astype(np.int32)
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ref, ok_ref, stats_ref = B.update_parallel(
            ref, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), NB)
        ok_sh, stats_sh = map_.update(ops, ks, vs)
        np.testing.assert_array_equal(np.asarray(ok_ref), ok_sh,
                                      err_msg=f"round {rnd}: ok diverged")
        np.testing.assert_array_equal(
            np.asarray(stats_ref.bucket_flushes),
            np.asarray(stats_sh.bucket_flushes),
            err_msg=f"round {rnd}: per-bucket flushes diverged")
        assert int(np.sum(np.asarray(stats_sh.foreign_ops))) == 0
        assert stats_sh.total_ops_committed == int(stats_ref.ops_committed)
        assert stats_sh.total_coalesced_flushes == \
            int(stats_ref.coalesced_flushes)
    assert items_of_state(ref) == map_.items()
    assert map_.flushes == int(ref.flushes)
    assert map_.fences == int(ref.fences)
    q = rng.integers(0, key_hi + 20, size=64).astype(np.int32)
    f_ref, v_ref = B.lookup(ref, jnp.asarray(q), NB)
    f_sh, v_sh = map_.lookup(q)
    np.testing.assert_array_equal(np.asarray(f_ref), f_sh)
    np.testing.assert_array_equal(np.asarray(v_ref) * np.asarray(f_ref),
                                  v_sh * f_sh)
    return ref


def test_single_shard_matches_engine():
    """A 1-shard mesh runs the whole dispatch pipeline (routing sort,
    all-to-all, valid padding) and must be op-for-op identical to the
    raw engine — this is the tier-1 guard for the sharded layer."""
    m = ShardedDurableMap(1, capacity=4096, n_buckets=NB)
    _mixed_rounds(m, B.make_state(4096, NB), rounds=6, seed=0)


def test_single_shard_homogeneous_wrappers():
    m = ShardedDurableMap(1, capacity=512, n_buckets=NB)
    ks = np.arange(1, 101, dtype=np.int32)
    ok, _ = m.insert(ks, ks * 3)
    assert ok.all()
    found, vals = m.lookup(ks)
    assert found.all()
    np.testing.assert_array_equal(vals, ks * 3)
    ok, _ = m.delete(np.array([1, 1, 999], np.int32))
    assert list(ok) == [True, False, False]
    found, vals = m.lookup(np.array([1], np.int32))
    assert not found[0]
    # batched.lookup's exact contract: not-found val is 0 even though
    # the dead node still holds the old value (probe exposes that)
    assert int(vals[0]) == 0
    exists, live, pv = m.probe(np.array([1, 2, 999], np.int32))
    assert list(exists) == [True, True, False]
    assert list(live) == [False, True, False]
    assert int(pv[1]) == 6


@_need(2)
def test_bad_bucket_split_rejected():
    with pytest.raises(ValueError):
        ShardedDurableMap(2, capacity=64, n_buckets=63)


def test_mesh_n_shards_mismatch_rejected():
    with pytest.raises(ValueError):
        ShardedDurableMap(2, capacity=64, n_buckets=64,
                          mesh=jax.make_mesh((1,), ("shards",)))


@_need(2)
def test_two_shards_match_engine():
    m = ShardedDurableMap(2, capacity=4096, n_buckets=NB)
    _mixed_rounds(m, B.make_state(4096, NB), rounds=6, seed=1)


@_need(4)
def test_four_shards_match_engine():
    m = ShardedDurableMap(4, capacity=4096, n_buckets=NB)
    _mixed_rounds(m, B.make_state(4096, NB), rounds=6, seed=2)


@_need(8)
def test_eight_shards_match_engine_heavy_duplicates():
    """The acceptance-criteria shape: 8 host devices, duplicate-heavy
    mixed batches, per-key/liveness + aggregate flush/fence identity."""
    m = ShardedDurableMap(8, capacity=8192, n_buckets=NB)
    _mixed_rounds(m, B.make_state(8192, NB), rounds=8, seed=3,
                  n_lo=50, n_hi=200, key_hi=40)


@_need(2)
def test_per_shard_commit_stays_in_bucket_range():
    """The persistence-locality proof via the instrumentation counters:
    every flush a shard issues lands in its own bucket range, each
    shard's flush total equals the single-device engine's flush total
    over exactly that bucket range, and no shard ever receives an op
    for a foreign bucket."""
    S = 2 if jax.device_count() < 4 else 4
    nb_local = NB // S
    m = ShardedDurableMap(S, capacity=4096, n_buckets=NB)
    ref = B.make_state(4096, NB)
    rng = np.random.default_rng(7)
    for _ in range(5):
        n = 80
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, 60, size=n).astype(np.int32)
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ref, _, stats_ref = B.update_parallel(
            ref, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), NB)
        _, stats_sh = m.update(ops, ks, vs)
        assert list(np.asarray(stats_sh.foreign_ops)) == [0] * S
        ref_bf = np.asarray(stats_ref.bucket_flushes)
        sh_bf = np.asarray(stats_sh.bucket_flushes).reshape(S, nb_local)
        for s in range(S):
            lo, hi = s * nb_local, (s + 1) * nb_local
            # shard s's flushes are exactly the reference's flushes for
            # its own range — and therefore zero everywhere else
            np.testing.assert_array_equal(sh_bf[s], ref_bf[lo:hi])
            assert int(np.asarray(stats_sh.coalesced_flushes)[s]) == \
                int(ref_bf[lo:hi].sum())
        # the global coalesced fence law across concurrent shards
        assert stats_sh.global_coalesced_fences == \
            2 * int(np.max(np.asarray(stats_sh.max_group)))


@_need(2)
def test_sharded_index_growth_under_skewed_keys():
    """Never-drop under adversarial skew: keys chosen to hash entirely
    into ONE shard's bucket range overflow that shard's pool long
    before the global capacity bound does — growth must size for the
    fullest shard (checked rebuild), not the global member count."""
    from repro.persistence.index import MembershipIndex

    nb, S = 128, 2
    nb_local = nb // S
    # index stores key+1; pick keys owned by shard 0
    skewed = [k for k in range(1000)
              if int(B.bucket_of(jnp.int32(k + 1), nb)) // nb_local == 0]
    assert len(skewed) >= 20
    idx = MembershipIndex(capacity=8, n_buckets=nb, n_shards=S)
    for i in range(0, 20, 3):          # cap_local=4: overflows fast
        idx.add(skewed[i:i + 3])
    got = idx.contains(skewed[:20])
    assert bool(got.all()), f"dropped members: {np.flatnonzero(~got)}"
    # removals + resurrect still behave after the skewed growth
    idx.update(add_keys=skewed[20:25], remove_keys=skewed[:5])
    assert not idx.contains(skewed[:5]).any()
    assert idx.contains(skewed[5:25]).all()


@_need(2)
def test_sharded_index_resurrect_does_not_trigger_growth():
    """The exact fits check must know that a removed key's node is
    resurrected in place: filling the pool, removing members, and
    re-adding them allocates nothing — and therefore must not run a
    spurious growth migration."""
    from repro.persistence.index import MembershipIndex

    idx = MembershipIndex(capacity=64, n_buckets=128, n_shards=2)
    keys = list(range(100, 160))           # fills most of the 2x32 pools
    for i in range(0, len(keys), 16):
        idx.add(keys[i:i + 16])
    grown = idx.migrations
    idx.remove(keys[:40])
    idx.add(keys[:40])                     # pure resurrection round
    assert idx.migrations == grown, "resurrects were counted as fresh"
    assert bool(idx.contains(keys).all())


@_need(2)
def test_sharded_membership_index_and_requestlog(tmp_path):
    from repro.persistence.index import MembershipIndex
    from repro.serving.engine import RequestLog

    idx = MembershipIndex(capacity=8, n_buckets=128, n_shards=2)
    keys = list(range(100, 180))
    for i in range(0, len(keys), 16):
        idx.add(keys[i:i + 16])
    assert idx.capacity >= 81          # grew past the initial pool
    assert bool(idx.contains(keys).all())
    idx.update(add_keys=[500, 2**40], remove_keys=[100, 101, 500])
    assert list(idx.contains([100, 101, 500, 2**40, 102])) == \
        [False, False, False, True, True]
    idx.add([100])                     # resurrect after remove
    assert bool(idx.contains([100])[0])

    log = RequestLog(tmp_path, shards=2)
    log.commit({1: [10], 2: [20]})
    log.commit({3: [30]}, evict=[1])
    assert list(log.is_committed([1, 2, 3])) == [False, True, True]
    # a second instance on the same dir folds the records identically
    log2 = RequestLog(tmp_path, shards=2)
    assert list(log2.is_committed([1, 2, 3])) == [False, True, True]
    assert log2.committed() == {2: [20], 3: [30]}


def test_make_map_splits_even_and_load_weighted():
    from repro.launch.mesh import make_map_splits

    assert make_map_splits(64, 4) == (0, 16, 32, 48, 64)
    with pytest.raises(ValueError):
        make_map_splits(63, 2)
    # all the load in the first 8 buckets → shard 0's range shrinks to
    # them and the cold remainder spreads over the other shards
    loads = np.zeros(64)
    loads[:8] = 100.0
    s = make_map_splits(64, 4, loads=loads)
    assert s[0] == 0 and s[-1] == 64
    assert all(a < b for a, b in zip(s, s[1:]))     # non-empty ranges
    assert s[1] <= 8                                # hot range isolated
    with pytest.raises(ValueError):
        make_map_splits(64, 4, loads=np.zeros(63))


def test_single_shard_uneven_splits_rejected_and_accepted():
    with pytest.raises(ValueError):
        ShardedDurableMap(1, capacity=256, n_buckets=NB,
                          splits=(0, 10, NB))      # wrong boundary count
    m = ShardedDurableMap(1, capacity=256, n_buckets=NB, splits=(0, NB))
    ks = np.arange(1, 51, dtype=np.int32)
    ok, _ = m.insert(ks, ks)
    assert ok.all()


def test_rebalance_single_shard_roundtrip():
    """1-shard rebalance exercises the full drain/route/commit pipeline
    everywhere: content preserved, locality counters clean, chains
    compacted (dead nodes dropped by the drain)."""
    m = ShardedDurableMap(1, capacity=2048, n_buckets=NB)
    ks = np.arange(1, 301, dtype=np.int32)
    m.insert(ks, ks * 3)
    m.delete(ks[::3])
    before = {k: v for k, (l, v) in m.items().items() if l}
    rep = m.rebalance((0, NB), buckets_per_round=5)
    assert rep.foreign_ops == 0
    assert rep.migrated == len(before)
    after = {k: v for k, (l, v) in m.items().items() if l}
    assert after == before
    assert rep.chain_after[1] <= rep.chain_before[1]   # compaction
    # the map keeps serving correctly post-rebalance
    f, v = m.lookup(ks)
    exp = np.asarray([int(k) in before for k in ks])
    np.testing.assert_array_equal(f, exp)


@_need(2)
def test_rebalance_uneven_splits_content_and_locality():
    """Re-split a live map onto uneven boundaries: per-key content is
    preserved, every migrated flush lands inside its *new* owner range
    (foreign_ops == 0; per-shard flush totals equal their own-range
    sums), and subsequent mixed rounds still match the single-device
    engine op-for-op."""
    S = 2 if jax.device_count() < 4 else 4
    m = ShardedDurableMap(S, capacity=4096, n_buckets=NB)
    ref = B.make_state(4096, NB)
    rng = np.random.default_rng(21)
    for _ in range(4):
        n = 90
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, 180, size=n).astype(np.int32)
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ref, ok_r, _ = B.update_parallel(
            ref, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), NB)
        ok_s, _ = m.update(ops, ks, vs)
        np.testing.assert_array_equal(np.asarray(ok_r), ok_s)
    live_ref = {k: v for k, (l, v) in items_of_state(ref).items() if l}
    splits = ((0, 12, NB) if S == 2 else (0, 6, 12, 40, NB))
    rep = m.rebalance(splits, buckets_per_round=7)
    assert m.splits == splits
    assert rep.foreign_ops == 0
    live_m = {k: v for k, (l, v) in m.items().items() if l}
    assert live_m == live_ref
    # every migrated key flushed exactly twice, in its own global bucket
    exp = np.zeros(NB, np.int64)
    np.add.at(exp, B.bucket_of_np(
        np.asarray(sorted(live_ref), np.int32), NB), 2)
    np.testing.assert_array_equal(rep.bucket_flushes, exp)
    # post-rebalance traffic: ok flags + lookups still engine-identical,
    # per-shard flushes confined to the new (uneven) owner ranges
    for _ in range(3):
        n = 80
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, 220, size=n).astype(np.int32)
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ref, ok_r, _ = B.update_parallel(
            ref, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), NB)
        ok_s, stats = m.update(ops, ks, vs)
        np.testing.assert_array_equal(np.asarray(ok_r), ok_s)
        assert int(np.sum(np.asarray(stats.foreign_ops))) == 0
        bf = np.asarray(stats.bucket_flushes)
        for s in range(S):
            lo, hi = splits[s], splits[s + 1]
            assert int(np.asarray(stats.coalesced_flushes)[s]) == \
                int(bf[lo:hi].sum())
    q = rng.integers(0, 260, size=128).astype(np.int32)
    f_r, v_r = B.lookup(ref, jnp.asarray(q), NB)
    f_s, v_s = m.lookup(q)
    np.testing.assert_array_equal(np.asarray(f_r), f_s)
    np.testing.assert_array_equal(np.asarray(v_r) * np.asarray(f_r),
                                  v_s * f_s)


@_need(2)
def test_migrate_to_growth_and_rehash_over_mesh():
    """Capacity + bucket-count growth through the mesh migration path:
    the split shape scales with the bucket space, content survives, and
    the rehash shortens chains."""
    m = ShardedDurableMap(2, capacity=1024, n_buckets=NB)
    ks = np.arange(1, 401, dtype=np.int32)
    m.insert(ks, ks * 7)
    m.delete(ks[::4])
    live = {k: v for k, (l, v) in m.items().items() if l}
    new, rep = m.migrate_to(capacity=4096, n_buckets=2 * NB)
    assert new.n_buckets == 2 * NB
    assert new.splits == tuple(2 * b for b in m.splits)
    assert rep.foreign_ops == 0
    assert {k: v for k, (l, v) in new.items().items() if l} == live
    assert rep.chain_after[1] < rep.chain_before[1]


@_need(8)
def test_eight_shard_rebalance_equivalence():
    """The multi-device-lane rebalance equivalence shape: 8 shards,
    duplicate-heavy traffic, a skew-correcting re-split mid-stream
    (boundaries from the live per-bucket flush loads), then more
    traffic — op results and final content must track the single-device
    engine throughout, with zero foreign ops."""
    from repro.launch.mesh import make_map_splits

    m = ShardedDurableMap(8, capacity=8192, n_buckets=NB)
    ref = B.make_state(8192, NB)
    rng = np.random.default_rng(31)
    loads = np.zeros(NB, np.int64)
    for _ in range(4):
        n = 160
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, 50, size=n).astype(np.int32)  # dup-heavy
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ref, ok_r, _ = B.update_parallel(
            ref, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), NB)
        ok_s, stats = m.update(ops, ks, vs)
        np.testing.assert_array_equal(np.asarray(ok_r), ok_s)
        loads += np.asarray(stats.bucket_flushes)
    rep = m.rebalance(make_map_splits(NB, 8, loads=loads))
    assert rep.foreign_ops == 0
    assert {k: v for k, (l, v) in m.items().items() if l} == \
        {k: v for k, (l, v) in items_of_state(ref).items() if l}
    for rnd in range(4):
        n = 120
        ops = rng.integers(0, 2, size=n).astype(np.int32)
        ks = rng.integers(0, 80, size=n).astype(np.int32)
        vs = rng.integers(0, 1000, size=n).astype(np.int32)
        ref, ok_r, _ = B.update_parallel(
            ref, jnp.asarray(ops), jnp.asarray(ks), jnp.asarray(vs), NB)
        ok_s, stats = m.update(ops, ks, vs)
        np.testing.assert_array_equal(np.asarray(ok_r), ok_s,
                                      err_msg=f"post-rebalance {rnd}")
        assert int(np.sum(np.asarray(stats.foreign_ops))) == 0
    q = rng.integers(0, 100, size=256).astype(np.int32)
    f_r, v_r = B.lookup(ref, jnp.asarray(q), NB)
    f_s, v_s = m.lookup(q)
    np.testing.assert_array_equal(np.asarray(f_r), f_s)
    np.testing.assert_array_equal(np.asarray(v_r) * np.asarray(f_r),
                                  v_s * f_s)


@_need(4)
def test_acceptance_4shard_8c_growth_under_live_traffic():
    """Acceptance criterion (sharded half): a 4-shard index seeded at
    capacity C absorbs 8C inserts under live mixed traffic, growing by
    mesh migration rounds; every member answer matches a dict model."""
    from repro.persistence.index import MembershipIndex

    C = 256
    idx = MembershipIndex(capacity=C, n_buckets=128, n_shards=4)
    model = set()
    rng = np.random.default_rng(41)
    next_key = 1
    while next_key <= 8 * C:
        fresh = list(range(next_key, next_key + 64))
        next_key += 64
        rem = [int(k) for k in rng.integers(1, next_key, size=16)
               if int(k) in model]
        idx.update(add_keys=fresh, remove_keys=rem)
        model |= set(fresh)
        model -= set(rem)
    assert idx.migrations >= 1
    probe = list(rng.integers(1, next_key + 50, size=500))
    got = idx.contains(probe)
    np.testing.assert_array_equal(
        got, np.asarray([int(k) in model for k in probe]))


def test_chain_stats_aggregates_across_shards():
    m = ShardedDurableMap(1, capacity=4096, n_buckets=8,
                          mesh=jax.make_mesh((1,), ("shards",)))
    ks = np.arange(1, 401, dtype=np.int32)
    m.insert(ks, ks)
    mx, mean = m.chain_stats()
    assert mean == pytest.approx(400 / 8)
    assert mx >= mean


@pytest.mark.slow
def test_eight_shard_subprocess_smoke():
    """Multi-shard coverage for single-device environments: re-run the
    2/4/8-shard equivalence tests in a subprocess with 8 forced host
    devices (XLA_FLAGS must precede jax init, hence the subprocess)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else "src")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         "tests/test_sharded.py", "-k", "shard or range",
         "-p", "no:cacheprovider"],       # pytest.ini's -m "not slow"
        capture_output=True, text=True, env=env)   # excludes this test
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "skipped" not in proc.stdout.split("\n")[-2], proc.stdout
