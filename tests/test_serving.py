"""Serving engine: batched requests, durable request log, crash recovery."""
import numpy as np
import jax
import pytest

from repro.configs.registry import get_arch, tiny
from repro.models.model import build_model
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = tiny(get_arch("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=6, S=16, seed=0):
    rng = np.random.default_rng(seed)
    return {i: rng.integers(0, cfg.vocab, size=S).astype(np.int32)
            for i in range(n)}


def test_serve_batch_completes_and_commits(setup, tmp_path):
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    reqs = _requests(cfg)
    out = eng.serve(reqs, n_new=4)
    assert set(out) == set(reqs)
    assert all(len(v) == 4 for v in out.values())
    # greedy decode is deterministic: re-serving returns identical results
    out2 = ServeEngine(model, params, max_len=32,
                       log_dir=tmp_path, batch_size=2).serve(reqs, n_new=4)
    assert out2 == out


def test_serve_crash_recovery_exactly_once(setup, tmp_path):
    """Crash after 1 committed batch: committed results survive, the rest
    are re-executed on restart, nothing is served twice or lost."""
    cfg, model, params = setup
    reqs = _requests(cfg)
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    partial = eng.serve(reqs, n_new=4, crash_after_batches=1)
    assert len(partial) == 2                      # one batch committed
    eng2 = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                       batch_size=2)
    full = eng2.serve(reqs, n_new=4)
    assert set(full) == set(reqs)
    for rid, gen in partial.items():
        assert full[rid] == gen                   # survived unmodified


def test_request_log_dedup_oob_rids_and_cross_instance(tmp_path):
    """The durable-map dedup must keep the old dict probe's behavior:
    arbitrary-int rids (outside int32) are accepted, restart against a
    log containing them works, and commits from another RequestLog
    instance on the same dir are visible after refresh()."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({7: [1], 2**33: [2], -5: [3]})
    assert list(log.is_committed([7, 2**33, -5, 8])) == [True] * 3 + [False]
    log2 = RequestLog(tmp_path)          # restart over the oob-rid log
    assert list(log2.is_committed([7, 2**33, -5, 8])) == [True] * 3 + [False]
    a, b = RequestLog(tmp_path), RequestLog(tmp_path)
    b.commit({42: [9]})
    a.refresh()                          # serve() calls this each time
    assert bool(a.is_committed([42])[0])


def test_serve_results_match_teacher_forcing(setup, tmp_path):
    """The engine's prefill+decode greedy path agrees with running the
    model once over the full (prompt + generated) sequence."""
    import jax.numpy as jnp
    cfg, model, params = setup
    reqs = _requests(cfg, n=2, S=12)
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    out = eng.serve(reqs, n_new=3)
    for rid, gen in out.items():
        seq = np.concatenate([reqs[rid], np.asarray(gen[:-1], np.int32)])
        logits, _ = jax.jit(lambda p, b: model.prefill(p, b, 32))(
            params, {"tokens": jnp.asarray(seq[None])})
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == gen[-1]
