"""Serving engine: batched requests, durable request log, crash recovery."""
import numpy as np
import jax
import pytest

from repro.configs.registry import get_arch, tiny
from repro.models.model import build_model
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = tiny(get_arch("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=6, S=16, seed=0):
    rng = np.random.default_rng(seed)
    return {i: rng.integers(0, cfg.vocab, size=S).astype(np.int32)
            for i in range(n)}


def test_serve_batch_completes_and_commits(setup, tmp_path):
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    reqs = _requests(cfg)
    out = eng.serve(reqs, n_new=4)
    assert set(out) == set(reqs)
    assert all(len(v) == 4 for v in out.values())
    # greedy decode is deterministic: re-serving returns identical results
    out2 = ServeEngine(model, params, max_len=32,
                       log_dir=tmp_path, batch_size=2).serve(reqs, n_new=4)
    assert out2 == out


def test_serve_crash_recovery_exactly_once(setup, tmp_path):
    """Crash after 1 committed batch: committed results survive, the rest
    are re-executed on restart, nothing is served twice or lost."""
    cfg, model, params = setup
    reqs = _requests(cfg)
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    partial = eng.serve(reqs, n_new=4, crash_after_batches=1)
    assert len(partial) == 2                      # one batch committed
    eng2 = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                       batch_size=2)
    full = eng2.serve(reqs, n_new=4)
    assert set(full) == set(reqs)
    for rid, gen in partial.items():
        assert full[rid] == gen                   # survived unmodified


def test_request_log_dedup_oob_rids_and_cross_instance(tmp_path):
    """The durable-map dedup must keep the old dict probe's behavior:
    arbitrary-int rids (outside int32) are accepted, restart against a
    log containing them works, and commits from another RequestLog
    instance on the same dir are visible after refresh()."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({7: [1], 2**33: [2], -5: [3]})
    assert list(log.is_committed([7, 2**33, -5, 8])) == [True] * 3 + [False]
    log2 = RequestLog(tmp_path)          # restart over the oob-rid log
    assert list(log2.is_committed([7, 2**33, -5, 8])) == [True] * 3 + [False]
    a, b = RequestLog(tmp_path), RequestLog(tmp_path)
    b.commit({42: [9]})
    a.refresh()                          # serve() calls this each time
    assert bool(a.is_committed([42])[0])


def test_request_log_torn_record_never_causes_overwrite(tmp_path):
    """A torn log record earlier in the sequence must not shift later
    commits onto occupied slots: restart derives the next log index from
    the highest existing index (torn files included), so acknowledged
    results are never silently destroyed."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({1: [1]})                           # log_000000.json
    log.commit({2: [2]})                           # log_000001.json
    log.commit({3: [3]})                           # log_000002.json
    (tmp_path / "log_000001.json").write_text('{"2": [2')    # tear it
    log2 = RequestLog(tmp_path)        # restart over the torn log
    assert log2._n == 3                # past every slot seen on disk
    # restart recovery trims the permanent torn record
    assert not (tmp_path / "log_000001.json").exists()
    log2.commit({4: [4]})              # lands on log_000003.json
    got = log2.committed()
    assert got[1] == [1] and got[3] == [3] and got[4] == [4]
    assert list(log2.is_committed([1, 3, 4])) == [True] * 3
    assert (tmp_path / "log_000003.json").exists()


def test_request_log_concurrent_instances_never_collide(tmp_path):
    """Two RequestLog instances on the same dir (no refresh between
    commits): the second commit must not overwrite the first instance's
    record — commit() claims its slot with an atomic O_EXCL create."""
    from repro.serving.engine import RequestLog
    a, b = RequestLog(tmp_path), RequestLog(tmp_path)
    a.commit({1: [1]})
    b.commit({2: [2]})
    assert RequestLog(tmp_path).committed() == {1: [1], 2: [2]}


def test_request_log_torn_record_heals_when_writer_completes(tmp_path):
    """A record observed mid-write parses as torn, but must be retried
    once its on-disk signature changes — a slow concurrent committer is
    not poisoned forever in the reader's dedup index."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    p = tmp_path / "log_000000.json"
    p.write_text('{"9": [1')             # reader overtakes the writer
    log.refresh()
    assert not log.is_committed([9])[0]
    assert "log_000000.json" in log._torn
    p.write_text('{"9": [1, 2]}')        # the writer's fence completes
    log.refresh()
    assert bool(log.is_committed([9])[0])
    assert "log_000000.json" not in log._torn


def test_request_log_crash_between_claim_and_fence(tmp_path):
    """A crash after the slot claim but before the fence leaves a
    zero-byte placeholder: restart recovery trims it and later commits
    step past its slot."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log._claim_slot()                    # placeholder, payload never fenced
    log.io.crash(evict="none")
    log2 = RequestLog(tmp_path)
    assert not (tmp_path / "log_000000.json").exists()   # trimmed
    log2.commit({5: [5]})
    assert (tmp_path / "log_000001.json").exists()       # slot not reused
    assert log2.committed() == {5: [5]}
    assert bool(log2.is_committed([5])[0])


def test_serve_ragged_prompt_lengths(setup, tmp_path):
    """Mixed-length request dicts must serve (no np.stack crash) via
    equal-length batch groups, and a request's generation must not
    depend on which other requests share its batch — no pad-token
    leakage into shorter rows' attention."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    reqs = {i: rng.integers(0, cfg.vocab, size=s).astype(np.int32)
            for i, s in enumerate((5, 16, 9, 12, 16, 7))}
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=4)
    out = eng.serve(reqs, n_new=4)
    assert set(out) == set(reqs)
    assert all(len(v) == 4 for v in out.values())
    # batch-composition independence: the same prompt served alone (on a
    # fresh log) yields the identical committed generation
    solo = ServeEngine(model, params, max_len=32,
                       log_dir=tmp_path / "solo", batch_size=4)
    alone = solo.serve({0: reqs[0]}, n_new=4)
    assert alone[0] == out[0]


def test_serve_returns_only_requested_rids(setup, tmp_path):
    """serve() answers for the rids it was asked, not every historically
    committed result in the log."""
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    first = _requests(cfg, n=4, seed=1)
    out1 = eng.serve(first, n_new=3)
    assert set(out1) == set(first)
    second = {rid + 100: p for rid, p in _requests(cfg, n=2, seed=2).items()}
    out2 = eng.serve(second, n_new=3)
    assert set(out2) == set(second)          # none of `first` leaks through
    # re-asking for a committed rid answers from the log, scoped the same
    out3 = eng.serve({0: first[0]}, n_new=3)
    assert set(out3) == {0} and out3[0] == out1[0]


def test_refresh_skips_scan_when_dir_unchanged(tmp_path, monkeypatch):
    """refresh() must not re-glob the whole log dir when nothing changed:
    the directory-mtime fast path keeps serve() O(new records)."""
    import time as _time
    from repro.serving.engine import RequestLog
    # shrink the racy window to this filesystem's real granularity so the
    # test does not sleep out the production network-mount headroom
    monkeypatch.setattr(RequestLog, "_RACY_NS", 50_000_000)
    log = RequestLog(tmp_path)
    log.commit({1: [1]})
    # step past the racy-timestamp window: a dir mtime younger than one
    # clock granule never authorizes the fast path
    _time.sleep(RequestLog._RACY_NS / 1e9 + 0.02)
    log.refresh()                            # scans once, caches dir mtime
    other = RequestLog(tmp_path)
    calls = []
    orig = RequestLog._scan

    def counting_scan(self):
        calls.append(1)
        return orig(self)

    monkeypatch.setattr(RequestLog, "_scan", counting_scan)
    log.refresh()
    log.refresh()
    assert calls == []                       # unchanged dir: no scan
    other.commit({2: [2]})                   # new record bumps dir mtime
    log.refresh()
    assert calls == [1]
    assert bool(log.is_committed([2])[0])


def test_refresh_torn_record_checks_only_torn_not_full_scan(tmp_path,
                                                            monkeypatch):
    """A lingering torn record (writer crashed before its fence) must not
    disable the fast path: an unchanged dir re-stats only the torn names
    (no full scandir), and the torn record still heals when its content
    changes — which is invisible to the dir mtime."""
    import time as _time
    from repro.serving.engine import RequestLog
    monkeypatch.setattr(RequestLog, "_RACY_NS", 50_000_000)
    log = RequestLog(tmp_path)
    log.commit({1: [1]})
    p = tmp_path / "log_000001.json"
    p.write_text('{"9": [1')                 # torn record appears
    _time.sleep(RequestLog._RACY_NS / 1e9 + 0.02)
    log.refresh()                            # scans, records torn, caches
    assert "log_000001.json" in log._torn
    scans = []
    monkeypatch.setattr(RequestLog, "_scan",
                        lambda self: scans.append(1))
    log.refresh()                            # unchanged dir + stable torn
    assert scans == []                       # no full scan
    assert not log.is_committed([9])[0]
    p.write_text('{"9": [1, 2]}')            # the writer's fence completes
    log.refresh()                            # dir mtime unchanged: heal
    assert scans == []                       # ...via the torn-only path
    assert bool(log.is_committed([9])[0])
    assert "log_000001.json" not in log._torn


def test_request_log_evict_round_and_restart_replay(tmp_path):
    """A commit's evictions land in the same record and the same mixed
    plan/commit round: evicted rids leave the exactly-once window, and a
    restart replaying the log in slot order reaches the same horizon."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({1: [1], 2: [2]})
    log.commit({3: [3]}, evict=[1])
    assert list(log.is_committed([1, 2, 3])) == [False, True, True]
    assert set(log.committed()) == {2, 3}
    log2 = RequestLog(tmp_path)              # restart: replay incl. evicts
    assert list(log2.is_committed([1, 2, 3])) == [False, True, True]
    assert set(log2.committed()) == {2, 3}
    # an evicted rid is re-servable: committing it again succeeds
    log2.commit({1: [9]})
    assert bool(log2.is_committed([1])[0])
    assert log2.committed()[1] == [9]


def test_request_log_dedup_grows_under_live_traffic(tmp_path):
    """The dedup map's seed capacity is only a starting point: a rid
    stream far past it grows the index online via migration rounds
    (no stop-the-world rebuild path left), keeps exactly-once intact
    across the growth events, and a restarted instance replays the log
    into its own (re-grown) map with identical answers."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path, capacity=16)
    rid = 0
    for _ in range(20):                      # 320 rids through a 16-pool
        log.commit({rid + i: [rid + i] for i in range(16)})
        rid += 16
    assert log.dedup_migrations >= 1
    assert bool(log.is_committed(range(rid)).all())
    assert not log.is_committed([rid, rid + 1]).any()
    # evictions during growth keep the exactly-once window consistent
    log.commit({rid: [1]}, evict=list(range(100)))
    got = log.is_committed(list(range(104)) + [rid])
    assert not got[:100].any() and got[100:].all()
    log2 = RequestLog(tmp_path, capacity=16)     # restart: same answers
    np.testing.assert_array_equal(
        log2.is_committed(list(range(104)) + [rid]), got)


def test_serve_retention_evicts_old_rids(setup, tmp_path):
    """retain=N bounds the exactly-once window: rids from *earlier* calls
    are evicted from the dedup index in the same commit round as new
    results — but never the rids the current call is serving, whose
    results were just paid for and are all returned."""
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2, retain=2)
    first = _requests(cfg, n=4, seed=1)
    out1 = eng.serve(first, n_new=3)
    assert set(out1) == set(first)           # current call never evicted
    second = {rid + 100: p for rid, p in _requests(cfg, n=4, seed=2).items()}
    out2 = eng.serve(second, n_new=3)
    assert set(out2) == set(second)
    # the first call's rids fell off the retention horizon
    assert not eng.log.is_committed(sorted(first)).any()
    committed = eng.log.committed()
    assert set(committed) <= set(second)
    assert len(committed) <= 2 + eng.batch   # horizon: retain + last batch


def test_snapshot_restart_replays_only_the_suffix(tmp_path):
    """O(1) serving restart: after snapshot(), a fresh RequestLog seeds
    itself from the snapshot and parses zero pre-horizon records — the
    restart cost is the post-snapshot suffix, not the served history."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    for i in range(10):
        log.commit({i: [i, i]})
    assert log.snapshot() == "snap_00000010.json"
    # truncation removed the covered records and any older snapshot
    assert sorted(p.name for p in tmp_path.glob("log_*.json")) == []
    log.commit({10: [10, 10]})                   # post-snapshot suffix
    log2 = RequestLog(tmp_path)                  # restart
    assert log2.records_parsed == 1              # the suffix record only
    assert log2.committed() == {i: [i, i] for i in range(11)}
    assert bool(log2.is_committed(range(11)).all())
    # a second snapshot supersedes the first
    assert log2.snapshot() == "snap_00000011.json"
    assert sorted(p.name for p in tmp_path.glob("snap_*.json")) == \
        ["snap_00000011.json"]
    log3 = RequestLog(tmp_path)
    assert log3.records_parsed == 0              # nothing left to replay
    assert log3.committed() == log2.committed()


def test_snapshot_carries_evictions_and_is_idempotent(tmp_path):
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({1: [1], 2: [2]})
    log.commit({3: [3]}, evict=[1])
    assert log.snapshot() is not None
    assert log.snapshot() is None                # nothing new covered
    log2 = RequestLog(tmp_path)
    assert set(log2.committed()) == {2, 3}       # eviction survived
    assert list(log2.is_committed([1, 2, 3])) == [False, True, True]


def test_snapshot_horizon_never_covers_a_torn_record(tmp_path):
    """A torn record may still heal into a commit, so the snapshot
    horizon stops below it — the record is not erased by truncation and
    folds normally once its writer finishes."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    for i in range(3):
        log.commit({i: [i]})
    p = tmp_path / "log_000003.json"
    p.write_text('{"9": [9')                     # concurrent mid-write
    assert log.snapshot() == "snap_00000003.json"
    assert p.exists()                            # not truncated away
    p.write_text('{"9": [9]}')                   # the writer finishes
    log2 = RequestLog(tmp_path)
    assert log2.committed() == {0: [0], 1: [1], 2: [2], 9: [9]}


def test_restart_trims_interrupted_truncation_leftovers(tmp_path):
    """A crash between the snapshot publish and the truncation unlinks
    leaves covered records (and an older snapshot) behind; the next
    restart folds nothing from them and trims them."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({1: [1]})
    old = log.snapshot(truncate=False)           # crash before truncating
    log.commit({2: [2]})
    new = log.snapshot(truncate=False)
    assert sorted(p.name for p in tmp_path.glob("*.json")) == \
        ["log_000000.json", "log_000001.json", old, new]
    log2 = RequestLog(tmp_path)
    assert log2.records_parsed == 0              # leftovers never parsed
    assert log2.committed() == {1: [1], 2: [2]}
    assert sorted(p.name for p in tmp_path.glob("*.json")) == [new]


def test_took_effect_and_descriptor_without_replay(tmp_path):
    """Detectable recovery: a recovering client asks took_effect(rid) /
    descriptor(rid) and is answered from the snapshot-seeded dedup map —
    zero log records parsed after the restart."""
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path)
    log.commit({1: [1, 2], 2: [2, 3]})
    log.commit({3: [3, 4]}, evict=[1])
    log.snapshot()
    log2 = RequestLog(tmp_path)
    assert log2.records_parsed == 0
    np.testing.assert_array_equal(log2.took_effect([1, 2, 3, 4]),
                                  [False, True, True, False])
    assert log2.descriptor(2) == {"rid": 2, "took_effect": True,
                                  "result": [2, 3]}
    # an evicted rid's descriptor left the window with its result
    assert log2.descriptor(1) == {"rid": 1, "took_effect": False,
                                  "result": None}
    assert log2.descriptor(99)["took_effect"] is False


def test_restart_trim_retries_failed_unlink_once(tmp_path, monkeypatch):
    """Satellite: restart-trim of a torn placeholder tolerates one
    transient unlink failure (retry after backoff) and a *persistent*
    failure never fails the restart — the file just stays torn."""
    from pathlib import Path
    from repro.serving.engine import RequestLog
    monkeypatch.setattr(RequestLog, "_TRIM_BACKOFF_S", 0.0)
    (tmp_path / "log_000000.json").write_text('{"1": [1')
    orig, calls = Path.unlink, []

    def flaky(self, missing_ok=False):
        if self.name == "log_000000.json":
            calls.append(1)
            if len(calls) == 1:
                raise OSError("EBUSY")
        return orig(self, missing_ok=missing_ok)

    monkeypatch.setattr(Path, "unlink", flaky)
    log = RequestLog(tmp_path)                   # restart succeeds
    assert calls == [1, 1]                       # failed once, retried
    assert not (tmp_path / "log_000000.json").exists()
    # persistent failure: restart still succeeds, file left torn
    (tmp_path / "log_000001.json").write_text('{"2": [2')

    def always_fails(self, missing_ok=False):
        if self.name == "log_000001.json":
            raise OSError("EBUSY")
        return orig(self, missing_ok=missing_ok)

    monkeypatch.setattr(Path, "unlink", always_fails)
    log2 = RequestLog(tmp_path)
    assert "log_000001.json" in log2._torn
    log2.commit({5: [5]})                        # slot derivation stepped
    assert (tmp_path / "log_000002.json").exists()


def test_restart_trim_heals_a_racing_writer_instead(tmp_path,
                                                    monkeypatch):
    """Satellite: the torn placeholder seen at restart may be another
    live instance's in-flight commit — the backoff re-check folds the
    completed record instead of trimming the writer's work."""
    import repro.serving.engine as eng_mod
    from repro.serving.engine import RequestLog
    p = tmp_path / "log_000000.json"
    p.write_text('{"7": [7')                     # writer mid-commit

    def writer_lands(_secs):                     # during the backoff...
        p.write_text('{"7": [7, 8]}')            # ...the fence completes

    monkeypatch.setattr(eng_mod.time, "sleep", writer_lands)
    log = RequestLog(tmp_path)
    assert p.exists()                            # never trimmed
    assert log.committed() == {7: [7, 8]}        # healed into a commit
    assert bool(log.took_effect([7])[0])


def test_serve_engine_snapshot_every(setup, tmp_path):
    """snapshot_every wires the truncating snapshot into the serving
    loop: restarts replay only the tail and answers are unchanged."""
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2, snapshot_every=1)
    reqs = _requests(cfg)
    out = eng.serve(reqs, n_new=4)
    assert set(out) == set(reqs)
    assert len(list(tmp_path.glob("snap_*.json"))) == 1
    assert list(tmp_path.glob("log_*.json")) == []   # all truncated
    eng2 = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                       batch_size=2, snapshot_every=1)
    assert eng2.log.records_parsed == 0              # O(1) restart
    assert eng2.serve(reqs, n_new=4) == out          # from the snapshot
    np.testing.assert_array_equal(eng2.took_effect(sorted(reqs)),
                                  [True] * len(reqs))


def test_serve_results_match_teacher_forcing(setup, tmp_path):
    """The engine's prefill+decode greedy path agrees with running the
    model once over the full (prompt + generated) sequence."""
    import jax.numpy as jnp
    cfg, model, params = setup
    reqs = _requests(cfg, n=2, S=12)
    eng = ServeEngine(model, params, max_len=32, log_dir=tmp_path,
                      batch_size=2)
    out = eng.serve(reqs, n_new=3)
    for rid, gen in out.items():
        seq = np.concatenate([reqs[rid], np.asarray(gen[:-1], np.int32)])
        logits, _ = jax.jit(lambda p, b: model.prefill(p, b, 32))(
            params, {"tokens": jnp.asarray(seq[None])})
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == gen[-1]
