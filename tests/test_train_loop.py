"""End-to-end fault tolerance: crash/restart equivalence, optimizer math,
pipeline determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import run_training


def _digest(losses):
    return {k: round(v, 6) for k, v in losses.items()}


@pytest.mark.parametrize("crash_phase", ["between", "shards", "manifest"])
def test_crash_restart_equivalence(tmp_path, crash_phase):
    """Crash at step 17 (or mid-commit at 20), restart, continue — the
    loss trajectory must bit-match the uninterrupted run."""
    kw = dict(arch="tiny:qwen3-1.7b", steps=30, ckpt_every=10,
              global_batch=4, seq_len=32, seed=3)
    ref = run_training(ckpt_dir=str(tmp_path / "ref"), **kw)
    assert ref["final_step"] == 30

    crash_at = 17 if crash_phase == "between" else 20
    d = str(tmp_path / "crash")
    first = run_training(ckpt_dir=d, crash_at=crash_at,
                         crash_phase=crash_phase, **kw)
    assert first["crashed_at"] == crash_at
    second = run_training(ckpt_dir=d, **kw)
    assert second["final_step"] == 30
    assert any("resumed from committed step" in l for l in second["log"])
    # every step the resumed run computed matches the reference exactly
    for s, loss in second["losses"].items():
        assert abs(loss - ref["losses"][s]) < 1e-6, (s, loss)
    assert second["final_loss"] == pytest.approx(ref["final_loss"], abs=1e-6)


def test_loss_decreases(tmp_path):
    out = run_training(arch="tiny:qwen3-1.7b", steps=30, ckpt_every=30,
                       ckpt_dir=str(tmp_path), global_batch=4, seq_len=32)
    first = np.mean([out["losses"][s] for s in range(1, 6)])
    last = np.mean([out["losses"][s] for s in range(26, 31)])
    assert last < first, (first, last)


def test_pipeline_determinism_and_restore():
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_arch, tiny
    from repro.data.pipeline import TokenPipeline
    cfg = tiny(get_arch("qwen3-1.7b"))
    shape = ShapeConfig("t", 16, 4, "train")
    p1 = TokenPipeline(cfg, shape, seed=5)
    batches = [p1.next_batch() for _ in range(5)]
    snap = p1.snapshot()
    more = [p1.next_batch() for _ in range(3)]
    p2 = TokenPipeline(cfg, shape, seed=5)
    p2.restore(snap)
    for want in more:
        got = p2.next_batch()
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
    # different cursors differ
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])


def test_adamw_matches_closed_form():
    """Single-param AdamW step vs hand-computed reference."""
    from repro.training.optimizer import AdamWConfig, adamw
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=1)
    opt = adamw(cfg)
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.5])}
    st = opt.init(p)
    newp, st = opt.update(g, st, p, jnp.int32(0))
    mu = 0.1 * 0.5
    nu = 0.01 * 0.25
    mhat = mu / (1 - 0.9)
    vhat = nu / (1 - 0.99)
    want = 2.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    assert float(newp["w"][0]) == pytest.approx(want, rel=1e-5)


def test_adafactor_reduces_loss(tmp_path):
    from repro.training.optimizer import adafactor
    from repro.configs.registry import get_arch, tiny
    from repro.models.model import build_model
    from repro.training.train_loop import make_train_step
    cfg = tiny(get_arch("qwen3-1.7b"))
    model = build_model(cfg)
    opt = adafactor()
    step = jax.jit(make_train_step(model, cfg, opt))
    params = model.init(jax.random.PRNGKey(0))
    st = opt.init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                cfg.vocab)
    batch = {"tokens": tokens}
    losses = []
    for i in range(12):
        params, st, m = step(params, st, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses[-1])


def test_gradient_compression_error_feedback():
    """bf16 + error feedback: compressed psum converges to the true mean
    over steps (residual is carried, not lost)."""
    from repro.training.train_loop import make_compressed_psum_grads
    f = make_compressed_psum_grads("pod")
    g = {"w": jnp.array([1e-3 + 1e-6])}   # below bf16 resolution near 1e-3
    err = {"w": jnp.zeros_like(g["w"])}

    def body(g, err):
        return f(g, err)

    wrapped = jax.jit(lambda g, e: jax.vmap(
        lambda gg, ee: body(gg, ee), axis_name="pod")(g, e))
    gs = jax.tree.map(lambda a: jnp.stack([a, a]), g)
    es = jax.tree.map(lambda a: jnp.stack([a, a]), err)
    total = 0.0
    for _ in range(50):
        (red, es) = wrapped(gs, es)
        total += float(red["w"][0, 0])
    # accumulated compressed sum ≈ accumulated true sum (error feedback)
    assert total == pytest.approx(50 * (1e-3 + 1e-6), rel=1e-3)


def test_straggler_detection(tmp_path):
    out = run_training(arch="tiny:qwen3-1.7b", steps=3, ckpt_every=3,
                       ckpt_dir=str(tmp_path), global_batch=4, seq_len=32,
                       step_deadline=0.0)   # everything is a straggler
    assert len(out["stragglers"]) == 3
