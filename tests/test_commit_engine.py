"""Plan/commit engine vs the sequential-scan oracle.

The parallel engine must be *bit-identical* to the scan path: same state
arrays (including node-id allocation order), same per-op results, same
flush/fence accounting — under duplicate keys, same-bucket conflicts,
resurrection, and interleaved insert/delete batches.  CommitStats
additionally reports the coalesced batch cost, which must follow the
2 × max-same-bucket-group law.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as B

NB = 16   # few buckets → heavy same-bucket conflict groups


def assert_states_equal(a: B.HashMapState, b: B.HashMapState, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f} diverged from oracle")


def test_insert_parallel_matches_oracle_duplicates_and_conflicts():
    rng = np.random.default_rng(1)
    for trial in range(5):
        st_o = B.make_state(2048, NB)
        st_p = B.make_state(2048, NB)
        for rnd in range(5):
            # keys drawn from a tiny range: duplicate keys inside the
            # batch plus guaranteed same-bucket collisions across keys
            ks = jnp.asarray(rng.integers(0, 40, size=48))
            vs = jnp.asarray(rng.integers(0, 1000, size=48))
            st_o, ok_o = B.insert(st_o, ks, vs, NB)
            st_p, ok_p, stats = B.insert_parallel(st_p, ks, vs, NB)
            np.testing.assert_array_equal(np.asarray(ok_o),
                                          np.asarray(ok_p))
            assert_states_equal(st_o, st_p, f"trial {trial} round {rnd}")
            assert int(stats.coalesced_fences) == 2 * int(stats.max_group)


def test_interleaved_insert_delete_resurrect_matches_oracle():
    rng = np.random.default_rng(7)
    st_o = B.make_state(4096, NB)
    st_p = B.make_state(4096, NB)
    for rnd in range(12):
        ks = jnp.asarray(rng.integers(0, 60, size=32))
        if rng.random() < 0.5:
            vs = jnp.asarray(rng.integers(0, 1000, size=32))
            st_o, ok_o = B.insert(st_o, ks, vs, NB)
            st_p, ok_p, _ = B.insert_parallel(st_p, ks, vs, NB)
        else:
            st_o, ok_o = B.delete(st_o, ks, NB)
            st_p, ok_p, _ = B.delete_parallel(st_p, ks, NB)
        np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p))
        assert_states_equal(st_o, st_p, f"round {rnd}")
    # fence/flush accounting tracked the oracle the whole way
    assert int(st_o.fences) == int(st_p.fences)
    assert int(st_o.flushes) == int(st_p.flushes)


def test_accounting_identical_and_coalesced_law():
    """Per-op accounting is oracle-identical; the coalesced batch cost is
    2 fences per commit *round* (one op per bucket per round)."""
    st = B.make_state(2048, NB)
    ks = jnp.arange(1, 101)
    st_o, _ = B.insert(st, ks, ks, NB)
    st_p, ok, stats = B.insert_parallel(st, ks, ks, NB)
    assert int(st_p.flushes) == int(st_o.flushes) == 200
    assert int(st_p.fences) == int(st_o.fences) == 200
    counts = np.zeros(NB, np.int64)
    for k in np.asarray(ks):
        counts[int(B.bucket_of(jnp.int32(k), NB))] += 1
    assert int(stats.max_group) == counts.max()
    assert int(stats.coalesced_fences) == 2 * counts.max()
    assert int(stats.coalesced_flushes) == int(st_p.flushes) - int(st.flushes)
    assert int(stats.ops_committed) == 100
    assert int(stats.conflict_groups) == (counts > 0).sum()


def test_lookup_after_parallel_commit():
    st = B.make_state(1024, NB)
    ks = jnp.arange(100, 200)
    st, ok, _ = B.insert_parallel(st, ks, ks * 3, NB)
    assert bool(ok.all())
    found, vals = B.lookup(st, ks, NB)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ks) * 3)
    st, okd, _ = B.delete_parallel(st, jnp.array([100, 100, 999]), NB)
    assert list(np.asarray(okd)) == [True, False, False]
    found, _ = B.lookup(st, jnp.array([100]), NB)
    assert not bool(found[0])


def test_crash_replay_prefix_durability_parallel():
    """Linearization order is batch order for both engines, so a crash
    after op p durably commits exactly the batch prefix [:p]; replaying
    that prefix through either engine reproduces the recovered state."""
    rng = np.random.default_rng(0)
    ks = jnp.asarray(rng.permutation(np.arange(1, 65)))
    vs = ks * 7
    full, _, _ = B.insert_parallel(B.make_state(512, NB), ks, vs, NB)
    for p in (0, 1, 17, 63, 64):
        replay_scan, _ = B.insert(B.make_state(512, NB), ks[:p], vs[:p], NB)
        replay_par, _, _ = B.insert_parallel(
            B.make_state(512, NB), ks[:p], vs[:p], NB)
        assert_states_equal(replay_scan, replay_par, f"prefix {p}")
        found, _ = B.lookup(replay_par, ks, NB)
        assert int(found.sum()) == p
        assert bool(found[:p].all()) if p else True


def test_insert_parallel_fails_cleanly_on_pool_exhaustion():
    """Fresh inserts past the node pool fail (ok=False) without touching
    state — no dangling head pointers, resurrects still work at full."""
    st = B.make_state(4, 2)                  # ids 1..3 usable
    st, ok, _ = B.insert_parallel(st, jnp.arange(1, 7), jnp.arange(1, 7), 2)
    assert list(np.asarray(ok)) == [True] * 3 + [False] * 3
    assert int(st.cursor) == 4
    found, vals = B.lookup(st, jnp.arange(1, 7), 2)
    assert list(np.asarray(found)) == [True] * 3 + [False] * 3
    np.testing.assert_array_equal(np.asarray(vals)[:3], [1, 2, 3])
    st, okd, _ = B.delete_parallel(st, jnp.array([2]), 2)
    assert bool(okd[0])
    st, okr, _ = B.insert_parallel(st, jnp.array([2, 9]),
                                   jnp.array([42, 1]), 2)
    assert list(np.asarray(okr)) == [True, False]   # resurrect fits, fresh not
    _, v = B.lookup(st, jnp.array([2]), 2)
    assert int(v[0]) == 42


def test_membership_index_grows_past_initial_capacity():
    """The durable-map membership index (serving dedup / manifest index)
    must never drop members: the pool doubles before a batch that would
    not fit."""
    from repro.persistence.index import MembershipIndex
    idx = MembershipIndex(capacity=8)
    keys = list(range(100, 180))             # 80 members through an 8-pool
    for i in range(0, len(keys), 16):
        idx.add(keys[i:i + 16])
    assert idx.capacity >= 81
    assert bool(idx.contains(keys).all())
    assert not bool(idx.contains([5, 999]).any())


def test_membership_index_out_of_range_keys_fall_back():
    """Keys outside the int32 map space (stray step numbers, oob rids)
    go to a Python-set side table instead of wrapping or raising."""
    from repro.persistence.index import MembershipIndex
    idx = MembershipIndex(capacity=8)
    idx.add([5, 2**40, -3])
    assert list(idx.contains([5, 2**40, -3, 2**41, 6])) == \
        [True, True, True, False, False]


def test_plan_phase_does_no_persistence_work():
    """The journey: planning a batch reads no fence/flush state and the
    failed ops of a commit add nothing to the accounting."""
    st = B.make_state(512, NB)
    st, _, _ = B.insert_parallel(st, jnp.arange(1, 21), jnp.arange(1, 21),
                                 NB)
    f0, n0 = int(st.flushes), int(st.fences)
    # all-duplicate batch: every op fails, accounting must not move
    st2, ok, stats = B.insert_parallel(st, jnp.arange(1, 21),
                                       jnp.zeros(20, jnp.int32), NB)
    assert not bool(ok.any())
    assert int(st2.flushes) == f0 and int(st2.fences) == n0
    assert int(stats.coalesced_fences) == 0
    B.lookup(st2, jnp.arange(1, 41), NB)
    assert int(st2.flushes) == f0 and int(st2.fences) == n0
