"""Plan/commit engine vs the sequential-scan oracle.

The parallel engine must be *bit-identical* to the scan path: same state
arrays (including node-id allocation order), same per-op results, same
flush/fence accounting — under duplicate keys, same-bucket conflicts,
resurrection, and interleaved insert/delete batches.  CommitStats
additionally reports the coalesced batch cost, which must follow the
2 × max-same-bucket-group law.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as B

NB = 16   # few buckets → heavy same-bucket conflict groups


def assert_states_equal(a: B.HashMapState, b: B.HashMapState, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f} diverged from oracle")


def test_insert_parallel_matches_oracle_duplicates_and_conflicts():
    rng = np.random.default_rng(1)
    for trial in range(5):
        st_o = B.make_state(2048, NB)
        st_p = B.make_state(2048, NB)
        for rnd in range(5):
            # keys drawn from a tiny range: duplicate keys inside the
            # batch plus guaranteed same-bucket collisions across keys
            ks = jnp.asarray(rng.integers(0, 40, size=48))
            vs = jnp.asarray(rng.integers(0, 1000, size=48))
            st_o, ok_o = B.insert(st_o, ks, vs, NB)
            st_p, ok_p, stats = B.insert_parallel(st_p, ks, vs, NB)
            np.testing.assert_array_equal(np.asarray(ok_o),
                                          np.asarray(ok_p))
            assert_states_equal(st_o, st_p, f"trial {trial} round {rnd}")
            assert int(stats.coalesced_fences) == 2 * int(stats.max_group)


def test_interleaved_insert_delete_resurrect_matches_oracle():
    rng = np.random.default_rng(7)
    st_o = B.make_state(4096, NB)
    st_p = B.make_state(4096, NB)
    for rnd in range(12):
        ks = jnp.asarray(rng.integers(0, 60, size=32))
        if rng.random() < 0.5:
            vs = jnp.asarray(rng.integers(0, 1000, size=32))
            st_o, ok_o = B.insert(st_o, ks, vs, NB)
            st_p, ok_p, _ = B.insert_parallel(st_p, ks, vs, NB)
        else:
            st_o, ok_o = B.delete(st_o, ks, NB)
            st_p, ok_p, _ = B.delete_parallel(st_p, ks, NB)
        np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p))
        assert_states_equal(st_o, st_p, f"round {rnd}")
    # fence/flush accounting tracked the oracle the whole way
    assert int(st_o.fences) == int(st_p.fences)
    assert int(st_o.flushes) == int(st_p.flushes)


def test_accounting_identical_and_coalesced_law():
    """Per-op accounting is oracle-identical; the coalesced batch cost is
    2 fences per commit *round* (one op per bucket per round)."""
    st = B.make_state(2048, NB)
    ks = jnp.arange(1, 101)
    st_o, _ = B.insert(st, ks, ks, NB)
    st_p, ok, stats = B.insert_parallel(st, ks, ks, NB)
    assert int(st_p.flushes) == int(st_o.flushes) == 200
    assert int(st_p.fences) == int(st_o.fences) == 200
    counts = np.zeros(NB, np.int64)
    for k in np.asarray(ks):
        counts[int(B.bucket_of(jnp.int32(k), NB))] += 1
    assert int(stats.max_group) == counts.max()
    assert int(stats.coalesced_fences) == 2 * counts.max()
    assert int(stats.coalesced_flushes) == int(st_p.flushes) - int(st.flushes)
    assert int(stats.ops_committed) == 100
    assert int(stats.conflict_groups) == (counts > 0).sum()


def test_lookup_after_parallel_commit():
    st = B.make_state(1024, NB)
    ks = jnp.arange(100, 200)
    st, ok, _ = B.insert_parallel(st, ks, ks * 3, NB)
    assert bool(ok.all())
    found, vals = B.lookup(st, ks, NB)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ks) * 3)
    st, okd, _ = B.delete_parallel(st, jnp.array([100, 100, 999]), NB)
    assert list(np.asarray(okd)) == [True, False, False]
    found, _ = B.lookup(st, jnp.array([100]), NB)
    assert not bool(found[0])


def test_crash_replay_prefix_durability_parallel():
    """Linearization order is batch order for both engines, so a crash
    after op p durably commits exactly the batch prefix [:p]; replaying
    that prefix through either engine reproduces the recovered state."""
    rng = np.random.default_rng(0)
    ks = jnp.asarray(rng.permutation(np.arange(1, 65)))
    vs = ks * 7
    full, _, _ = B.insert_parallel(B.make_state(512, NB), ks, vs, NB)
    for p in (0, 1, 17, 63, 64):
        replay_scan, _ = B.insert(B.make_state(512, NB), ks[:p], vs[:p], NB)
        replay_par, _, _ = B.insert_parallel(
            B.make_state(512, NB), ks[:p], vs[:p], NB)
        assert_states_equal(replay_scan, replay_par, f"prefix {p}")
        found, _ = B.lookup(replay_par, ks, NB)
        assert int(found.sum()) == p
        assert bool(found[:p].all()) if p else True


def test_insert_parallel_fails_cleanly_on_pool_exhaustion():
    """Fresh inserts past the node pool fail (ok=False) without touching
    state — no dangling head pointers, resurrects still work at full."""
    st = B.make_state(4, 2)                  # ids 1..3 usable
    st, ok, _ = B.insert_parallel(st, jnp.arange(1, 7), jnp.arange(1, 7), 2)
    assert list(np.asarray(ok)) == [True] * 3 + [False] * 3
    assert int(st.cursor) == 4
    found, vals = B.lookup(st, jnp.arange(1, 7), 2)
    assert list(np.asarray(found)) == [True] * 3 + [False] * 3
    np.testing.assert_array_equal(np.asarray(vals)[:3], [1, 2, 3])
    st, okd, _ = B.delete_parallel(st, jnp.array([2]), 2)
    assert bool(okd[0])
    st, okr, _ = B.insert_parallel(st, jnp.array([2, 9]),
                                   jnp.array([42, 1]), 2)
    assert list(np.asarray(okr)) == [True, False]   # resurrect fits, fresh not
    _, v = B.lookup(st, jnp.array([2]), 2)
    assert int(v[0]) == 42


def test_membership_index_grows_past_initial_capacity():
    """The durable-map membership index (serving dedup / manifest index)
    must never drop members: the pool doubles before a batch that would
    not fit."""
    from repro.persistence.index import MembershipIndex
    idx = MembershipIndex(capacity=8)
    keys = list(range(100, 180))             # 80 members through an 8-pool
    for i in range(0, len(keys), 16):
        idx.add(keys[i:i + 16])
    assert idx.capacity >= 81
    assert bool(idx.contains(keys).all())
    assert not bool(idx.contains([5, 999]).any())


def test_membership_index_out_of_range_keys_fall_back():
    """Keys outside the int32 map space (stray step numbers, oob rids)
    go to a Python-set side table instead of wrapping or raising."""
    from repro.persistence.index import MembershipIndex
    idx = MembershipIndex(capacity=8)
    idx.add([5, 2**40, -3])
    assert list(idx.contains([5, 2**40, -3, 2**41, 6])) == \
        [True, True, True, False, False]


def test_update_parallel_matches_mixed_oracle():
    """The tentpole law: one mixed insert/delete plan/commit round is
    bit-identical to the sequential mixed oracle — state arrays, per-op
    ok flags, flush/fence accounting — under duplicate keys with
    alternating ops and heavy same-bucket conflicts."""
    rng = np.random.default_rng(3)
    st_o = B.make_state(4096, NB)
    st_p = B.make_state(4096, NB)
    for rnd in range(10):
        # tiny key range: many duplicate keys per batch, ops alternate
        ks = jnp.asarray(rng.integers(0, 25, size=64))
        vs = jnp.asarray(rng.integers(0, 1000, size=64))
        ops = jnp.asarray(rng.integers(0, 2, size=64))
        st_o, ok_o = B.apply(st_o, ops, ks, vs, NB)
        st_p, ok_p, stats = B.update_parallel(st_p, ops, ks, vs, NB)
        np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p),
                                      err_msg=f"round {rnd}")
        assert_states_equal(st_o, st_p, f"round {rnd}")
        assert int(stats.coalesced_fences) == 2 * int(stats.max_group)
    assert int(st_o.fences) == int(st_p.fences)
    assert int(st_o.flushes) == int(st_p.flushes)


def test_mixed_duplicate_alternating_ops_compose():
    """Duplicate keys with alternating ops inside one batch compose on
    the {live, dead} liveness state in batch order: insert succeeds iff
    currently dead/absent, delete iff currently live."""
    I, D = B.OP_INSERT, B.OP_DELETE
    # one absent key: ins, ins(dup), del, del(dup), ins, del
    ops = jnp.asarray([I, I, D, D, I, D])
    ks = jnp.full(6, 11)
    vs = jnp.asarray([1, 2, 3, 4, 5, 6])
    st, ok, stats = B.update_parallel(B.make_state(64, NB), ops, ks, vs, NB)
    assert list(np.asarray(ok)) == [True, False, True, False, True, True]
    found, _ = B.lookup(st, jnp.asarray([11]), NB)
    assert not bool(found[0])                   # last op deleted it
    assert int(st.cursor) == 2                  # exactly one allocation
    # seeded live: delete, insert(resurrect), insert(dup)
    st0, _, _ = B.insert_parallel(B.make_state(64, NB), jnp.asarray([7]),
                                  jnp.asarray([70]), NB)
    ops = jnp.asarray([D, I, I])
    st1, ok, _ = B.update_parallel(st0, ops, jnp.full(3, 7),
                                   jnp.asarray([0, 71, 72]), NB)
    assert list(np.asarray(ok)) == [True, True, False]
    found, vals = B.lookup(st1, jnp.asarray([7]), NB)
    assert bool(found[0]) and int(vals[0]) == 71
    assert int(st1.cursor) == int(st0.cursor)   # resurrect, no allocation
    # oracle agreement on both scenarios
    st_o, ok_o = B.apply(st0, ops, jnp.full(3, 7),
                         jnp.asarray([0, 71, 72]), NB)
    assert_states_equal(st_o, st1, "seeded-live")
    assert list(np.asarray(ok_o)) == list(np.asarray(ok))


def test_mixed_crash_replay_prefix_durability():
    """Linearization order is batch order for the mixed engine too: a
    crash after op p durably commits exactly the batch prefix [:p];
    replaying that prefix through either mixed engine reproduces the
    recovered state."""
    rng = np.random.default_rng(5)
    n = 64
    ks = jnp.asarray(rng.integers(1, 30, size=n))
    vs = jnp.asarray(rng.integers(0, 1000, size=n))
    ops = jnp.asarray(rng.integers(0, 2, size=n))
    for p in (0, 1, 13, 40, n):
        replay_scan, _ = B.apply(B.make_state(512, NB), ops[:p], ks[:p],
                                 vs[:p], NB)
        replay_par, _, _ = B.update_parallel(B.make_state(512, NB),
                                             ops[:p], ks[:p], vs[:p], NB)
        assert_states_equal(replay_scan, replay_par, f"prefix {p}")


def test_update_parallel_capacity_failure_kills_group():
    """A fresh insert that does not fit fails its whole duplicate-key
    group — exactly what re-running each op against the still-exhausted
    pool would do — and the oracle agrees."""
    I, D = B.OP_INSERT, B.OP_DELETE
    # pool of 3 usable ids; keys 5,6,7 alloc them, key 8's group starves
    ops = jnp.asarray([I, D, I] * 4)
    ks = jnp.asarray([5] * 3 + [6] * 3 + [7] * 3 + [8] * 3)
    vs = jnp.arange(12)
    st_o, ok_o = B.apply(B.make_state(4, 2), ops, ks, vs, 2)
    st_p, ok_p, _ = B.update_parallel(B.make_state(4, 2), ops, ks, vs, 2)
    np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p))
    assert_states_equal(st_o, st_p, "exhausted")
    assert list(np.asarray(ok_p))[9:] == [False] * 3   # whole group failed
    assert int(st_p.cursor) == 4


@pytest.mark.slow
def test_update_parallel_20k_mixed_oracle_identical():
    """Acceptance-scale check: a randomized 20k-op mixed batch with
    duplicate keys is bit-identical between update_parallel and the
    sequential mixed oracle (state, ok flags, flush/fence accounting)."""
    rng = np.random.default_rng(11)
    NB_BIG = 1024
    n = 20_000
    st0 = B.make_state(1 << 16, NB_BIG)
    ks = jnp.asarray(rng.integers(1, 8_000, size=n))   # dup-heavy
    vs = jnp.asarray(rng.integers(0, 1 << 20, size=n))
    ops = jnp.asarray(rng.integers(0, 2, size=n))
    st_o, ok_o = B.apply(st0, ops, ks, vs, NB_BIG)
    st_p, ok_p, stats = B.update_parallel(st0, ops, ks, vs, NB_BIG)
    np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p))
    assert_states_equal(st_o, st_p, "20k mixed")
    assert int(stats.coalesced_fences) == 2 * int(stats.max_group)


def test_membership_index_mixed_update_and_remove():
    """The index's mixed round: adds and removes commit in one batch,
    a removed key re-added resurrects its node (no fresh allocation),
    and a key named in both sides leaves (remove wins)."""
    from repro.persistence.index import MembershipIndex
    idx = MembershipIndex(capacity=64)
    idx.add(range(10, 20))
    cursor0 = int(idx.state.cursor)
    idx.update(add_keys=[20, 21], remove_keys=[10, 11, 20])
    assert list(idx.contains([10, 11, 20, 21, 12])) == \
        [False, False, False, True, True]
    idx.add([10])                            # resurrects the dead node
    assert bool(idx.contains([10])[0])
    assert int(idx.state.cursor) == cursor0 + 2   # only 20, 21 allocated
    # out-of-range keys ride the same mixed round via the side table
    idx.update(add_keys=[2**40], remove_keys=[2**41])
    idx.update(remove_keys=[2**40])
    assert not idx.contains([2**40])[0]


def test_update_parallel_valid_mask_transparent():
    """Invalid (padding) ops are fully transparent: running the full
    batch with a mask is *bit-identical* to running only the valid
    subset — state arrays, accounting, and the valid ops' ok flags —
    even with pads interleaved mid-way through duplicate-key groups
    (the sharded layer's all-to-all padding relies on this)."""
    rng = np.random.default_rng(9)
    for trial in range(4):
        n = 64
        ops = jnp.asarray(rng.integers(0, 2, size=n))
        ks = jnp.asarray(rng.integers(0, 20, size=n))   # dup-heavy
        vs = jnp.asarray(rng.integers(0, 1000, size=n))
        valid = jnp.asarray(rng.random(n) < 0.6)
        st_m, ok_m, stats_m = B.update_parallel(
            B.make_state(512, NB), ops, ks, vs, NB, valid=valid)
        sub = np.flatnonzero(np.asarray(valid))
        st_s, ok_s, _ = B.update_parallel(
            B.make_state(512, NB), ops[sub], ks[sub], vs[sub], NB)
        assert_states_equal(st_m, st_s, f"trial {trial}")
        np.testing.assert_array_equal(np.asarray(ok_m)[sub],
                                      np.asarray(ok_s))
        assert not bool(np.asarray(ok_m)[np.asarray(~valid)].any())
        assert int(stats_m.coalesced_fences) == 2 * int(stats_m.max_group)


def test_update_parallel_all_invalid_is_noop():
    st0, _, _ = B.insert_parallel(B.make_state(64, NB), jnp.arange(1, 9),
                                  jnp.arange(1, 9), NB)
    st, ok, stats = B.update_parallel(
        st0, jnp.zeros(16, jnp.int32), jnp.arange(1, 17),
        jnp.arange(1, 17), NB, valid=jnp.zeros(16, jnp.bool_))
    assert not bool(ok.any())
    assert_states_equal(st, st0, "all-invalid")
    assert int(stats.ops_committed) == 0
    assert int(stats.coalesced_fences) == 0


def test_valid_mask_mid_group_pad_does_not_resurrect():
    """A pad shaped like an insert sitting *between* a real delete and a
    real insert of the same key must not leak into the liveness
    composition (an unmasked insert there would make the later real
    insert fail)."""
    I, D = B.OP_INSERT, B.OP_DELETE
    st0, _, _ = B.insert_parallel(B.make_state(64, NB), jnp.asarray([5]),
                                  jnp.asarray([50]), NB)
    ops = jnp.asarray([D, I, I])
    ks = jnp.full(3, 5)
    vs = jnp.asarray([0, 999, 51])
    valid = jnp.asarray([True, False, True])
    st, ok, _ = B.update_parallel(st0, ops, ks, vs, NB, valid=valid)
    assert list(np.asarray(ok)) == [True, False, True]
    found, vals = B.lookup(st, jnp.asarray([5]), NB)
    assert bool(found[0]) and int(vals[0]) == 51   # not the pad's 999
    # oracle agreement on the valid subset
    st_o, ok_o = B.apply(st0, ops[jnp.asarray([0, 2])],
                         ks[jnp.asarray([0, 2])],
                         vs[jnp.asarray([0, 2])], NB)
    assert_states_equal(st_o, st, "mid-group pad")


def test_commit_stats_bucket_flushes():
    """bucket_flushes is the per-bucket breakdown of the flush
    accounting: sums to coalesced_flushes, nonzero exactly on the
    buckets of committing ops (2 per fresh insert, 1 per
    resurrect/delete), zero for failed ops."""
    st = B.make_state(512, NB)
    ks = jnp.arange(1, 41)
    st, _, stats = B.insert_parallel(st, ks, ks, NB)
    bf = np.asarray(stats.bucket_flushes)
    assert bf.sum() == int(stats.coalesced_flushes) == 80
    counts = np.zeros(NB, np.int64)
    for k in np.asarray(ks):
        counts[int(B.bucket_of(jnp.int32(k), NB))] += 2   # fresh: 2 each
    np.testing.assert_array_equal(bf, counts)
    # resurrect/delete flush 1 each, into the key's own bucket only
    st, _, stats_d = B.delete_parallel(st, ks[:4], NB)
    bf_d = np.asarray(stats_d.bucket_flushes)
    assert bf_d.sum() == 4
    for k in np.asarray(ks[:4]):
        assert bf_d[int(B.bucket_of(jnp.int32(k), NB))] >= 1
    # failed ops contribute nothing anywhere
    _, ok, stats_f = B.insert_parallel(st, ks[4:8], ks[4:8], NB)
    assert not bool(ok.any())
    assert np.asarray(stats_f.bucket_flushes).sum() == 0


def test_nil_sentinel_never_aliases_a_node():
    """Regression for the link-sentinel ambiguity: ``make_state`` used
    to zero-initialize ``nxt``/``head``, making "empty link" and "node
    index 0" the same value.  Links now end at the explicit ``NIL`` and
    no chain, on either engine, may ever link *to* slot 0 (the reserved
    never-allocated slot) — chain-walking code (the migration engine's
    bucket drains) depends on the distinction."""
    assert int(B.NIL) == -1
    st = B.make_state(64, NB)
    assert (np.asarray(st.nxt) == int(B.NIL)).all()
    assert (np.asarray(st.head) == int(B.NIL)).all()
    rng = np.random.default_rng(13)
    st_o, st_p = B.make_state(512, NB), B.make_state(512, NB)
    for _ in range(6):
        ops = jnp.asarray(rng.integers(0, 2, size=40))
        ks = jnp.asarray(rng.integers(0, 30, size=40))
        vs = jnp.asarray(rng.integers(0, 1000, size=40))
        st_o, _ = B.apply(st_o, ops, ks, vs, NB)
        st_p, _, _ = B.update_parallel(st_p, ops, ks, vs, NB)
    for st in (st_o, st_p):
        nxt, head, cur = (np.asarray(st.nxt), np.asarray(st.head),
                          int(st.cursor))
        assert (nxt[1:cur] != 0).all(), "a chain links to reserved slot 0"
        assert (head != 0).all(), "a bucket head points at slot 0"
        # every chain terminates at NIL within the pool
        for b in range(NB):
            node, steps = int(head[b]), 0
            while node != int(B.NIL):
                node = int(nxt[node])
                steps += 1
                assert steps <= cur, "cycle / runaway chain"
    assert_states_equal(st_o, st_p, "nil-sentinel rounds")


def test_key_zero_roundtrips_on_both_engines():
    """Key 0 was the canary for the 0-as-null scheme (a chain end looked
    like a node whose key is 0).  With the NIL sentinel it is an
    ordinary key: insert, lookup, delete, resurrect — oracle-identical."""
    ks = jnp.asarray([0, 5, 0, 13])
    vs = jnp.asarray([10, 50, 11, 130])
    st_o, ok_o = B.insert(B.make_state(64, 2), ks, vs, 2)
    st_p, ok_p, _ = B.insert_parallel(B.make_state(64, 2), ks, vs, 2)
    assert list(np.asarray(ok_o)) == [True, True, False, True]
    np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p))
    assert_states_equal(st_o, st_p, "key 0")
    f, v = B.lookup(st_p, jnp.asarray([0]), 2)
    assert bool(f[0]) and int(v[0]) == 10
    st_p, okd, _ = B.delete_parallel(st_p, jnp.asarray([0]), 2)
    assert bool(okd[0])
    f, _ = B.lookup(st_p, jnp.asarray([0]), 2)
    assert not bool(f[0])
    st_p, okr, _ = B.insert_parallel(st_p, jnp.asarray([0]),
                                     jnp.asarray([77]), 2)
    assert bool(okr[0])
    f, v = B.lookup(st_p, jnp.asarray([0]), 2)
    assert bool(f[0]) and int(v[0]) == 77
    # and the migration drain carries key 0 like any other
    from repro.core.migrate import migrate_state
    new, _ = migrate_state(st_p, 2, 64, 4)
    f, v = B.lookup(new, jnp.asarray([0]), 4)
    assert bool(f[0]) and int(v[0]) == 77


def test_plan_phase_does_no_persistence_work():
    """The journey: planning a batch reads no fence/flush state and the
    failed ops of a commit add nothing to the accounting."""
    st = B.make_state(512, NB)
    st, _, _ = B.insert_parallel(st, jnp.arange(1, 21), jnp.arange(1, 21),
                                 NB)
    f0, n0 = int(st.flushes), int(st.fences)
    # all-duplicate batch: every op fails, accounting must not move
    st2, ok, stats = B.insert_parallel(st, jnp.arange(1, 21),
                                       jnp.zeros(20, jnp.int32), NB)
    assert not bool(ok.any())
    assert int(st2.flushes) == f0 and int(st2.fences) == n0
    assert int(stats.coalesced_fences) == 0
    B.lookup(st2, jnp.arange(1, 41), NB)
    assert int(st2.flushes) == f0 and int(st2.fences) == n0
