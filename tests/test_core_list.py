"""Core reproduction tests: PMem semantics + Harris list + checkers."""
import numpy as np
import pytest

from repro.core.harris_list import HarrisList
from repro.core.instr import TraversalWriteError, pack, unpack, is_marked
from repro.core.linearizability import (check_durably_linearizable,
                                        check_linearizable, explain_failure)
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.scheduler import Interleaver
from repro.core.traversal import run_operation


# --------------------------------------------------------------------- #
# PMem semantics                                                         #
# --------------------------------------------------------------------- #
def test_pmem_flush_fence_persists():
    m = PMem(64, line_words=8)
    m.write(3, 42)
    assert m.persistent[3] == 0
    m.flush(3)
    assert m.persistent[3] == 0          # flush alone is not persistence
    m.fence()
    assert m.persistent[3] == 42
    assert m.counters.flushes == 1 and m.counters.fences == 1


def test_pmem_crash_loses_unflushed():
    m = PMem(64, line_words=8)
    m.write(3, 42)
    m.crash(evict="none")
    assert m.volatile[3] == 0 and m.persistent[3] == 0


def test_pmem_crash_eviction_subset():
    m = PMem(64, line_words=8)
    m.write(1, 11)    # line 0
    m.write(9, 99)    # line 1
    m.crash(evict=[1])                   # only line 1 evicted
    assert m.persistent[9] == 99 and m.persistent[1] == 0
    assert m.volatile[1] == 0            # cache reloaded from NVRAM


def test_pmem_fence_only_persists_flushed_lines():
    m = PMem(64, line_words=8)
    m.write(1, 11)
    m.write(9, 99)
    m.flush(9)
    m.fence()
    assert m.persistent[9] == 99 and m.persistent[1] == 0


def test_pack_unpack_mark():
    w = pack(88, 0)
    assert unpack(w) == (88, 0) and not is_marked(w)
    assert is_marked(w | 1)


# --------------------------------------------------------------------- #
# Harris list: sequential correctness under all three policies           #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("policy_name", ["volatile", "izraelevitz", "nvtraverse"])
def test_list_sequential_vs_model(policy_name):
    rng = np.random.default_rng(0)
    mem = PMem(1 << 16)
    ds = HarrisList(mem)
    policy = get_policy(policy_name)
    model = {}
    for _ in range(400):
        op = rng.choice(["insert", "delete", "find"])
        k = int(rng.integers(0, 40))
        if op == "insert":
            got = run_operation(ds, policy, "insert", (k, k * 10))
            want = k not in model
            model[k] = k * 10
        elif op == "delete":
            got = run_operation(ds, policy, "delete", (k,))
            want = k in model
            model.pop(k, None)
        else:
            got = run_operation(ds, policy, "find", (k,))
            want = k in model
        assert got == want, (op, k)
        assert ds.contents() == model
    ds.check_integrity()


def test_traverse_may_not_write():
    mem = PMem(1 << 12)
    ds = HarrisList(mem)

    class Evil(HarrisList):
        pass

    evil = Evil.__new__(Evil)
    evil.__dict__.update(ds.__dict__)

    def bad_traverse(ctx, entry, op, args):
        ctx.write(entry + 1, 7)

    evil.traverse = bad_traverse
    with pytest.raises(TraversalWriteError):
        run_operation(evil, get_policy("nvtraverse"), "find", (1,))


# --------------------------------------------------------------------- #
# flush/fence economy — the paper's core claim                           #
# --------------------------------------------------------------------- #
def _fill(ds, policy, keys):
    for k in keys:
        run_operation(ds, policy, "insert", (k, k))


def test_nvtraverse_zero_persistence_in_traverse():
    mem = PMem(1 << 16)
    ds = HarrisList(mem)
    pol = get_policy("nvtraverse")
    _fill(ds, pol, range(0, 200, 2))
    mem.counters.reset()
    for k in range(1, 100, 7):
        run_operation(ds, pol, "find", (k,))
        run_operation(ds, pol, "insert", (k, k))
        run_operation(ds, pol, "delete", (k,))
    assert mem.counters.traverse_flushes == 0
    assert mem.counters.traverse_fences == 0


def test_nvtraverse_constant_fences_izraelevitz_linear():
    """NVTraverse: O(1) fences/op regardless of size; Izraelevitz: O(path)."""
    results = {}
    for size in (64, 512):
        for name in ("nvtraverse", "izraelevitz"):
            mem = PMem(1 << 18)
            ds = HarrisList(mem)
            pol = get_policy(name)
            _fill(ds, get_policy("nvtraverse"), range(size))
            mem.counters.reset()
            n_ops = 50
            for k in range(n_ops):
                run_operation(ds, pol, "find", (int(k * size / n_ops),))
            results[(name, size)] = mem.counters.fences / n_ops
    # NVTraverse find: exactly 2 fences (makePersistent + before-return)
    assert results[("nvtraverse", 64)] <= 3
    assert results[("nvtraverse", 512)] <= 3
    # size-independent for NVTraverse ...
    assert results[("nvtraverse", 512)] == results[("nvtraverse", 64)]
    # ... but grows ~8x for Izraelevitz when the list grows 8x
    ratio = results[("izraelevitz", 512)] / results[("izraelevitz", 64)]
    assert ratio > 4.0
    # and the headline gap: >25x fewer fences at size 512 (paper: 13.5-39.6x)
    assert results[("izraelevitz", 512)] / results[("nvtraverse", 512)] > 25


# --------------------------------------------------------------------- #
# concurrent linearizability (no crash)                                  #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(5))
def test_list_concurrent_linearizable(seed):
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 16)
    ds = HarrisList(mem)
    pol = get_policy("nvtraverse")
    init_keys = list(range(0, 20, 2))
    _fill(ds, pol, init_keys)
    ops = []
    for _ in range(24):
        op = rng.choice(["insert", "delete", "find"])
        k = int(rng.integers(0, 20))
        ops.append((op, (k, k) if op == "insert" else (k,)))
    recs = Interleaver(ds, pol, ops, seed=seed).run()
    assert all(r.completed for r in recs)
    ds.check_integrity()
    assert check_linearizable(recs, initial_keys=init_keys), \
        explain_failure(recs, ds.contents().keys(), init_keys)


# --------------------------------------------------------------------- #
# durable linearizability under crash + recovery (Theorem 4.2)           #
# --------------------------------------------------------------------- #
def _crash_trial(policy_name, seed, crash_at, evict, p_evict=0.5):
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 16, seed=seed)
    ds = HarrisList(mem)
    pol = get_policy(policy_name)
    init_keys = list(range(0, 20, 2))
    _fill(ds, get_policy("nvtraverse"), init_keys)
    mem.persist_all()
    ops = []
    for _ in range(20):
        op = rng.choice(["insert", "delete", "find"])
        k = int(rng.integers(0, 20))
        ops.append((op, (k, k) if op == "insert" else (k,)))
    il = Interleaver(ds, pol, ops, seed=seed)
    recs = il.run(crash_at=crash_at, evict=evict, p_evict=p_evict)
    if not il.crashed:   # schedule finished before the crash point
        return None
    ds.disconnect()      # recovery = Supplement 1 (§4 "Recovery")
    ds.check_integrity(require_unmarked=True)
    recovered = set(ds.contents().keys())
    ok = check_durably_linearizable(recs, recovered, initial_keys=init_keys)
    return ok, recs, recovered, init_keys


@pytest.mark.parametrize("evict", ["none", "all", "random"])
@pytest.mark.parametrize("seed", range(4))
def test_nvtraverse_durably_linearizable(seed, evict):
    for crash_at in (5, 25, 60, 120, 250):
        out = _crash_trial("nvtraverse", seed, crash_at, evict)
        if out is None:
            continue
        ok, recs, recovered, init_keys = out
        assert ok, explain_failure(recs, recovered, init_keys)


@pytest.mark.parametrize("seed", range(2))
def test_izraelevitz_durably_linearizable(seed):
    for crash_at in (10, 80, 300):
        out = _crash_trial("izraelevitz", seed, crash_at, "random")
        if out is None:
            continue
        ok, recs, recovered, init_keys = out
        assert ok, explain_failure(recs, recovered, init_keys)


def test_volatile_policy_is_not_durable():
    """Sanity for the checker: with no flushes at all, completed updates are
    lost on crash (evict=none) — the checker must catch at least one such
    violation across the sweep."""
    violations = 0
    trials = 0
    for seed in range(6):
        for crash_at in (40, 80, 160, 320):
            out = _crash_trial("volatile", seed, crash_at, "none")
            if out is None:
                continue
            trials += 1
            if not out[0]:
                violations += 1
    assert trials > 0
    assert violations > 0, "checker failed to catch volatile-policy data loss"


@pytest.mark.parametrize("evict", ["none", "random"])
def test_list_supplement2_original_parent_variant(evict):
    """The Supplement 2 path (ensureReachable flushes the location stored
    in the node's original-parent field instead of the Lemma 4.1 returned
    parent) must be equally durable."""
    for seed in range(3):
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 16, seed=seed)
        ds = HarrisList(mem, use_orig_parent=True)
        pol = get_policy("nvtraverse")
        init_keys = list(range(0, 12, 2))
        for k in init_keys:
            run_operation(ds, pol, "insert", (k, k))
        mem.persist_all()
        ops = []
        for _ in range(14):
            op = rng.choice(["insert", "delete", "find"])
            k = int(rng.integers(0, 12))
            ops.append((op, (k, k) if op == "insert" else (k,)))
        il = Interleaver(ds, pol, ops, seed=seed)
        recs = il.run(crash_at=40, evict=evict)
        if not il.crashed:
            continue
        ds.disconnect()
        ds.check_integrity(require_unmarked=True)
        assert check_durably_linearizable(
            recs, set(ds.contents()), initial_keys=init_keys), \
            explain_failure(recs, set(ds.contents()), init_keys)
