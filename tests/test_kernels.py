"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.nvt_probe.ops import nvt_probe
from repro.kernels.nvt_probe.ref import tiles_from_hashmap
from repro.kernels.ssd_scan.ops import ssd_scan


# --------------------------------------------------------------------- #
# flash attention                                                        #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,K,dh,bq,bk", [
    (1, 128, 128, 2, 2, 64, 64, 64),      # MHA square
    (2, 256, 256, 4, 2, 64, 128, 64),     # GQA 2:1
    (1, 256, 256, 8, 2, 32, 64, 128),     # GQA 4:1, small head
    (2, 64, 192, 2, 1, 128, 64, 64),      # rectangular, MQA
])
def test_flash_attention_sweep(B, Sq, Sk, H, K, dh, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, dh), dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for causal in (True, False):
        out = flash_attention(q, k, v, causal=causal, impl="pallas",
                              interpret=True, block_q=bq, block_k=bk)
        ref = flash_attention(q, k, v, causal=causal, impl="xla")
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 4, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          impl="pallas", interpret=True,
                          block_q=64, block_k=64)
    ref = flash_attention(q, k, v, causal=True, window=window, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model's attention_scores path."""
    from repro.models.layers import attention_scores, causal_mask
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, K, dh = 2, 128, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, impl="pallas",
                          interpret=True, block_q=64, block_k=64)
    ref = attention_scores(q, k, v, causal_mask(S, S, 0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------- #
# SSD scan                                                               #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 96, 2, 64, 32, 32),     # padded final chunk (96 = 3*32)
    (2, 80, 2, 16, 16, 32),     # uneven: pad path
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, N)) * 0.5).astype(dtype)
    out = ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk, impl="pallas",
                   interpret=True)
    ref = ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk, impl="xla")
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_kernel_matches_model_block():
    """Kernel == the model's chunked SSD == sequential recurrence."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, H, P, N = 2, 128, 4, 32, 16
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    out = ssd_scan(xh, dt, A, Bm, Cm, chunk=32, impl="pallas",
                   interpret=True)
    ref, _ = ssd_chunked(xh, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------- #
# NVTraverse probe                                                       #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("NB,cap,nq", [(64, 16, 128), (256, 32, 256),
                                       (16, 8, 64)])
def test_nvt_probe_sweep(NB, cap, nq):
    rng = np.random.default_rng(0)
    keys = rng.choice(np.arange(1, 10_000), size=NB * cap // 2,
                      replace=False).astype(np.int32)
    from repro.kernels.nvt_probe.ref import mix32_np
    kt = np.zeros((NB, cap), np.int32)
    vt = np.zeros((NB, cap), np.int32)
    slots = np.zeros(NB, np.int32)
    inserted = {}
    for k in keys:
        b = int(mix32_np(k) % np.uint32(NB))
        if slots[b] < cap:
            kt[b, slots[b]] = k
            vt[b, slots[b]] = k * 3
            slots[b] += 1
            inserted[int(k)] = int(k) * 3
    queries = rng.integers(1, 10_000, size=nq).astype(np.int32)
    found, vals = nvt_probe(jnp.asarray(kt), jnp.asarray(vt),
                            jnp.asarray(queries), impl="pallas",
                            interpret=True, block_q=64)
    rf, rv = nvt_probe(jnp.asarray(kt), jnp.asarray(vt),
                       jnp.asarray(queries), impl="xla")
    np.testing.assert_array_equal(np.asarray(found), np.asarray(rf))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
    for i, qk in enumerate(queries):
        assert bool(found[i]) == (int(qk) in inserted)
        if int(qk) in inserted:
            assert int(vals[i]) == inserted[int(qk)]


def test_nvt_probe_streams_table_larger_than_vmem_cap():
    """The second grid dimension streams bucket-tile blocks through VMEM:
    a 4 MB table (> the old 2 MB whole-table-in-VMEM cap) in 8 tiles,
    bit-exact against probe_ref, including a non-divisible tile count
    (padded bucket rows)."""
    from repro.kernels.nvt_probe.ref import tiles_from_keys
    NB, cap = 4096, 256                      # 4096*256*4 B = 4 MB
    assert NB * cap * 4 > 2 * 1024 * 1024
    rng = np.random.default_rng(0)
    keys = rng.choice(np.arange(1, 1 << 20), size=NB * cap // 4,
                      replace=False).astype(np.int32)
    kt, vt = tiles_from_keys(keys, NB, cap, val_mult=5)
    queries = jnp.asarray(
        rng.integers(1, 1 << 20, size=128).astype(np.int32))
    rf, rv = nvt_probe(kt, vt, queries, impl="xla")
    for block_nb in (512, 4096, 3000):       # streamed / single / padded
        f, v = nvt_probe(kt, vt, queries, impl="pallas", interpret=True,
                         block_q=64, block_nb=block_nb)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(rf))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


def test_nvt_probe_cross_checks_chain_hashmap():
    """Kernel on dense tiles == chain walking on the jitted durable map —
    the journey gives identical answers in both layouts."""
    from repro.core import batched as B
    NB = 32
    st = B.make_state(512, NB)
    ks = jnp.arange(1, 101)
    st, _ = B.insert(st, ks, ks * 7, NB)
    st, _ = B.delete(st, jnp.arange(1, 31), NB)
    kt, vt = tiles_from_hashmap(st, NB, cap=32)
    queries = jnp.arange(1, 121)
    found, vals = nvt_probe(kt, vt, queries, impl="pallas",
                            interpret=True, block_q=64)
    cf, cv = B.lookup(st, queries, NB)
    np.testing.assert_array_equal(np.asarray(found, bool), np.asarray(cf))
    np.testing.assert_array_equal(
        np.asarray(vals) * np.asarray(found),
        np.asarray(cv) * np.asarray(cf).astype(np.int32))
