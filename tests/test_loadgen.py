"""LoadScope: windowed telemetry, event timeline, flight recorder,
deterministic load schedules, and the bench-history regression gate.

The windowed-histogram tests pin the properties the load harness leans
on: half-open epoch membership as a pure function of ``t_us``, the
windowed-vs-lifetime consistency invariant (``merged() == lifetime``
when nothing was dropped), and snapshot-merge associativity /
commutativity — including across real shard *subprocesses*, since
that is how a sharded load run's telemetry is reassembled.  The
schedule tests pin determinism (same seed ⇒ bit-identical schedule);
the harness tests run a real closed loop against a ``RequestLog`` in a
tmp dir, including the injected torn-payload crash with its
flight-recorder dump and per-phase restart breakdown.  The
bench-history tests are the acceptance witness for the regression
gate: a seeded synthetic regression must be detected, an equally large
improvement must not fail.
"""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.obs.loadgen import (LoadHarness, LoadSpec, Schedule,
                               make_schedule)
from repro.obs.timeline import (EventTimeline, FlightRecorder,
                                attribute_excursions)
from repro.obs.windows import WindowedCounter, WindowedHistogram


# --------------------------------------------------------------------- #
# windowed telemetry                                                     #
# --------------------------------------------------------------------- #
def test_window_boundary_epoch_semantics():
    """Epoch e covers [e*window_us, (e+1)*window_us) — a sample at
    exactly the boundary opens the *next* window."""
    w = WindowedHistogram(window_us=100.0, lo=1.0, hi=1e4, growth=2.0)
    assert w.epoch_of(0.0) == 0
    assert w.epoch_of(99.999) == 0
    assert w.epoch_of(100.0) == 1
    assert w.epoch_of(250.0) == 2
    w.record(5.0, t_us=99.999)
    w.record(7.0, t_us=100.0)
    assert w.window(0).count == 1 and w.window(1).count == 1
    rows = w.series()
    assert [r["epoch"] for r in rows] == [0, 1]
    assert rows[0]["t_end_us"] == rows[1]["t_start_us"] == 100.0


def test_windowed_vs_lifetime_quantile_consistency():
    """With nothing dropped, the merge of all windows IS the lifetime
    aggregate — same counts, sums and quantiles at every q."""
    w = WindowedHistogram(window_us=50.0, lo=1.0, hi=1e5, growth=1.25)
    rng = np.random.default_rng(3)
    for t, v in zip(rng.uniform(0, 1000, 500),
                    rng.lognormal(3, 1, 500)):
        w.record(float(v), t_us=float(t))
    m = w.merged()
    assert w.dropped_epochs == 0
    assert m.count == w.lifetime.count
    assert m.sum == pytest.approx(w.lifetime.sum)   # summation order
    for q in (0.01, 0.5, 0.9, 0.99, 1.0):
        assert m.quantile(q) == w.lifetime.quantile(q)


def test_max_windows_bound_and_dropped_epochs():
    w = WindowedHistogram(window_us=10.0, max_windows=4)
    for e in range(9):
        w.record(2.0, t_us=e * 10.0)
    assert len(w.epochs) == 4
    assert w.dropped_epochs == 5
    assert sorted(w.epochs) == [5, 6, 7, 8]      # oldest dropped first
    assert w.lifetime.count == 9                 # lifetime never drops


def test_snapshot_merge_associative_commutative_roundtrip():
    """Per-epoch elementwise addition: any merge order and grouping of
    shard snapshots yields the same series — and snapshots survive a
    JSON round trip."""
    def mk(seed):
        w = WindowedHistogram(window_us=25.0, lo=1.0, hi=1e4,
                              growth=1.5)
        rng = np.random.default_rng(seed)
        for t, v in zip(rng.uniform(0, 200, 60),
                        rng.uniform(1, 5e3, 60)):
            w.record(float(v), t_us=float(t))
        return w

    a, b, c = mk(1), mk(2), mk(3)
    snaps = [json.loads(json.dumps(x.snapshot())) for x in (a, b, c)]

    def fold(order):
        out = WindowedHistogram(window_us=25.0, lo=1.0, hi=1e4,
                                growth=1.5)
        for i in order:
            out.merge_snapshot(snaps[i])
        return out

    ref = fold([0, 1, 2])
    for order in ([2, 1, 0], [1, 0, 2], [2, 0, 1]):
        got = fold(order)
        assert [r["count"] for r in got.series()] \
            == [r["count"] for r in ref.series()]
        assert got.lifetime.count == ref.lifetime.count
        for q in (0.5, 0.99):
            assert got.merged().quantile(q) == ref.merged().quantile(q)
    assert ref.lifetime.count == 180


def test_merge_rejects_layout_mismatch():
    w = WindowedHistogram(window_us=100.0, lo=1.0, hi=1e4, growth=2.0)
    other = WindowedHistogram(window_us=50.0, lo=1.0, hi=1e4,
                              growth=2.0)
    with pytest.raises(ValueError, match="window/bucket layouts"):
        w.merge_snapshot(other.snapshot())
    c = WindowedCounter(window_us=100.0)
    with pytest.raises(ValueError, match="window_us"):
        c.merge_snapshot(WindowedCounter(window_us=7.0).snapshot())


_CHILD = """
import json, sys
import numpy as np
from repro.obs.windows import WindowedHistogram
seed = int(sys.argv[1])
w = WindowedHistogram(window_us=40.0, lo=1.0, hi=1e4, growth=1.5)
rng = np.random.default_rng(seed)
for t, v in zip(rng.uniform(0, 400, 80), rng.uniform(1, 9e3, 80)):
    w.record(float(v), t_us=float(t))
print(json.dumps(w.snapshot()))
"""


def test_snapshot_merge_across_shard_subprocesses():
    """Two real subprocesses each record their shard's samples and emit
    a snapshot on stdout; the parent merges them (both orders) and the
    result equals recording everything in one process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    snaps = []
    for seed in (101, 202):
        out = subprocess.run([sys.executable, "-c", _CHILD, str(seed)],
                             capture_output=True, text=True, env=env,
                             check=True)
        snaps.append(json.loads(out.stdout))

    local = WindowedHistogram(window_us=40.0, lo=1.0, hi=1e4,
                              growth=1.5)
    for seed in (101, 202):
        rng = np.random.default_rng(seed)
        for t, v in zip(rng.uniform(0, 400, 80),
                        rng.uniform(1, 9e3, 80)):
            local.record(float(v), t_us=float(t))

    for order in ((0, 1), (1, 0)):
        m = WindowedHistogram(window_us=40.0, lo=1.0, hi=1e4,
                              growth=1.5)
        for i in order:
            m.merge_snapshot(snaps[i])
        assert [r["count"] for r in m.series()] \
            == [r["count"] for r in local.series()]
        assert m.lifetime.count == local.lifetime.count == 160
        assert m.merged().quantile(0.99) \
            == local.merged().quantile(0.99)


def test_windowed_counter_epochs_and_merge():
    c = WindowedCounter(window_us=1000.0, max_windows=3)
    c.inc(3, t_us=0.0)
    c.inc(2, t_us=999.9)
    c.inc(5, t_us=1000.0)
    assert [(s["epoch"], s["count"]) for s in c.series()] \
        == [(0, 5), (1, 5)]
    assert c.series()[0]["per_s"] == 5 / (1000.0 / 1e6)
    with pytest.raises(ValueError, match="monotone"):
        c.inc(-1, t_us=0.0)
    d = WindowedCounter(window_us=1000.0, max_windows=3)
    d.merge_snapshot(json.loads(json.dumps(c.snapshot())))
    d.merge_snapshot(c.snapshot())
    assert d.total == 20 and d.epochs[0] == 10


# --------------------------------------------------------------------- #
# deterministic schedules                                                #
# --------------------------------------------------------------------- #
def test_schedule_same_seed_bit_identical():
    spec = LoadSpec(n_ops=64, seed=42, mode="open", dist="zipf",
                    skew=1.3, rate_ops_s=500.0)
    a, b = make_schedule(spec), make_schedule(spec)
    assert a.fingerprint() == b.fingerprint()
    np.testing.assert_array_equal(a.is_update, b.is_update)
    np.testing.assert_array_equal(a.rank, b.rank)
    np.testing.assert_array_equal(a.arrival_us, b.arrival_us)
    # any field change changes the fingerprint
    for other in (LoadSpec(n_ops=64, seed=43, mode="open",
                           rate_ops_s=500.0),
                  LoadSpec(n_ops=64, seed=42, mode="open",
                           rate_ops_s=501.0),
                  LoadSpec(n_ops=65, seed=42, mode="open",
                           rate_ops_s=500.0)):
        assert make_schedule(other).fingerprint() != a.fingerprint()


def test_schedule_validation_and_clipping():
    with pytest.raises(ValueError, match="unknown mode"):
        make_schedule(LoadSpec(mode="ajar"))
    with pytest.raises(ValueError, match="unknown dist"):
        make_schedule(LoadSpec(dist="pareto"))
    with pytest.raises(ValueError, match="skew > 1"):
        make_schedule(LoadSpec(dist="zipf", skew=1.0))
    with pytest.raises(ValueError, match="rate_ops_s > 0"):
        make_schedule(LoadSpec(mode="open", rate_ops_s=0.0))
    s = make_schedule(LoadSpec(n_ops=2000, dist="zipf", skew=1.05,
                               retain=32))
    assert s.rank.min() >= 1 and s.rank.max() <= 32   # clipped
    u = make_schedule(LoadSpec(n_ops=2000, dist="uniform", retain=32))
    assert u.rank.min() >= 1 and u.rank.max() <= 32


def test_open_arrivals_strictly_increasing_at_rate():
    s = make_schedule(LoadSpec(n_ops=4000, seed=5, mode="open",
                               rate_ops_s=1000.0))
    assert np.all(np.diff(s.arrival_us) > 0)
    mean_gap = float(np.diff(s.arrival_us).mean())
    assert 800.0 < mean_gap < 1250.0          # ~1000us at 1k ops/s
    c = make_schedule(LoadSpec(n_ops=8, mode="closed"))
    assert not c.arrival_us.any()             # closed loop: no pacing


# --------------------------------------------------------------------- #
# timeline + excursion attribution                                       #
# --------------------------------------------------------------------- #
def test_timeline_half_open_range_and_recorder_mirror():
    fr = FlightRecorder(capacity=8, clock=lambda: 0.0)
    tl = EventTimeline(epoch_ns=0, recorder=fr)
    tl.annotate("snapshot", t_us=100.0, horizon=3)
    tl.annotate("truncate", t_us=200.0)
    assert [e["kind"] for e in tl.in_range(100.0, 200.0)] \
        == ["snapshot"]                        # half-open: 200 excluded
    assert tl.in_range(200.0, 300.0)[0]["kind"] == "truncate"
    kinds = [e["kind"] for e in fr.entries()]
    assert kinds == ["snapshot", "truncate"]   # mirrored into the ring
    assert all(e["type"] == "annotation" for e in fr.entries())


def test_attribute_excursions_slack_mincount_and_unexplained():
    tl = EventTimeline(epoch_ns=0)
    tl.annotate("snapshot", t_us=95.0)         # just BEFORE window 1
    base = {"count": 10, "p99_us": 10.0}
    series = [
        dict(epoch=0, t_start_us=0.0, t_end_us=100.0, **base),
        dict(epoch=1, t_start_us=100.0, t_end_us=200.0, count=10,
             p99_us=80.0),                     # excursion, event at -5us
        dict(epoch=2, t_start_us=200.0, t_end_us=300.0, **base),
        dict(epoch=3, t_start_us=300.0, t_end_us=400.0, count=10,
             p99_us=90.0),                     # excursion, NO event
        dict(epoch=4, t_start_us=400.0, t_end_us=500.0, **base),
        dict(epoch=5, t_start_us=500.0, t_end_us=600.0, count=0,
             p99_us=float("nan")),             # empty window: ignored
    ]                                          # baseline median = 10
    out = attribute_excursions(series, tl, factor=3.0, slack_us=10.0)
    assert [(x["epoch"], [e["kind"] for e in x["events"]])
            for x in out] == [(1, ["snapshot"]), (3, [])]
    assert all(x["baseline_us"] == 10.0 for x in out)
    # without slack the just-before event no longer attributes
    out2 = attribute_excursions(series, tl, factor=3.0, slack_us=0.0)
    assert [x["events"] for x in out2] == [[], []]
    # min_count filters thin windows out of baseline AND excursions
    assert attribute_excursions(series, tl, factor=3.0,
                                min_count=11) == []


# --------------------------------------------------------------------- #
# flight recorder                                                        #
# --------------------------------------------------------------------- #
def test_flight_recorder_ring_bounds_and_dump(tmp_path):
    fr = FlightRecorder(capacity=3, clock=lambda: 7.0)
    for i in range(10):
        fr.note("annotation", {"kind": "k", "i": i})
    fr.on_event("flush", target="log_0001.json")
    assert len(fr.entries()) == 3              # bounded
    assert fr.seen == 11
    assert [e["type"] for e in fr.entries()] \
        == ["annotation", "annotation", "persist"]
    assert fr.entries()[-1]["kind"] == "flush"
    p = tmp_path / "dump.json"
    d = fr.dump("slo_breach", path=p,
                restart_timing={"total_us": 5.0})
    assert (d["reason"], d["n_entries"], d["seen"], d["dropped"]) \
        == ("slo_breach", 3, 11, 8)
    assert d["restart_timing"] == {"total_us": 5.0}
    assert json.loads(p.read_text())["reason"] == "slo_breach"
    assert fr.dumps == ["slo_breach"]
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


# --------------------------------------------------------------------- #
# harness end-to-end (RequestLog in a tmp dir)                           #
# --------------------------------------------------------------------- #
def test_harness_closed_loop_report(tmp_path):
    spec = LoadSpec(n_ops=24, seed=9, dist="zipf", skew=1.4,
                    update_frac=0.6, batch=3, window_us=5_000.0,
                    retain=32, snapshot_every=4, warmup_ops=2)
    rep = LoadHarness(str(tmp_path / "l"), spec).run()
    assert rep["target"] == "log"
    assert rep["ops"] == 24 and rep["rids_processed"] == 72
    assert rep["p99_us"] >= rep["p50_us"] > 0
    assert rep["sustained_ops_s"] > 0
    assert rep["schedule_fingerprint"] \
        == make_schedule(spec).fingerprint()
    kinds = {e["kind"] for e in rep["timeline"]}
    assert "log_open" in kinds and "snapshot" in kinds
    assert sum(r["count"] for r in rep["series"]) == 24
    assert rep["counters"]["commits"] > 0
    assert rep["counters"]["snapshots"] > 0
    assert rep["flight"]["seen"] > 0 and not rep["flight"]["dumps"]


def test_harness_crash_dump_and_recovery(tmp_path):
    flight = tmp_path / "flight.json"
    spec = LoadSpec(n_ops=16, seed=2, dist="uniform", update_frac=0.7,
                    batch=2, window_us=20_000.0, retain=16,
                    snapshot_every=None, warmup_ops=2, crash_at_op=8,
                    crash_evict="torn")
    rep = LoadHarness(str(tmp_path / "c"), spec,
                      flight_path=str(flight)).run()
    cr = rep["crash"]
    assert cr["no_acked_lost"] is True
    assert cr["evict"] == "torn"
    rt = cr["restart_timing"]
    assert rt["total_us"] > 0
    assert set(rt) >= {"load_snapshot_us", "replay_us", "trim_us",
                       "total_us", "records_parsed"}
    kinds = [e["kind"] for e in rep["timeline"]]
    for k in ("crash", "recovery_begin", "recovery_end"):
        assert k in kinds
    d = json.loads(flight.read_text())
    assert d["reason"] == "injected_crash"
    assert d["no_acked_lost"] is True
    assert d["restart_timing"]["total_us"] > 0
    assert d["n_entries"] > 0
    types = {e["type"] for e in d["entries"]}
    assert "span" in types and "persist" in types
    assert rep["flight"]["dumps"] == ["injected_crash"]


def test_restart_timing_phases_on_plain_reopen(tmp_path):
    from repro.serving.engine import RequestLog
    log = RequestLog(tmp_path, capacity=256)
    log.commit({1: [1], 2: [2]})
    log.snapshot()
    log.commit({3: [3]})
    again = RequestLog(tmp_path, capacity=256)
    rt = again.restart_timing
    assert rt["snapshot_loaded"] is True
    assert rt["records_parsed"] == 1        # only the post-snapshot one
    assert rt["total_us"] >= rt["replay_us"] >= 0
    assert all(again.took_effect([1, 2, 3]))


# --------------------------------------------------------------------- #
# bench-history regression gate                                          #
# --------------------------------------------------------------------- #
def _bench_tools():
    sys.path.insert(0, os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "tools")))
    import bench_history
    return bench_history


def _fake_bench(p99=400.0, ops=5000.0, speedup=40.0):
    return {"insert": {"parallel_us_per_op": 2.0, "speedup": speedup},
            "serving_load": {"points": {"closed_zipf1.1": {
                "p50_us": 100.0, "p99_us": p99,
                "sustained_ops_s": ops}}}}


def test_bench_history_extract_wildcards():
    bh = _bench_tools()
    scalars = bh.extract(_fake_bench())
    assert scalars["serving_load.points.closed_zipf1.1.p99_us"] \
        == (400.0, "lower")
    assert scalars["serving_load.points.closed_zipf1.1"
                   ".sustained_ops_s"] == (5000.0, "higher")
    assert scalars["insert.speedup"] == (40.0, "higher")
    assert "serving_load.points.closed_zipf1.1.p50_us" in scalars
    # absent sections are skipped, not errors
    assert bh.extract({}) == {}


def test_bench_history_detects_seeded_synthetic_regression():
    """The acceptance witness: noise-band history from seeded jittered
    runs; a big latency/throughput regression is flagged, an equally
    big improvement is not."""
    bh = _bench_tools()
    history = bh.load_history("/nonexistent/BENCH_history.json")
    rng = np.random.default_rng(77)
    for i in range(5):
        jit = 1.0 + float(rng.normal(0, 0.02))
        bh.append_entry(history,
                        bh.extract(_fake_bench(p99=400.0 * jit,
                                               ops=5000.0 / jit)),
                        run_id=f"seed-{i}")
    assert len(history["entries"]) == 5

    clean = bh.check(bh.extract(_fake_bench()), history)
    assert clean["regressions"] == [] and clean["checked"] == 5

    bad = bh.check(bh.extract(_fake_bench(p99=2000.0, ops=900.0,
                                          speedup=8.0)), history)
    names = {r["name"] for r in bad["regressions"]}
    assert names == {"serving_load.points.closed_zipf1.1.p99_us",
                     "serving_load.points.closed_zipf1.1"
                     ".sustained_ops_s",
                     "insert.speedup"}
    # direction-aware: a 5x IMPROVEMENT never regresses
    good = bh.check(bh.extract(_fake_bench(p99=80.0, ops=25000.0,
                                           speedup=200.0)), history)
    assert good["regressions"] == []
    assert len(good["improved"]) >= 3


def test_bench_history_min_runs_and_bounded_entries(tmp_path):
    bh = _bench_tools()
    history = bh.load_history(tmp_path / "none.json")
    for i in range(2):
        bh.append_entry(history, bh.extract(_fake_bench()),
                        run_id=f"r{i}")
    v = bh.check(bh.extract(_fake_bench()), history, min_runs=3)
    assert v["checked"] == 0 and len(v["new"]) == 5   # under min_runs
    for i in range(60):
        bh.append_entry(history, bh.extract(_fake_bench()),
                        run_id=f"r{i}", max_entries=50)
    assert len(history["entries"]) == 50              # bounded
    # corrupted history self-heals to empty
    p = tmp_path / "h.json"
    p.write_text("{not json")
    assert bh.load_history(p) == {"format": 1, "entries": []}


def test_bench_history_cli_strict_exit_codes(tmp_path):
    bh_path = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "tools", "bench_history.py"))
    bench = tmp_path / "bench.json"
    hist = tmp_path / "hist.json"
    for i in range(3):
        bench.write_text(json.dumps(_fake_bench(p99=400.0 + i)))
        subprocess.run([sys.executable, bh_path, "--bench", str(bench),
                        "--history", str(hist), "--append",
                        "--run-id", f"s{i}"], check=True,
                       capture_output=True)
    bench.write_text(json.dumps(_fake_bench(p99=4000.0)))
    report_only = subprocess.run(
        [sys.executable, bh_path, "--bench", str(bench),
         "--history", str(hist), "--check"],
        capture_output=True, text=True)
    assert report_only.returncode == 0               # report-only
    assert "REGRESSION" in report_only.stdout
    strict = subprocess.run(
        [sys.executable, bh_path, "--bench", str(bench),
         "--history", str(hist), "--check", "--strict"],
        capture_output=True, text=True)
    assert strict.returncode == 1                    # gate fires
    missing = subprocess.run(
        [sys.executable, bh_path, "--bench",
         str(tmp_path / "absent.json"), "--check"],
        capture_output=True, text=True)
    assert missing.returncode == 2
