"""Hypothesis property tests on the system's invariants (deliverable c)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.harris_list import HarrisList
from repro.core.hash_table import HashTable
from repro.core.linearizability import check_durably_linearizable
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.scheduler import Interleaver
from repro.core.traversal import run_operation

SETTINGS = settings(max_examples=25, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


# --------------------------------------------------------------------- #
# PMem invariants                                                        #
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.lists(st.tuples(st.integers(0, 63), st.integers(-5, 5)),
                min_size=1, max_size=40),
       st.data())
def test_pmem_fence_exactly_flushed_lines(ops, data):
    """After any write/flush sequence + fence: persistent == volatile on
    flushed lines; untouched-by-fence words keep their old value."""
    m = PMem(64, line_words=8)
    flushed_lines = set()
    for addr, val in ops:
        m.write(addr, val)
        if data.draw(st.booleans()):
            m.flush(addr)
            flushed_lines.add(addr // 8)
    m.fence()
    for ln in range(8):
        lo, hi = ln * 8, ln * 8 + 8
        if ln in flushed_lines:
            np.testing.assert_array_equal(m.persistent[lo:hi],
                                          m.volatile[lo:hi])


@SETTINGS
@given(st.lists(st.tuples(st.integers(0, 63), st.integers(1, 100)),
                min_size=1, max_size=30),
       st.sampled_from(["none", "all", "random"]))
def test_pmem_crash_monotone(ops, evict):
    """Post-crash persistent state: each word is either its pre-crash
    persistent value or its volatile value — never anything else; and
    volatile == persistent afterwards (cache reload)."""
    m = PMem(64, line_words=8, seed=1)
    for addr, val in ops:
        m.write(addr, val)
    pers_before = m.persistent.copy()
    vol_before = m.volatile.copy()
    m.crash(evict=evict)
    for i in range(64):
        assert m.persistent[i] in (pers_before[i], vol_before[i])
    np.testing.assert_array_equal(m.volatile, m.persistent)


# --------------------------------------------------------------------- #
# structure invariants                                                   #
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.lists(st.tuples(st.sampled_from(["insert", "delete", "find"]),
                          st.integers(0, 15)), min_size=1, max_size=40))
def test_list_matches_model_set(ops):
    mem = PMem(1 << 15)
    ds = HarrisList(mem)
    pol = get_policy("nvtraverse")
    model = set()
    for op, k in ops:
        got = run_operation(ds, pol, op, (k, k) if op == "insert" else (k,))
        if op == "insert":
            assert got == (k not in model)
            model.add(k)
        elif op == "delete":
            assert got == (k in model)
            model.discard(k)
        else:
            assert got == (k in model)
    assert set(ds.contents()) == model
    ds.check_integrity()


@SETTINGS
@given(st.integers(0, 10_000), st.integers(0, 400),
       st.sampled_from(["none", "all", "random"]))
def test_hash_table_crash_always_durably_linearizable(seed, crash_at, evict):
    """The flagship property: ANY schedule × ANY crash point × ANY eviction
    subset recovers to a durably-linearizable state (Theorem 4.2)."""
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 16, seed=seed)
    ds = HashTable(mem, n_buckets=4)
    pol = get_policy("nvtraverse")
    init = [int(k) for k in rng.choice(12, size=4, replace=False)]
    for k in init:
        run_operation(ds, pol, "insert", (k, k))
    mem.persist_all()
    ops = []
    for _ in range(10):
        op = rng.choice(["insert", "delete", "find"])
        k = int(rng.integers(0, 12))
        ops.append((op, (k, k) if op == "insert" else (k,)))
    il = Interleaver(ds, pol, ops, seed=seed)
    recs = il.run(crash_at=crash_at, evict=evict)
    if il.crashed:
        ds.disconnect()
        ds.check_integrity(require_unmarked=True)
        assert check_durably_linearizable(
            recs, set(ds.contents()), initial_keys=init)


# --------------------------------------------------------------------- #
# batched map vs oracle                                                  #
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 30)),
                min_size=1, max_size=25))
def test_batched_hashmap_property(ops):
    import jax.numpy as jnp
    from repro.core import batched as B
    st_ = B.make_state(256, 8)
    model = {}
    for is_insert, k in ops:
        if is_insert:
            st_, ok = B.insert(st_, jnp.array([k]), jnp.array([k * 2]), 8)
            assert bool(ok[0]) == (k not in model)
            model[k] = k * 2
        else:
            st_, ok = B.delete(st_, jnp.array([k]), 8)
            assert bool(ok[0]) == (k in model)
            model.pop(k, None)
    keys = jnp.arange(1, 31)
    found, vals = B.lookup(st_, keys, 8)
    for i, k in enumerate(range(1, 31)):
        assert bool(found[i]) == (k in model)


@SETTINGS
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 12),
                          st.integers(0, 99)),
                min_size=48, max_size=48))
def test_mixed_update_parallel_matches_sequential_oracle(ops):
    """Random interleaved insert/delete sequences — duplicate keys with
    alternating ops included (the tiny key range guarantees them) — are
    bit-identical between one update_parallel round and the sequential
    mixed oracle: state arrays, per-op ok flags, and flush/fence
    accounting.  (Fixed batch size: one jit trace for all examples.)"""
    import jax.numpy as jnp
    from repro.core import batched as B
    codes = jnp.asarray([B.OP_INSERT if is_ins else B.OP_DELETE
                         for is_ins, _, _ in ops])
    ks = jnp.asarray([k for _, k, _ in ops])
    vs = jnp.asarray([v for _, _, v in ops])
    st_o, ok_o = B.apply(B.make_state(128, 8), codes, ks, vs, 8)
    st_p, ok_p, stats = B.update_parallel(B.make_state(128, 8), codes,
                                          ks, vs, 8)
    np.testing.assert_array_equal(np.asarray(ok_o), np.asarray(ok_p))
    for f in st_o._fields:
        np.testing.assert_array_equal(np.asarray(getattr(st_o, f)),
                                      np.asarray(getattr(st_p, f)),
                                      err_msg=f"field {f}")
    assert int(stats.coalesced_fences) == 2 * int(stats.max_group)


# --------------------------------------------------------------------- #
# checkpoint layer                                                       #
# --------------------------------------------------------------------- #
@SETTINGS
@given(st.integers(0, 1000), st.sampled_from(["none", "all", "random"]),
       st.sampled_from(["shards", "manifest", None]))
def test_checkpoint_crash_property(seed, evict, crash_after):
    """Any commit interruption + any eviction: recovery returns the last
    published step with verified digests."""
    import tempfile
    import jax.numpy as jnp
    from repro.persistence.checkpoint import CheckpointManager
    tmpdir = tempfile.TemporaryDirectory()
    root = tmpdir.name
    mgr = CheckpointManager(root, seed=seed)
    t1 = {"w": jnp.full((8,), 1.0)}
    t2 = {"w": jnp.full((8,), 2.0)}
    mgr.save(1, t1)
    out = mgr.save(2, t2, crash_after=crash_after)
    mgr.io.crash(evict=evict)
    man, tree = CheckpointManager(root).restore(t1)
    if crash_after is None:
        assert man.step == 2
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.full((8,), 2.0))
    else:
        assert man.step == 1
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.full((8,), 1.0))
