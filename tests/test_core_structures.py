"""Cross-structure tests: BST, hash table, skiplist, queue.

Each set-semantics structure goes through the same gauntlet as the list:
sequential-vs-model, flush economy, concurrent linearizability, and
durable linearizability under crash + recovery (Theorem 4.2).
"""
import numpy as np
import pytest

from repro.core.bst import ExternalBST
from repro.core.hash_table import HashTable
from repro.core.linearizability import (check_durably_linearizable,
                                        check_linearizable,
                                        check_queue_durably_linearizable,
                                        explain_failure)
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.queue import MSQueue
from repro.core.scheduler import Interleaver
from repro.core.skiplist import SkipList
from repro.core.traversal import run_operation

FACTORIES = {
    "bst": lambda mem: ExternalBST(mem),
    "hash": lambda mem: HashTable(mem, n_buckets=4),
    "skiplist": lambda mem: SkipList(mem, max_level=6),
}


def _fill(ds, keys):
    pol = get_policy("nvtraverse")
    for k in keys:
        run_operation(ds, pol, "insert", (k, k * 10))


# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", FACTORIES)
@pytest.mark.parametrize("policy_name", ["volatile", "nvtraverse"])
def test_sequential_vs_model(name, policy_name):
    rng = np.random.default_rng(7)
    mem = PMem(1 << 17)
    ds = FACTORIES[name](mem)
    policy = get_policy(policy_name)
    model = {}
    for _ in range(500):
        op = rng.choice(["insert", "delete", "find"])
        k = int(rng.integers(0, 50))
        if op == "insert":
            got = run_operation(ds, policy, "insert", (k, k * 10))
            want = k not in model
            model[k] = k * 10
        elif op == "delete":
            got = run_operation(ds, policy, "delete", (k,))
            want = k in model
            model.pop(k, None)
        else:
            got = run_operation(ds, policy, "find", (k,))
            want = k in model
        assert got == want, (op, k)
        assert ds.contents() == model
    ds.check_integrity()


@pytest.mark.parametrize("name", FACTORIES)
def test_zero_persistence_in_traverse(name):
    mem = PMem(1 << 17)
    ds = FACTORIES[name](mem)
    _fill(ds, range(0, 128, 2))
    mem.counters.reset()
    pol = get_policy("nvtraverse")
    for k in range(1, 60, 5):
        run_operation(ds, pol, "find", (k,))
        run_operation(ds, pol, "insert", (k, 1))
        run_operation(ds, pol, "delete", (k,))
    assert mem.counters.traverse_flushes == 0
    assert mem.counters.traverse_fences == 0


@pytest.mark.parametrize("name", FACTORIES)
def test_constant_fences_per_find(name):
    """O(1) fences per lookup regardless of structure size."""
    per_size = {}
    for size in (32, 256):
        mem = PMem(1 << 18)
        ds = FACTORIES[name](mem)
        _fill(ds, range(size))
        mem.counters.reset()
        pol = get_policy("nvtraverse")
        for k in range(0, size, max(1, size // 16)):
            run_operation(ds, pol, "find", (k,))
        per_size[size] = mem.counters.fences / (mem.counters.cas + 16)
    assert per_size[256] <= per_size[32] * 1.5 + 1e-9


@pytest.mark.parametrize("name", FACTORIES)
@pytest.mark.parametrize("seed", range(3))
def test_concurrent_linearizable(name, seed):
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 17)
    ds = FACTORIES[name](mem)
    init_keys = list(range(0, 16, 2))
    _fill(ds, init_keys)
    ops = []
    for _ in range(20):
        op = rng.choice(["insert", "delete", "find"])
        k = int(rng.integers(0, 16))
        ops.append((op, (k, k) if op == "insert" else (k,)))
    pol = get_policy("nvtraverse")
    recs = Interleaver(ds, pol, ops, seed=seed).run()
    assert all(r.completed for r in recs)
    ds.check_integrity()
    assert check_linearizable(recs, initial_keys=init_keys), \
        explain_failure(recs, ds.contents().keys(), init_keys)


@pytest.mark.parametrize("name", FACTORIES)
@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("evict", ["none", "all", "random"])
def test_durably_linearizable_under_crash(name, seed, evict):
    for crash_at in (8, 30, 90, 200):
        rng = np.random.default_rng(seed * 1000 + crash_at)
        mem = PMem(1 << 17, seed=seed)
        ds = FACTORIES[name](mem)
        init_keys = list(range(0, 16, 2))
        _fill(ds, init_keys)
        mem.persist_all()
        ops = []
        for _ in range(16):
            op = rng.choice(["insert", "delete", "find"])
            k = int(rng.integers(0, 16))
            ops.append((op, (k, k) if op == "insert" else (k,)))
        il = Interleaver(ds, get_policy("nvtraverse"), ops, seed=seed)
        recs = il.run(crash_at=crash_at, evict=evict)
        if not il.crashed:
            continue
        ds.disconnect()
        ds.check_integrity(require_unmarked=True)
        recovered = set(ds.contents().keys())
        assert check_durably_linearizable(recs, recovered,
                                          initial_keys=init_keys), \
            explain_failure(recs, recovered, init_keys)


# --------------------------------------------------------------------- #
# skiplist specifics                                                     #
# --------------------------------------------------------------------- #
def test_skiplist_index_rebuild_deterministic():
    mem = PMem(1 << 17)
    ds = SkipList(mem, max_level=6)
    _fill(ds, range(64))
    before = {l: list(v) for l, v in ds.index.items()}
    ds.rebuild_index()
    assert {l: list(v) for l, v in ds.index.items()} == before


def test_skiplist_crash_rebuild_towers_identical_to_scalar():
    """Crash mid-schedule under the Interleaver, recover, and rebuild:
    the towers must equal an independent per-key ``tower_height``
    expectation over the recovered live set — the same identity the
    batch engine's ``build_towers`` guarantees (Property 2: index
    reconstruction is deterministic in the bottom list alone)."""
    from repro.core.skiplist import tower_height
    for seed, crash_at in [(0, 12), (1, 40), (2, 120)]:
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 17, seed=seed)
        ds = SkipList(mem, max_level=6)
        _fill(ds, range(0, 24, 3))
        mem.persist_all()
        ops = []
        for _ in range(14):
            op = rng.choice(["insert", "delete"])
            k = int(rng.integers(0, 24))
            ops.append((op, (k, k * 5) if op == "insert" else (k,)))
        il = Interleaver(ds, get_policy("nvtraverse"), ops, seed=seed)
        il.run(crash_at=crash_at, evict="random")
        ds.index = {}                     # towers die with the crash
        ds.disconnect()                   # recovery (rebuilds the index)
        snapshot = ds.sorted_snapshot()   # one bottom-level walk
        assert [k for k, _ in snapshot] == sorted(ds.contents())
        want = {l: [(k, a) for k, a in snapshot
                    if tower_height(k, 6) >= l]
                for l in range(2, 7)}
        assert ds.index == want, f"seed {seed}: rebuilt towers diverge"
        # and the rebuild is a fixed point
        ds.rebuild_index()
        assert ds.index == want


def test_skiplist_index_is_volatile_auxiliary():
    """Crash wipes the towers; recovery rebuilds them; contents survive."""
    mem = PMem(1 << 17)
    ds = SkipList(mem, max_level=6)
    _fill(ds, range(32))
    mem.crash(evict="none")     # everything explicit was already fenced
    ds.index = {}               # towers are gone (volatile)
    ds.disconnect()             # recovery path (also rebuilds the index)
    assert set(ds.contents().keys()) == set(range(32))
    pol = get_policy("nvtraverse")
    assert run_operation(ds, pol, "find", (17,)) is True


# --------------------------------------------------------------------- #
# queue                                                                  #
# --------------------------------------------------------------------- #
def test_queue_sequential_fifo():
    mem = PMem(1 << 16)
    q = MSQueue(mem)
    pol = get_policy("nvtraverse")
    for v in range(10):
        assert run_operation(q, pol, "enqueue", (v,)) is True
    assert q.contents() == list(range(10))
    for v in range(10):
        assert run_operation(q, pol, "dequeue", ()) == v
    assert run_operation(q, pol, "dequeue", ()) is None


@pytest.mark.parametrize("seed", range(4))
def test_queue_concurrent_linearizable(seed):
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 16)
    q = MSQueue(mem)
    ops = []
    v = 100
    for _ in range(11):
        if rng.random() < 0.6:
            ops.append(("enqueue", (v,)))
            v += 1
        else:
            ops.append(("dequeue", ()))
    recs = Interleaver(q, get_policy("nvtraverse"), ops, seed=seed).run()
    assert all(r.completed for r in recs)
    q.check_integrity()
    assert check_queue_durably_linearizable(recs, q.contents())


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("evict", ["none", "all", "random"])
def test_queue_durably_linearizable_under_crash(seed, evict):
    for crash_at in (6, 20, 60):
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 16, seed=seed)
        q = MSQueue(mem)
        ops = []
        v = 100
        for _ in range(12):
            if rng.random() < 0.6:
                ops.append(("enqueue", (v,)))
                v += 1
            else:
                ops.append(("dequeue", ()))
        il = Interleaver(q, get_policy("nvtraverse"), ops, seed=seed)
        recs = il.run(crash_at=crash_at, evict=evict)
        if not il.crashed:
            continue
        q.disconnect()
        q.check_integrity(require_unmarked=True)
        assert check_queue_durably_linearizable(recs, q.contents())


def test_queue_supplement2_original_parent():
    """ensureReachable flushes the location recorded in the node's
    original-parent field (Supplement 2), not a traversal-returned parent."""
    mem = PMem(1 << 16)
    q = MSQueue(mem)
    pol = get_policy("nvtraverse")
    run_operation(q, pol, "enqueue", (5,))
    run_operation(q, pol, "enqueue", (6,))
    # second node's original parent is the first node's next field
    from repro.core.queue import NXT, OPAR
    from repro.core.instr import unpack
    first = unpack(int(mem.volatile[q.head + NXT]))[0]
    second = unpack(int(mem.volatile[first + NXT]))[0]
    assert int(mem.volatile[second + OPAR]) == first + NXT
