"""Tests for the JAX-native batched durable hash map."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as B

NB = 64


def test_insert_lookup_roundtrip():
    st = B.make_state(1024, NB)
    ks = jnp.arange(100, 200)
    st, ok = B.insert(st, ks, ks * 3, NB)
    assert bool(ok.all())
    found, vals = B.lookup(st, ks, NB)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ks) * 3)
    found2, _ = B.lookup(st, jnp.arange(500, 520), NB)
    assert not bool(found2.any())


def test_duplicate_insert_fails_and_delete_resurrect():
    st = B.make_state(256, NB)
    st, ok1 = B.insert(st, jnp.array([7, 7, 9]), jnp.array([1, 2, 3]), NB)
    # scan order linearization: first 7 wins, second fails
    assert list(np.asarray(ok1)) == [True, False, True]
    _, vals = B.lookup(st, jnp.array([7]), NB)
    assert int(vals[0]) == 1
    st, okd = B.delete(st, jnp.array([7, 100]), NB)
    assert list(np.asarray(okd)) == [True, False]
    found, _ = B.lookup(st, jnp.array([7]), NB)
    assert not bool(found[0])
    st, ok2 = B.insert(st, jnp.array([7]), jnp.array([42]), NB)
    assert bool(ok2[0])
    found, vals = B.lookup(st, jnp.array([7]), NB)
    assert bool(found[0]) and int(vals[0]) == 42


def test_vs_python_model_random_ops():
    rng = np.random.default_rng(3)
    st = B.make_state(4096, NB)
    model = {}
    for _ in range(20):
        ks = rng.integers(0, 60, size=32)
        op = rng.choice(["insert", "delete"])
        if op == "insert":
            vs = rng.integers(0, 1000, size=32)
            st, ok = B.insert(st, jnp.asarray(ks), jnp.asarray(vs), NB)
            for i, (k, v) in enumerate(zip(ks, vs)):
                want = k not in model
                assert bool(ok[i]) == want, (k, v)
                if want:
                    model[int(k)] = int(v)
        else:
            st, ok = B.delete(st, jnp.asarray(ks), NB)
            seen = set()
            for i, k in enumerate(ks):
                want = int(k) in model and int(k) not in seen
                # duplicate deletes in one batch: only the first succeeds
                assert bool(ok[i]) == (int(k) in model)
                model.pop(int(k), None)
        probe = rng.integers(0, 60, size=64)
        found, vals = B.lookup(st, jnp.asarray(probe), NB)
        for i, k in enumerate(probe):
            assert bool(found[i]) == (int(k) in model)
            if int(k) in model:
                assert int(vals[i]) == model[int(k)]


def test_flush_fence_accounting_o1_per_op():
    """2 flushes + 2 fences per fresh insert; 0 of each per lookup —
    the batched map mirrors the instruction-level NVTraverse economics."""
    st = B.make_state(2048, NB)
    st, ok = B.insert(st, jnp.arange(1, 101), jnp.arange(1, 101), NB)
    assert int(st.flushes) == 200 and int(st.fences) == 200
    f0, n0 = int(st.flushes), int(st.fences)
    B.lookup(st, jnp.arange(1, 101), NB)   # journey: no persistence
    assert int(st.flushes) == f0 and int(st.fences) == n0
    st, _ = B.delete(st, jnp.arange(1, 11), NB)
    assert int(st.flushes) == f0 + 10 and int(st.fences) == n0 + 20


def test_crash_prefix_durability():
    """A crash mid-batch leaves exactly a prefix of the serialized batch —
    replaying the committed prefix reproduces the recovered state."""
    rng = np.random.default_rng(0)
    ks = jnp.asarray(rng.permutation(np.arange(1, 65)))
    vs = ks * 7
    full = B.make_state(512, NB)
    full, _ = B.insert(full, ks, vs, NB)
    for n_committed in (0, 1, 17, 63):
        st = B.make_state(512, NB)
        st, _ = B.insert(st, ks[:n_committed], vs[:n_committed], NB)
        found, _ = B.lookup(st, ks, NB)
        assert int(found.sum()) == n_committed
        # every committed key present, none of the uncommitted
        assert bool(found[:n_committed].all()) if n_committed else True


def test_chain_stats():
    st = B.make_state(4096, 8)
    st, _ = B.insert(st, jnp.arange(1, 801), jnp.arange(1, 801), 8)
    mx, mean = B.chain_stats(st, 8)
    assert 800 / 8 * 0.5 < float(mean) < 800 / 8 * 1.5
    assert int(mx) >= int(mean)


def test_chain_stats_exact_counts_and_dead_nodes():
    """chain_stats counts *nodes in chains*, exactly: the mean over all
    buckets is total allocated nodes / n_buckets, the max matches a
    per-bucket histogram of the hash — and logical deletes do not
    shorten any chain (dead nodes stay linked until a rebuild)."""
    nb = 8
    st = B.make_state(256, nb)
    assert (int(B.chain_stats(st, nb)[0]),
            float(B.chain_stats(st, nb)[1])) == (0, 0.0)
    ks = jnp.arange(1, 41)
    st, _ = B.insert(st, ks, ks, nb)
    counts = np.zeros(nb, np.int64)
    for k in np.asarray(ks):
        counts[int(B.bucket_of(jnp.int32(k), nb))] += 1
    mx, mean = B.chain_stats(st, nb)
    assert int(mx) == counts.max()
    assert float(mean) == pytest.approx(40 / nb)
    # duplicate inserts and deletes never relink: chain shape unchanged
    st2, _ = B.delete(st, ks[:17], nb)
    st2, _ = B.insert(st2, ks[:5], ks[:5] * 9, nb)   # resurrect in place
    mx2, mean2 = B.chain_stats(st2, nb)
    assert (int(mx2), float(mean2)) == (int(mx), float(mean))


def test_lookup_deleted_then_resurrected_keys():
    """Direct coverage for the lookup path over every liveness phase of
    a key: live → found, logically deleted → not found (the dead node
    still sits mid-chain and must not satisfy or derail the walk),
    resurrected → found with the *new* value — on both engines."""
    nb = 4                                     # long chains: dead nodes
    ks = jnp.arange(1, 33)                     # sit mid-walk for later keys
    for kind in ("scan", "parallel"):
        st = B.make_state(256, nb)
        if kind == "scan":
            st, _ = B.insert(st, ks, ks * 2, nb)
            st, _ = B.delete(st, ks[::2], nb)
        else:
            st, _, _ = B.insert_parallel(st, ks, ks * 2, nb)
            st, _, _ = B.delete_parallel(st, ks[::2], nb)
        found, vals = B.lookup(st, ks, nb)
        np.testing.assert_array_equal(
            np.asarray(found), np.arange(32) % 2 == 1)
        np.testing.assert_array_equal(
            np.asarray(vals)[1::2], np.asarray(ks)[1::2] * 2)
        # resurrect half of the deleted keys with new values
        res = ks[::4]
        cursor_before = int(st.cursor)
        if kind == "scan":
            st, ok = B.insert(st, res, res * 7, nb)
        else:
            st, ok, _ = B.insert_parallel(st, res, res * 7, nb)
        assert bool(ok.all())
        found, vals = B.lookup(st, ks, nb)
        exp_found = (np.arange(32) % 2 == 1) | (np.arange(32) % 4 == 0)
        np.testing.assert_array_equal(np.asarray(found), exp_found)
        np.testing.assert_array_equal(
            np.asarray(vals)[::4], np.asarray(res) * 7)
        np.testing.assert_array_equal(
            np.asarray(vals)[1::2], np.asarray(ks)[1::2] * 2)
        assert cursor_before == int(st.cursor)  # resurrection: no alloc
        # still-deleted keys stay invisible
        still_dead = np.asarray(ks)[2::4]
        f2, _ = B.lookup(st, jnp.asarray(still_dead), nb)
        assert not bool(f2.any())


def test_cross_check_with_instruction_level_structure():
    """Same workload through the instruction-level hash table and the
    batched map: identical abstract contents and same per-op fence count."""
    from repro.core.hash_table import HashTable
    from repro.core.pmem import PMem
    from repro.core.policies import get_policy
    from repro.core.traversal import run_operation

    ks = list(range(1, 41))
    mem = PMem(1 << 16)
    ht = HashTable(mem, n_buckets=NB)
    pol = get_policy("nvtraverse")
    mem.counters.reset()
    for k in ks:
        run_operation(ht, pol, "insert", (k, k))
    inst_fences = mem.counters.fences / len(ks)

    st = B.make_state(1024, NB)
    st, _ = B.insert(st, jnp.asarray(ks), jnp.asarray(ks), NB)
    batched_fences = int(st.fences) / len(ks)
    assert ht.contents() == {k: k for k in ks}
    found, _ = B.lookup(st, jnp.asarray(ks), NB)
    assert bool(found.all())
    # Both are O(1) fences/op.  Instruction-level = exactly 3 (Protocol 1
    # makePersistent fence + pre-CAS fence + return fence).  The batched
    # map's serialized scan elides the Protocol-1 fence — every field its
    # traversal reads was persisted before the previous op's return fence —
    # a beyond-paper optimization recorded in EXPERIMENTS.md (3 → 2).
    assert inst_fences == pytest.approx(3.0)
    assert batched_fences == pytest.approx(2.0)
