"""Treiber stack in traversal form: the sixth paper-scope structure."""
import numpy as np
import pytest

from repro.core.linearizability import check_stack_durably_linearizable
from repro.core.pmem import PMem
from repro.core.policies import get_policy
from repro.core.scheduler import Interleaver
from repro.core.stack import TreiberStack
from repro.core.traversal import run_operation


def test_sequential_lifo():
    mem = PMem(1 << 16)
    st = TreiberStack(mem)
    pol = get_policy("nvtraverse")
    for v in range(10):
        assert run_operation(st, pol, "push", (v,)) is True
    assert st.contents() == list(reversed(range(10)))
    for v in reversed(range(10)):
        assert run_operation(st, pol, "pop", ()) == v
    assert run_operation(st, pol, "pop", ()) is None


def test_zero_persistence_in_traverse_and_o1_fences():
    mem = PMem(1 << 16)
    st = TreiberStack(mem)
    pol = get_policy("nvtraverse")
    mem.counters.reset()
    n = 40
    for v in range(n):
        run_operation(st, pol, "push", (v,))
    for _ in range(n):
        run_operation(st, pol, "pop", ())
    assert mem.counters.traverse_flushes == 0
    assert mem.counters.traverse_fences == 0
    assert mem.counters.fences / (2 * n) < 4      # O(1) per op


@pytest.mark.parametrize("seed", range(4))
def test_concurrent_linearizable(seed):
    rng = np.random.default_rng(seed)
    mem = PMem(1 << 16)
    st = TreiberStack(mem)
    ops, v = [], 100
    for _ in range(11):
        if rng.random() < 0.6:
            ops.append(("push", (v,)))
            v += 1
        else:
            ops.append(("pop", ()))
    recs = Interleaver(st, get_policy("nvtraverse"), ops, seed=seed).run()
    assert all(r.completed for r in recs)
    st.check_integrity()
    assert check_stack_durably_linearizable(recs, st.contents())


@pytest.mark.parametrize("evict", ["none", "all", "random"])
@pytest.mark.parametrize("seed", range(3))
def test_durably_linearizable_under_crash(seed, evict):
    for crash_at in (5, 18, 50):
        rng = np.random.default_rng(seed)
        mem = PMem(1 << 16, seed=seed)
        st = TreiberStack(mem)
        ops, v = [], 100
        for _ in range(12):
            if rng.random() < 0.6:
                ops.append(("push", (v,)))
                v += 1
            else:
                ops.append(("pop", ()))
        il = Interleaver(st, get_policy("nvtraverse"), ops, seed=seed)
        recs = il.run(crash_at=crash_at, evict=evict)
        if not il.crashed:
            continue
        st.disconnect()
        st.check_integrity(require_unmarked=True)
        assert check_stack_durably_linearizable(recs, st.contents())


def test_buried_marked_node_is_trimmed():
    """A push landing between a pop's mark and its swing buries a marked
    node mid-chain; helps and recovery must both remove it."""
    mem = PMem(1 << 16)
    st = TreiberStack(mem)
    pol = get_policy("nvtraverse")
    for v in (1, 2, 3):
        run_operation(st, pol, "push", (v,))
    # interleave a pop and a push so schedules with burial occur
    for seed in range(8):
        m = PMem(1 << 16, seed=seed)
        s2 = TreiberStack(m)
        for v in (1, 2, 3):
            run_operation(s2, pol, "push", (v,))
        recs = Interleaver(s2, pol, [("pop", ()), ("push", (9,))],
                           seed=seed).run()
        s2.disconnect()
        s2.check_integrity(require_unmarked=True)
        assert check_stack_durably_linearizable(
            recs, s2.contents(), initial=[3, 2, 1])
