"""OrderedNVT differential + crash-replay test layer.

Three oracles pin the ordered engine down:

  * the **sequential scan oracle** :func:`repro.core.ordered.
    apply_ordered` — the plan/commit engine must be *bit-identical* to
    it (state arrays including node-id allocation order and chain
    links, per-op ok flags, flush/fence accounting);
  * the **pure-dict oracle** (:func:`repro.core.ordered.oracle_apply` /
    ``oracle_range`` — dict + ``sorted``, zero engine code) for
    content, range queries, and top-k;
  * the **durable-bytes oracle** of the ``ordered`` crash scenario —
    crash-at-every-site recovery must replay to the exact acked prefix
    with bit-identical volatile-tower rebuild.

Plus the seed linearizability harness lifted to the engine level: batch
executions mapped to concurrent :class:`~repro.core.scheduler.OpRecord`
histories checked with :func:`~repro.core.linearizability.
check_linearizable` / ``check_durably_linearizable``.
"""
import json
import tempfile
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ordered as O
from repro.core.batched import OP_DELETE, OP_INSERT
from repro.core.ordered import (DurableOrderedMap, apply_ordered,
                                build_towers, check_sorted, items_host,
                                live_items, lookup_ordered, make_ordered,
                                oracle_apply, oracle_range, range_query,
                                scan, top_k, update_parallel_ordered)


def assert_states_equal(a: O.OrderedState, b: O.OrderedState, ctx=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f} diverged")


def random_batch(rng, n, key_hi=40, val_hi=1000):
    return (rng.integers(0, 2, n).astype(np.int32),
            rng.integers(0, key_hi, n).astype(np.int32),
            rng.integers(0, val_hi, n).astype(np.int32))


# --------------------------------------------------------------------- #
# bit-identity: parallel engine vs sequential scan vs pure-dict oracle   #
# --------------------------------------------------------------------- #
def test_mixed_rounds_bit_identical_to_scan_and_dict_oracle():
    rng = np.random.default_rng(11)
    for trial in range(4):
        cap = int(rng.integers(48, 256))
        st_p, st_s, model = make_ordered(cap), make_ordered(cap), {}
        for rnd in range(8):
            ops, ks, vs = random_batch(rng, int(rng.integers(1, 40)))
            st_p, ok_p, stats = update_parallel_ordered(st_p, ops, ks, vs)
            st_s, ok_s = apply_ordered(st_s, jnp.asarray(ops),
                                       jnp.asarray(ks), jnp.asarray(vs))
            ok_m = oracle_apply(model, ops, ks, vs, capacity=cap)
            np.testing.assert_array_equal(np.asarray(ok_p),
                                          np.asarray(ok_s))
            np.testing.assert_array_equal(np.asarray(ok_p),
                                          np.asarray(ok_m, bool))
            assert_states_equal(st_p, st_s, f"trial {trial} round {rnd}")
            assert items_host(st_p) == model
            check_sorted(st_p)
        # accounting tracked the oracle the whole way
        assert int(st_p.flushes) == int(st_s.flushes)
        assert int(st_p.fences) == int(st_s.fences)


def test_duplicate_key_groups_compose_liveness_in_batch_order():
    """Heavy duplicate-key batches: the whole group's outcome is the
    batch-order composition (insert iff dead, delete iff live), seeded
    by the snapshot — exactly the scan."""
    rng = np.random.default_rng(23)
    st_p, st_s, model = make_ordered(64), make_ordered(64), {}
    for rnd in range(10):
        # 3 distinct keys, 24 ops: ~8 ops per duplicate group
        ops, ks, vs = random_batch(rng, 24, key_hi=3)
        st_p, ok_p, _ = update_parallel_ordered(st_p, ops, ks, vs)
        st_s, ok_s = apply_ordered(st_s, jnp.asarray(ops),
                                   jnp.asarray(ks), jnp.asarray(vs))
        ok_m = oracle_apply(model, ops, ks, vs, capacity=64)
        np.testing.assert_array_equal(np.asarray(ok_p), np.asarray(ok_s))
        np.testing.assert_array_equal(np.asarray(ok_p),
                                      np.asarray(ok_m, bool))
        assert_states_equal(st_p, st_s, f"round {rnd}")


def test_capacity_failure_kills_whole_group_cleanly():
    """A fresh insert that does not fit fails its entire duplicate-key
    group (no partial liveness composition) and leaves accounting and
    chain untouched — same as the scan hitting the full pool."""
    cap = 6          # sentinel + 5 nodes
    st_p, st_s = make_ordered(cap), make_ordered(cap)
    ks0 = np.asarray([10, 20, 30, 40], np.int32)
    st_p, ok, _ = update_parallel_ordered(
        st_p, np.zeros(4, np.int32), ks0, ks0)
    st_s, _ = apply_ordered(st_s, jnp.zeros(4, jnp.int32),
                            jnp.asarray(ks0), jnp.asarray(ks0))
    assert np.asarray(ok).all()
    # 1 free slot; two fresh keys + a delete-then-insert group on 50
    ops = np.asarray([OP_INSERT, OP_INSERT, OP_DELETE, OP_INSERT],
                     np.int32)
    ks = np.asarray([50, 60, 50, 50], np.int32)
    vs = np.asarray([1, 2, 0, 3], np.int32)
    st_p, ok_p, _ = update_parallel_ordered(st_p, ops, ks, vs)
    st_s, ok_s = apply_ordered(st_s, jnp.asarray(ops), jnp.asarray(ks),
                               jnp.asarray(vs))
    np.testing.assert_array_equal(np.asarray(ok_p), np.asarray(ok_s))
    assert_states_equal(st_p, st_s, "capacity group-kill")
    # 50 allocated (first in batch order), 60 failed cleanly
    assert live_items(st_p) == {10: 10, 20: 20, 30: 30, 40: 40, 50: 3}
    check_sorted(st_p)


def test_conflict_stats_follow_pred_group_law():
    """coalesced_fences = 2 × the largest same-predecessor group; fresh
    nodes splicing one gap share a group."""
    st = make_ordered(128)
    st, ok, _ = update_parallel_ordered(
        st, np.zeros(2, np.int32), np.asarray([0, 100], np.int32),
        np.asarray([0, 100], np.int32))
    # 6 fresh keys between 0 and 100: all share predecessor node(0)
    ks = np.asarray([10, 20, 30, 40, 50, 60], np.int32)
    st2, ok, stats = update_parallel_ordered(
        st, np.zeros(6, np.int32), ks, ks)
    assert np.asarray(ok).all()
    assert int(stats.ops_committed) == 6
    assert int(stats.conflict_groups) == 1
    assert int(stats.max_group) == 6
    assert int(stats.coalesced_fences) == 2 * 6
    # spread across distinct predecessors: groups of 1
    ks2 = np.asarray([5, 15, 25, 35], np.int32)
    _, ok, stats = update_parallel_ordered(st2, np.zeros(4, np.int32),
                                           ks2, ks2)
    assert np.asarray(ok).all()
    assert int(stats.conflict_groups) == 4
    assert int(stats.max_group) == 1
    assert int(stats.coalesced_fences) == 2


def test_accounting_law_fresh_two_resurrect_one():
    st = make_ordered(64)
    ks = np.arange(1, 11, dtype=np.int32)
    st, _, _ = update_parallel_ordered(st, np.zeros(10, np.int32), ks, ks)
    assert int(st.flushes) == 20 and int(st.fences) == 20
    st, _, _ = update_parallel_ordered(st, np.ones(10, np.int32), ks, ks)
    assert int(st.flushes) == 30 and int(st.fences) == 40     # delete: 1
    st, _, _ = update_parallel_ordered(st, np.zeros(10, np.int32), ks, ks)
    assert int(st.flushes) == 40 and int(st.fences) == 60     # resurrect: 1


# --------------------------------------------------------------------- #
# property-based op streams (hypothesis when available; the seeded       #
# fallback below always runs the same property)                          #
# --------------------------------------------------------------------- #
def _check_stream_property(batches, cap):
    """The property: arbitrary mixed batches stay bit-identical to the
    scan oracle and the dict oracle, the chain stays sorted, and a
    random range query matches the sorted-dict answer."""
    st_p, st_s, model = make_ordered(cap), make_ordered(cap), {}
    for b in batches:
        ops = np.asarray([o for o, _, _ in b], np.int32)
        ks = np.asarray([k for _, k, _ in b], np.int32)
        vs = np.asarray([v for _, _, v in b], np.int32)
        st_p, ok_p, _ = update_parallel_ordered(st_p, ops, ks, vs)
        st_s, ok_s = apply_ordered(st_s, jnp.asarray(ops),
                                   jnp.asarray(ks), jnp.asarray(vs))
        ok_m = oracle_apply(model, ops, ks, vs, capacity=cap)
        np.testing.assert_array_equal(np.asarray(ok_p), np.asarray(ok_s))
        np.testing.assert_array_equal(np.asarray(ok_p),
                                      np.asarray(ok_m, bool))
        assert_states_equal(st_p, st_s)
        assert items_host(st_p) == model
        check_sorted(st_p)
    return st_p, model


def test_property_streams_bit_identical_seeded():
    """Seeded generator over the same space the hypothesis test draws
    from — runs in every environment (hypothesis is an optional dep)."""
    rng = np.random.default_rng(1234)
    for _ in range(12):
        cap = int(rng.integers(4, 48))
        batches = [[(int(rng.integers(0, 2)), int(rng.integers(0, 26)),
                     int(rng.integers(0, 100)))
                    for _ in range(int(rng.integers(1, 60)))]
                   for _ in range(int(rng.integers(1, 5)))]
        st_p, model = _check_stream_property(batches, cap)
        lo = int(rng.integers(-2, 27))
        hi = int(rng.integers(lo, 29))
        total, rk, rv = range_query(st_p, lo, hi, 64)
        want = oracle_range(model, lo, hi)
        assert int(total) == len(want)
        assert list(zip(np.asarray(rk)[:len(want)].tolist(),
                        np.asarray(rv)[:len(want)].tolist())) == want


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    SETTINGS = settings(max_examples=20, deadline=None,
                        suppress_health_check=[HealthCheck.too_slow])
    op_stream = st.lists(
        st.tuples(st.integers(0, 1), st.integers(0, 25),
                  st.integers(0, 99)),
        min_size=1, max_size=60)

    @SETTINGS
    @given(st.lists(op_stream, min_size=1, max_size=4),
           st.integers(4, 48))
    def test_hypothesis_streams_bit_identical(batches, cap):
        _check_stream_property(batches, cap)
except ImportError:          # pragma: no cover - optional dependency
    pass


# --------------------------------------------------------------------- #
# ordered reads: towers, range, scan, top-k                              #
# --------------------------------------------------------------------- #
def _grown_state(rng, cap=512, rounds=6):
    stt, model = make_ordered(cap), {}
    for _ in range(rounds):
        ops, ks, vs = random_batch(rng, 64, key_hi=200)
        stt, _, _ = update_parallel_ordered(stt, ops, ks, vs)
        oracle_apply(model, ops, ks, vs, capacity=cap)
    return stt, model


def test_tower_rebuild_identity_and_lookup_equivalence():
    """Property 2, mechanically: towers rebuilt from the bottom list
    match an independent per-key scalar tower_height expectation, the
    rebuild is idempotent, and descending them changes no answer."""
    from repro.core.skiplist import tower_height
    rng = np.random.default_rng(5)
    stt, model = _grown_state(rng)
    tw = build_towers(stt)
    # independent expectation from the seed skiplist's scalar promotion
    ks_arr, live = np.asarray(stt.key), np.asarray(stt.live)
    for lvl in range(2, O.MAX_LEVEL + 1):
        want = sorted((int(ks_arr[n]), int(n))
                      for n in np.nonzero(live)[0]
                      if tower_height(int(ks_arr[n]), O.MAX_LEVEL) >= lvl)
        row_k = np.asarray(tw.keys[lvl - 2])
        row_a = np.asarray(tw.addr[lvl - 2])
        assert [(int(row_k[i]), int(row_a[i]))
                for i in range(len(want))] == want
        assert (row_k[len(want):] == O.KEY_PAD).all()
    tw2 = build_towers(stt)
    for a, b in zip(tw, tw2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # lookups with towers == without (the index is only a shortcut)
    probe = jnp.asarray(rng.integers(0, 220, 64), jnp.int32)
    f1, v1 = lookup_ordered(stt, probe, tw)
    f2, v2 = lookup_ordered(stt, probe, None)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    for i, k in enumerate(np.asarray(probe)):
        lv, v = model.get(int(k), (False, 0))
        assert bool(np.asarray(f1)[i]) == lv
        if lv:
            assert int(np.asarray(v1)[i]) == v


def test_range_query_zipf_matches_sorted_dict_oracle():
    """Seeded zipf key stream (skewed duplicates), then a sweep of
    range shapes vs the pure sorted-dict oracle — including truncation
    and with/without towers."""
    rng = np.random.default_rng(42)
    stt, model = make_ordered(1024), {}
    for _ in range(6):
        n = 96
        ks = (rng.zipf(1.3, n) % 500).astype(np.int32)
        ops = rng.integers(0, 2, n).astype(np.int32)
        vs = rng.integers(0, 10_000, n).astype(np.int32)
        stt, _, _ = update_parallel_ordered(stt, ops, ks, vs)
        oracle_apply(model, ops, ks, vs, capacity=1024)
    tw = build_towers(stt)
    for lo, hi in [(0, 499), (10, 20), (100, 300), (450, 600),
                   (7, 7), (300, 100)]:
        want = oracle_range(model, lo, hi)
        for towers in (tw, None):
            total, rk, rv = range_query(stt, lo, hi, 600, towers)
            assert int(total) == len(want)
            assert list(zip(np.asarray(rk)[:len(want)].tolist(),
                            np.asarray(rv)[:len(want)].tolist())) == want
    # truncation: max_items smaller than the hit count
    want = oracle_range(model, 0, 499)
    total, rk, rv = range_query(stt, 0, 499, 5, tw)
    assert int(total) == len(want)
    assert list(zip(np.asarray(rk)[:5].tolist(),
                    np.asarray(rv)[:5].tolist())) == want[:5]


def test_scan_and_top_k_match_oracle():
    rng = np.random.default_rng(9)
    stt, model = _grown_state(rng)
    alive = sorted((k, v) for k, v in live_items(stt).items())
    assert alive == sorted(
        (k, v) for k, (lv, v) in model.items() if lv)
    total, sk, sv = scan(stt, 512)
    assert int(total) == len(alive)
    assert list(zip(np.asarray(sk)[:len(alive)].tolist(),
                    np.asarray(sv)[:len(alive)].tolist())) == alive
    for k in (1, 3, 17, len(alive), len(alive) + 10):
        cnt, tk, tv = top_k(stt, k)
        want = alive[-k:]
        assert int(cnt) == len(want)
        assert list(zip(np.asarray(tk)[:len(want)].tolist(),
                        np.asarray(tv)[:len(want)].tolist())) == want


# --------------------------------------------------------------------- #
# durable wrapper: journal round-trip + crash replay                     #
# --------------------------------------------------------------------- #
def test_durable_map_recovery_bit_identical():
    rng = np.random.default_rng(3)
    with tempfile.TemporaryDirectory() as d:
        m = DurableOrderedMap(d, capacity=128)
        model = {}
        for b in range(7):
            ops, ks, vs = random_batch(rng, int(rng.integers(1, 20)))
            m.update(ops, ks, vs)
            oracle_apply(model, ops, ks, vs, capacity=128)
            if b == 3:
                m.snapshot()
        assert m.items() == model
        m2 = DurableOrderedMap(d, capacity=128)
        assert_states_equal(m.state, m2.state, "recovery")
        for a, b_ in zip(m.towers, m2.towers):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
        assert m2._n == m._n
        check_sorted(m2.state)
        total, rk, rv = m2.range(0, 39, 64)
        want = oracle_range(model, 0, 39)
        assert total == len(want)
        assert list(zip(rk.tolist(), rv.tolist())) == want


def test_ordered_crash_scenario_sampled_sites():
    """Crash-at-site recovery through the faultinject scenario (the
    full 25-site × 3-eviction sweep runs in the CI faultinject lane;
    tier-1 samples a site budget across all three adversaries)."""
    from repro.robustness.faultinject import OrderedScenario, sweep
    rep = sweep(OrderedScenario, budget=7,
                evict_modes=("none", "random", "torn"))
    assert rep["failures"] == [], rep["failures"]
    assert rep["n_sites"] > 0
    kinds = {s["kind"] for s in rep["sites"]}
    assert kinds == {"flush", "fence", "publish", "trim"}


def test_torn_round_never_acked_and_prefix_replayed():
    """A round file torn mid-stage is never acknowledged; recovery
    replays exactly the published prefix."""
    rng = np.random.default_rng(8)
    with tempfile.TemporaryDirectory() as d:
        m = DurableOrderedMap(d, capacity=64)
        for _ in range(3):
            ops, ks, vs = random_batch(rng, 8)
            m.update(ops, ks, vs)
        acked = m.items()
        # stage a 4th round but crash before publish: torn staging
        m.io.write("ord.tmp", b'{"ops": [0], "ks": [5]')   # torn payload
        m.io.crash(evict="all")
        m2 = DurableOrderedMap(d, capacity=64)
        assert m2.items() == acked
        assert m2._n == 3
        check_sorted(m2.state)


# --------------------------------------------------------------------- #
# serving consumer: ordered_dedup retention trim                         #
# --------------------------------------------------------------------- #
def test_request_log_ordered_dedup_equivalent_and_restartable():
    from repro.serving.engine import RequestLog
    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        a = RequestLog(root / "hash", capacity=256)
        b = RequestLog(root / "ord", capacity=256, ordered_dedup=True)
        retain = 5
        rid = 0
        for batch in range(7):
            rec = {rid + i: [batch, i] for i in range(3)}
            rid += 3
            ea, eb = a.expired_rids(retain), b.expired_rids(retain)
            assert sorted(ea) == eb          # ordered trim is ascending
            a.commit(rec, evict=ea)
            b.commit(rec, evict=eb)
            assert a.committed() == b.committed()
            rids = list(range(rid))
            np.testing.assert_array_equal(a.took_effect(rids),
                                          b.took_effect(rids))
            if batch == 3:
                a.snapshot()
                b.snapshot()
        assert b.dedup_migrations == b._dedup.migrations
        # restart: ordered mode recovers through the same snapshot +
        # suffix replay and answers identically
        a2 = RequestLog(root / "hash", capacity=256)
        b2 = RequestLog(root / "ord", capacity=256, ordered_dedup=True)
        assert a2.committed() == b2.committed() == a.committed()
        assert sorted(a2.expired_rids(2)) == b2.expired_rids(2)
        np.testing.assert_array_equal(a2.took_effect(list(range(rid))),
                                      b2.took_effect(list(range(rid))))


def test_ordered_membership_index_expired_window():
    from repro.persistence.index import OrderedMembershipIndex
    idx = OrderedMembershipIndex(capacity=8)   # forces growth too
    idx.update(add_keys=range(0, 40, 2))
    assert idx.expired(5) == list(range(0, 30, 2))
    assert idx.expired(100) == []
    assert idx.expired(0) == list(range(0, 40, 2))
    idx.update(remove_keys=[0, 2, 4])
    assert idx.expired(5) == list(range(6, 30, 2))
    assert idx.range_members(10, 20, 50) == [10, 12, 14, 16, 18, 20]
    assert idx.migrations >= 1


# --------------------------------------------------------------------- #
# engine-level linearizability (the revived seed harness)                #
# --------------------------------------------------------------------- #
def _batch_records(batches, oks, crashed_batch=None):
    """Map batch executions onto concurrent OpRecord histories: ops of
    batch b are concurrent with each other (invoke 2b, respond 2b+1),
    batches are real-time ordered; a crashed batch's ops stay pending."""
    from repro.core.scheduler import OpRecord
    records, opid = [], 0
    for bi, (ops, ks, _vs) in enumerate(batches):
        crashed = crashed_batch is not None and bi >= crashed_batch
        for i in range(len(ks)):
            name = "insert" if int(ops[i]) == OP_INSERT else "delete"
            records.append(OpRecord(
                opid=opid, op=name, args=(int(ks[i]),),
                invoke_step=2 * bi,
                respond_step=None if crashed else 2 * bi + 1,
                result=None if crashed else bool(oks[bi][i])))
            opid += 1
    return records


def test_engine_batches_linearizable():
    from repro.core.linearizability import check_linearizable
    rng = np.random.default_rng(31)
    stt = make_ordered(256)
    batches, oks = [], []
    for _ in range(5):
        ops, ks, vs = random_batch(rng, 12, key_hi=10)
        stt, ok, _ = update_parallel_ordered(stt, ops, ks, vs)
        batches.append((ops, ks, vs))
        oks.append(np.asarray(ok))
    assert check_linearizable(_batch_records(batches, oks))


def test_engine_crash_prefix_durably_linearizable():
    """Crash after every batch boundary of a durable run: the recovered
    live set must durably linearize the full history with the suffix
    pending (all-or-nothing per batch — the journal replays a strict
    round prefix)."""
    from repro.core.linearizability import check_durably_linearizable
    rng = np.random.default_rng(37)
    with tempfile.TemporaryDirectory() as d:
        m = DurableOrderedMap(d, capacity=256)
        batches, oks = [], []
        for _ in range(4):
            ops, ks, vs = random_batch(rng, 8, key_hi=12)
            ok = m.update(ops, ks, vs)
            batches.append((ops, ks, vs))
            oks.append(ok)
        # simulate recovery from every durable prefix: replay the first
        # c rounds (the journal's only crash outcomes) and check
        for c in range(len(batches) + 1):
            stt = make_ordered(256)
            for ops, ks, vs in batches[:c]:
                stt, _, _ = update_parallel_ordered(stt, ops, ks, vs)
            recovered = set(live_items(stt))
            assert check_durably_linearizable(
                _batch_records(batches, oks, crashed_batch=c),
                recovered_keys=recovered), f"prefix {c} not durable-lin"


def test_seed_skiplist_rebuild_matches_engine_towers():
    """Bridge: the seed SkipList's recovery rebuild and the batch
    engine's build_towers promote the *same* keys to the same levels
    (both derive from tower_height)."""
    from repro.core.pmem import PMem
    from repro.core.policies import get_policy
    from repro.core.skiplist import SkipList
    from repro.core.traversal import run_operation
    mem = PMem(4096)
    sl = SkipList(mem, max_level=8)
    pol = get_policy("nvtraverse")
    keys = [3, 17, 29, 41, 53, 65, 77, 89, 101]
    for k in keys:
        assert run_operation(sl, pol, "insert", (k, k * 2))
    for k in (29, 65):
        assert run_operation(sl, pol, "delete", (k,))
    sl.rebuild_index()
    live = [k for k in keys if k not in (29, 65)]
    # mirror the live set into the ordered engine
    stt = make_ordered(64)
    ks = np.asarray(live, np.int32)
    stt, ok, _ = update_parallel_ordered(
        stt, np.zeros(len(live), np.int32), ks, 2 * ks)
    assert np.asarray(ok).all()
    tw = build_towers(stt)
    for lvl in range(2, 9):
        seed_keys = [k for k, _ in sl.index[lvl]]
        row = np.asarray(tw.keys[lvl - 2])
        eng_keys = [int(row[i]) for i in range(len(seed_keys))]
        assert eng_keys == seed_keys, f"level {lvl} promotion differs"
        assert (row[len(seed_keys):] == O.KEY_PAD).all()
    # the seed rebuild is itself stable (sorted_snapshot path)
    before = {l: list(v) for l, v in sl.index.items()}
    sl.rebuild_index()
    assert sl.index == before


# --------------------------------------------------------------------- #
# acceptance: 20k-op mixed stream (slow lane)                            #
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_acceptance_20k_mixed_stream_bit_identical():
    rng = np.random.default_rng(1)
    cap = 16_384
    st_p, st_s, model = make_ordered(cap), make_ordered(cap), {}
    n_ops = 0
    while n_ops < 20_000:
        n = 512
        ops = rng.integers(0, 2, n).astype(np.int32)
        ks = (rng.zipf(1.2, n) % 8000).astype(np.int32)
        vs = rng.integers(0, 10_000, n).astype(np.int32)
        st_p, ok_p, _ = update_parallel_ordered(st_p, ops, ks, vs)
        st_s, ok_s = apply_ordered(st_s, jnp.asarray(ops),
                                   jnp.asarray(ks), jnp.asarray(vs))
        ok_m = oracle_apply(model, ops, ks, vs, capacity=cap)
        np.testing.assert_array_equal(np.asarray(ok_p), np.asarray(ok_s))
        np.testing.assert_array_equal(np.asarray(ok_p),
                                      np.asarray(ok_m, bool))
        n_ops += n
    assert_states_equal(st_p, st_s, "20k stream")
    assert items_host(st_p) == model
    check_sorted(st_p)
    for lo, hi in [(0, 7999), (100, 200), (4000, 4100)]:
        want = oracle_range(model, lo, hi)
        total, rk, rv = range_query(st_p, lo, hi, 8192)
        assert int(total) == len(want)
        assert list(zip(np.asarray(rk)[:len(want)].tolist(),
                        np.asarray(rv)[:len(want)].tolist())) == want
