"""Validation of EXPERIMENTS.md §Repro against the paper's own claims.

These run the benchmark cost model at reduced op counts; the full sweeps
are in benchmarks/.
"""
import pytest

from benchmarks.paper_figures import run_workload


@pytest.fixture(scope="module")
def list_sweep():
    out = {}
    for size in (256, 4096):   # only sizes the tests probe
        for pol in ("volatile", "izraelevitz", "nvtraverse"):
            out[(size, pol)] = run_workload("list", pol, size=size,
                                            update_pct=20, n_ops=150)
    return out


@pytest.mark.slow     # shares the ~25s list_sweep fixture
def test_nvtraverse_vs_izraelevitz_in_paper_band(list_sweep):
    """Paper §5.2: 13.5×–39.6× over Izraelevitz on lists, growing with
    size (256→8192).  Our cost model must land inside/near that band and
    reproduce the growth."""
    r256 = (list_sweep[(256, "izraelevitz")]["t_op_us"]
            / list_sweep[(256, "nvtraverse")]["t_op_us"])
    r4096 = (list_sweep[(4096, "izraelevitz")]["t_op_us"]
             / list_sweep[(4096, "nvtraverse")]["t_op_us"])
    assert 10.0 < r256 < 45.0, r256
    assert 20.0 < r4096 < 60.0, r4096
    assert r4096 > r256          # the gap grows with traversal length


@pytest.mark.slow     # shares the ~25s list_sweep fixture
def test_volatile_gap_closes_with_size(list_sweep):
    """Paper §5.2: non-durable wins ~2.9× on small lists; the difference
    'becomes less pronounced, and even inverts, as the list grows'."""
    g256 = (list_sweep[(256, "nvtraverse")]["t_op_us"]
            / list_sweep[(256, "volatile")]["t_op_us"])
    g4096 = (list_sweep[(4096, "nvtraverse")]["t_op_us"]
             / list_sweep[(4096, "volatile")]["t_op_us"])
    assert g256 > 1.15           # durability costs something when short
    assert g4096 < 1.10          # ...and almost nothing when long
    assert g4096 < g256


@pytest.mark.slow     # shares the ~25s list_sweep fixture
def test_fence_economics_mechanism(list_sweep):
    """The mechanism: NVTraverse fences are O(1)/op, Izraelevitz O(path)."""
    for size in (256, 4096):
        assert list_sweep[(size, "nvtraverse")]["fences_per_op"] < 4
    assert (list_sweep[(4096, "izraelevitz")]["fences_per_op"]
            > 0.8 * 4096 * 0.9)  # ~= nodes traversed


@pytest.mark.parametrize("structure", ["hash", "bst", "skiplist"])
def test_other_structures_same_economy(structure):
    nv = run_workload(structure, "nvtraverse", size=512, update_pct=20,
                      n_ops=100)
    iz = run_workload(structure, "izraelevitz", size=512, update_pct=20,
                      n_ops=100)
    assert nv["fences_per_op"] < 5
    assert iz["t_op_us"] / nv["t_op_us"] > 2.5, structure
    # hash table: short chains => small Izraelevitz gap (paper fig 5d);
    # bst/skiplist: log-depth traversals => bigger gap (figs 5e, 5f)
    if structure != "hash":
        assert iz["t_op_us"] / nv["t_op_us"] > 5.0
