"""GPipe pipeline-parallel schedule: correctness vs the unpipelined stack
(runs on 4 host devices in a subprocess)."""
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training.pipeline import (gpipe_forward, init_pipeline_params,
                                     make_gpipe_fn, mlp_block)

S, LPS, D, F = 4, 2, 16, 32
mesh = jax.make_mesh((4,), ("stage",))
params = init_pipeline_params(jax.random.PRNGKey(0), n_stages=S,
                              layers_per_stage=LPS, d_model=D, d_ff=F)
M, B, T = 6, 2, 8
x = jax.random.normal(jax.random.PRNGKey(1), (M, B, T, D))

# reference: plain sequential stack
ref = x
flat = jax.tree.map(lambda a: a.reshape((S * LPS,) + a.shape[2:]), params)
def body(h, lp):
    return mlp_block(lp, h), None
ref, _ = jax.lax.scan(lambda h, lp: (mlp_block(lp, h), None),
                      x.reshape(M * B, T, D),
                      flat)
ref = ref.reshape(M, B, T, D)

fn = make_gpipe_fn(mesh, n_stages=S)
psh = jax.tree.map(lambda a: jax.device_put(
    a, NamedSharding(mesh, P("stage"))), params)
out = jax.jit(fn)(psh, x)
# the pipeline output is valid on the last stage; fetch global view
err = float(jnp.max(jnp.abs(out - ref)))
print("PIPE_ERR", err)
assert err < 1e-4, err
print("PIPE_OK")
"""


@pytest.mark.slow     # ~7 min: 4-host-device XLA compile in a subprocess
def test_gpipe_matches_sequential():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"},
                         cwd=Path(__file__).parent.parent, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "PIPE_OK" in out.stdout
