"""Fast CI variant of the multi-pod dry-run: tiny configs on an 8-host-
device (2,2,2) pod mesh in a subprocess (the 512-device production matrix
runs via launch/dryrun.py; its artifacts are validated here too)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch, tiny
from repro.launch.cells import make_cell, lower_cell

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
results = {}
for name in ["qwen3-1.7b", "qwen2-moe-a2.7b", "mamba2-370m", "zamba2-7b",
             "whisper-medium"]:
    cfg = tiny(get_arch(name))
    cfg = dataclasses.replace(cfg, d_model=64, n_heads=2, n_kv_heads=2,
                              d_head=32, microbatches=2)
    for kind, shape in [("train", ShapeConfig("t", 64, 8, "train")),
                        ("decode", ShapeConfig("d", 64, 8, "decode"))]:
        cell = make_cell(cfg, shape, mesh)
        compiled = lower_cell(cell, mesh).compile()
        results[f"{name}:{kind}"] = compiled.memory_analysis(
            ).temp_size_in_bytes
print("OK", len(results))
"""


@pytest.mark.slow
def test_small_multipod_mesh_lowers():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"},
                         cwd=Path(__file__).parent.parent, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK 10" in out.stdout


def test_production_dryrun_artifacts_complete():
    """The 512-device matrix must exist and be failure-free: 80 cells =
    10 archs x 4 shapes x 2 meshes, each 'ok' or a documented skip."""
    d = Path(__file__).parent.parent / "benchmarks/results/dryrun"
    if not d.exists():
        pytest.skip("production dry-run not executed in this checkout")
    recs = [json.loads(p.read_text()) for p in d.glob("*.json")]
    assert len(recs) >= 80
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert not by_status.get("error"), [
        (r["arch"], r["shape"]) for r in by_status["error"]]
    assert len(by_status.get("skipped", [])) == 14   # 7 archs x 2 meshes
    # every ok cell carries the memory analysis the roofline needs
    for r in by_status["ok"]:
        assert "temp_size_in_bytes" in r and "argument_size_in_bytes" in r
    # single-pod ok cells carry extrapolated cost terms
    singles = [r for r in by_status["ok"] if r["mesh"] == "single"]
    assert all("cost" in r for r in singles)
