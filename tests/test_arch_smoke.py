"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one prefill/decode step on CPU; asserts shapes + no
NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch, tiny
from repro.models.model import build_model

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S + 1), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[1], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["vis"] = jax.random.normal(
            ks[1], (B, cfg.vis_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_grad(name):
    cfg = tiny(get_arch(name))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), name
    assert float(loss) > 0
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(l))) for l in leaves)
    # gradient must reach the embedding and the deepest block params
    gnorm = sum(float(jnp.sum(jnp.abs(l))) for l in leaves)
    assert gnorm > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_consistency(name):
    """Greedy decode over the prompt suffix must match teacher forcing."""
    cfg = tiny(get_arch(name))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_len = S + 8 + (cfg.vis_tokens if cfg.family == "vlm" else 0)

    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :S]       # prompt
    logits_pre, caches = jax.jit(
        lambda p, b: model.prefill(p, b, max_len))(params, pre_batch)
    assert np.all(np.isfinite(np.asarray(logits_pre, np.float32)))

    # one decode step must equal the teacher-forced next-position logits
    next_tok = batch["tokens"][:, S]
    pos = S + (cfg.vis_tokens if cfg.family == "vlm" else 0)
    logits_dec, caches = jax.jit(model.decode_step)(
        params, next_tok, caches, jnp.int32(pos))
    assert logits_dec.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits_dec, np.float32)))

    full_batch = dict(batch)
    full_batch["tokens"] = jnp.concatenate(
        [batch["tokens"][:, :S], next_tok[:, None]], axis=1)
    logits_tf, _ = jax.jit(
        lambda p, b: model.prefill(p, b, max_len + 1))(params, full_batch)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_tf[:, 0], np.float32), rtol=2e-2, atol=2e-2)


def test_param_counts_match_analytics():
    """init() parameter count must match ArchConfig.n_params analytics
    (within the small terms the analytic formula rounds away)."""
    for name in sorted(ARCHS):
        cfg = tiny(get_arch(name))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.n_params()
        assert abs(actual - analytic) / max(actual, 1) < 0.15, (
            name, actual, analytic)


def test_full_configs_match_brief():
    """Exact numbers from the assignment brief."""
    a = get_arch("arctic-480b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads) == (35, 7168, 56, 8)
    assert (a.n_experts, a.top_k, a.d_ff, a.vocab) == (128, 2, 4864, 32000)
    assert a.moe_dense_residual
    q = get_arch("qwen2-moe-a2.7b")
    assert (q.n_experts, q.top_k, q.n_shared_experts) == (60, 4, 4)
    g = get_arch("gemma3-27b")
    assert (g.n_layers, g.d_model, g.d_ff, g.vocab) == (62, 5376, 21504, 262144)
    assert (g.local_per_global, g.n_kv_heads) == (5, 16)
    m = get_arch("mamba2-370m")
    assert (m.n_layers, m.d_model, m.ssm_state, m.vocab) == (48, 1024, 128, 50280)
    z = get_arch("zamba2-7b")
    assert (z.n_layers, z.d_model, z.ssm_state, z.vocab) == (81, 3584, 64, 32000)
    assert z.shared_attn_every > 0
    w = get_arch("whisper-medium")
    assert (w.n_layers, w.enc_layers, w.d_model, w.vocab) == (24, 24, 1024, 51865)
    i = get_arch("internvl2-26b")
    assert (i.n_layers, i.d_model, i.n_heads, i.n_kv_heads, i.d_ff,
            i.vocab) == (48, 6144, 48, 8, 16384, 92553)
    for nm, L, D, H, K, F, V in [
            ("qwen3-1.7b", 28, 2048, 16, 8, 6144, 151936),
            ("qwen1.5-32b", 64, 5120, 40, 40, 27392, 152064),
            ("qwen2-7b", 28, 3584, 28, 4, 18944, 152064)]:
        c = get_arch(nm)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, D, H, K, F, V), nm


@pytest.mark.parametrize("name", ["qwen3-1.7b", "gemma3-27b"])
def test_blocked_attention_matches_naive(name):
    """§Perf path equivalence: blocked (XLA-flash) == naive logits."""
    import dataclasses
    cfg = tiny(get_arch(name))
    cfg_b = dataclasses.replace(cfg, attn_impl="blocked", attn_chunk=16)
    m1, m2 = build_model(cfg), build_model(cfg_b)
    params = m1.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1 = jax.jit(m1.loss)(params, batch)
    l2 = jax.jit(m2.loss)(params, batch)
    assert float(l1) == pytest.approx(float(l2), rel=2e-4)
    g1 = jax.jit(jax.grad(m1.loss))(params, batch)
    g2 = jax.jit(jax.grad(m2.loss))(params, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_fused_projections_match_unfused():
    """§Perf fusion: packing unfused wq/wk/wv (and gate|up) into the fused
    layout must give bit-identical logits."""
    import dataclasses
    cfg = tiny(get_arch("qwen2-7b"))          # has qkv biases
    cfg_f = dataclasses.replace(cfg, fused_qkv=True, fused_gate_up=True)
    m, mf = build_model(cfg), build_model(cfg_f)
    params = m.init(jax.random.PRNGKey(0))

    def pack_block(b):
        a = dict(b["attn"])
        a["wqkv"] = jnp.concatenate([a.pop("wq"), a.pop("wk"),
                                     a.pop("wv")], axis=1)
        if "bq" in a:
            a["bqkv"] = jnp.concatenate([a.pop("bq"), a.pop("bk"),
                                         a.pop("bv")])
        ml = dict(b["mlp"])
        ml["w_gate_up"] = jnp.concatenate([ml.pop("w_gate"),
                                           ml.pop("w_up")], axis=1)
        return {**b, "attn": a, "mlp": ml}

    fused = dict(params)
    fused["blocks"] = jax.vmap(pack_block)(params["blocks"])
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1 = float(jax.jit(m.loss)(params, batch))
    l2 = float(jax.jit(mf.loss)(fused, batch))
    assert l1 == pytest.approx(l2, rel=1e-6)


@pytest.mark.parametrize("name", ["mamba2-370m", "zamba2-7b", "gemma3-27b"])
def test_long_context_decode_path(name):
    """The sub-quadratic archs that run long_500k: exercise an actually-
    long decode (reduced dims, 2k cache) — ring-correctness of positions,
    window masks, and SSM state carry at depth."""
    import dataclasses
    cfg = tiny(get_arch(name))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S, extra = 48, 3
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, S),
                                          0, cfg.vocab)}
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, 2048))(params, batch)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    for i in range(extra):
        logits, caches = decode(params, tok, caches, jnp.int32(S + i))
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
