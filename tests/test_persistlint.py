"""PersistLint: every rule proven live by a mutation that trips it,
plus clean-run zero-violation assertions over the repo and the four
durable layers.

The trace mutations operate on *recorded real streams* (delete the
fence that dominated a publish, drop the flush that covered a commit)
— deleting an event from a clean trace of the actual layer is exactly
the "what if this instruction were missing" experiment, without
monkeypatching the IO (whose forgiving simulator would mask the bug:
a skipped StagedIO.fence would crash the run at the publish rename,
not silently corrupt)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.checker import check_events
from repro.analysis.persistlint import run_static
from repro.analysis.trace import (EVENT_KINDS, PersistEvent, PersistTrace,
                                  trace_scenario)
from repro.core.harris_list import HarrisList
from repro.core.pmem import PMem
from repro.core.policies import NVTraversePolicy
from repro.core.traversal import run_operation
from repro.persistence.manifest import StagedIO
from repro.robustness import KINDS
from repro.robustness import faultinject

REPO = Path(__file__).resolve().parents[1]
LAYERS = ("log", "log2", "checkpoint", "migrate", "rebalance")


def E(i, kind, target="", src=None, in_traverse=False):
    return PersistEvent(i, kind, target, src, in_traverse)


# --------------------------------------------------------------------- #
# shared KINDS registry                                                  #
# --------------------------------------------------------------------- #
def test_kinds_registry_is_shared():
    assert KINDS == ("flush", "fence", "publish", "trim")
    assert faultinject.KINDS is KINDS          # one object, one registry
    assert set(KINDS) < set(EVENT_KINDS)
    assert "write" in EVENT_KINDS


def test_unknown_kind_fails_loudly_everywhere():
    with pytest.raises(AssertionError):
        faultinject.CrashPlan().on_site("frobnicate", "x")
    with pytest.raises(ValueError):
        PersistTrace().on_event("frobnicate", "x")
    with pytest.raises(ValueError):
        check_events([E(0, "frobnicate", "x")])


# --------------------------------------------------------------------- #
# clean runs: the repo and every layer satisfy the discipline            #
# --------------------------------------------------------------------- #
def test_static_repo_is_clean_with_exactly_the_known_waivers():
    rep = run_static()
    assert rep.ok, [v.to_dict() for v in rep.violations]
    assert sorted((v.file, v.rule) for v in rep.waived) == [
        ("serving/engine.py", "raw-durable-io"),
        ("serving/engine.py", "raw-durable-io"),
    ]


@pytest.mark.parametrize("layer", LAYERS)
def test_trace_layer_is_clean(layer):
    tr = trace_scenario(layer)
    rep = check_events(tr.events)
    assert rep.n_events > 10
    assert rep.ok, [f.to_dict() for f in rep.violations]
    # the layers are not just correct but waste-free today; if a future
    # change makes a diagnostic legitimate, loosen this line, not ok
    assert rep.diagnostics == [], [f.to_dict() for f in rep.diagnostics]
    # the trace rides the same attach surface the crash sweep uses
    assert len(tr.sites) > 0 and tr.fired_at is None


# --------------------------------------------------------------------- #
# trace mutations: delete/insert instructions in a real recorded stream #
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def log_events():
    return trace_scenario("log").events


def test_mutation_deleted_fence_fires_publish_before_persist(log_events):
    # strip the fence that dominates the last snapshot publish
    pubs = [e for e in log_events if e.kind == "publish" and e.src]
    assert pubs, "log layer publishes snapshots"
    target_pub = pubs[-1]
    fences = [e for e in log_events
              if e.kind == "fence" and e.index < target_pub.index]
    mutated = [e for e in log_events if e.index != fences[-1].index]
    rep = check_events(mutated)
    assert [f.rule for f in rep.violations] == ["publish-before-persist"]
    assert rep.violations[0].target == target_pub.src


def test_mutation_dropped_flush_fires_missing_flush(log_events):
    # drop the flush covering the last snapshot's payload: its publish
    # then renames bytes that were written but never flushed
    pub = [e for e in log_events if e.kind == "publish" and e.src][-1]
    victim = [e for e in log_events
              if e.kind == "flush" and e.target == pub.src
              and e.index < pub.index][-1]
    mutated = [e for e in log_events if e.index != victim.index]
    rep = check_events(mutated)
    assert [f.rule for f in rep.violations] == ["missing-flush"]
    assert rep.violations[0].target == victim.target
    assert rep.violations[0].index == pub.index


def test_mutation_inserted_traverse_flush_fires(log_events):
    mutated = list(log_events) + [
        E(len(log_events), "flush", "line:7", in_traverse=True)]
    rep = check_events(mutated, end_check=False)
    assert [f.rule for f in rep.violations] == ["traversal-phase-persistence"]


def test_mutation_duplicated_flush_is_diagnostic_only(log_events):
    first_flush = next(e for e in log_events if e.kind == "flush")
    mutated = (log_events[:first_flush.index + 1]
               + [first_flush] + log_events[first_flush.index + 1:])
    rep = check_events(mutated)
    assert rep.ok
    assert [f.rule for f in rep.diagnostics] == ["redundant-flush"]


def test_mutation_trailing_fence_is_diagnostic_only(log_events):
    mutated = list(log_events) + [E(len(log_events), "fence")]
    rep = check_events(mutated)
    assert rep.ok
    assert [f.rule for f in rep.diagnostics] == ["fence-with-nothing-pending"]


# --------------------------------------------------------------------- #
# live mutations: real IO under a trace, discipline broken on purpose    #
# --------------------------------------------------------------------- #
def test_live_write_after_flush_before_fence(tmp_path):
    """The forgiving StagedIO simulator persists the newest bytes at the
    fence; the checker's strict clwb model flags the unflushed tail."""
    io = StagedIO(tmp_path)
    tr = PersistTrace().attach(io)
    io.write("a.tmp", b"v1")
    io.flush("a.tmp")
    io.write("a.tmp", b"v2")           # after the flush: not covered
    io.fence()
    io.publish("a.tmp", "a")
    rep = check_events(tr.events)
    assert [f.rule for f in rep.violations] == ["missing-flush"]
    assert [f.rule for f in rep.diagnostics] == ["fence-with-nothing-pending"]


def test_live_clean_staged_cycle(tmp_path):
    io = StagedIO(tmp_path)
    tr = PersistTrace().attach(io)
    io.write("a.tmp", b"v")
    io.flush("a.tmp")
    io.fence()
    io.publish("a.tmp", "a")
    io.unlink("a")
    assert [e.kind for e in tr.events] == [
        "write", "flush", "fence", "publish", "trim"]
    assert check_events(tr.events).ok


def test_live_pmem_stream_and_cas_payload():
    mem = PMem(256, line_words=8)
    tr = PersistTrace().attach(mem)
    mem.write(8, 42)
    mem.flush(8)
    mem.fence()
    assert mem.cas(16, 0, 99)
    kinds = [e.kind for e in tr.events]
    assert kinds == ["write", "flush", "fence", "publish", "write"]
    rep = check_events(tr.events, end_check=False)
    assert rep.ok and rep.diagnostics == []


def test_live_leaky_policy_fires_traversal_phase():
    """A policy that flushes during the journey is the paper's core sin;
    the checker sees it through the in_traverse bit on real PMem ops."""
    class LeakyPolicy(NVTraversePolicy):
        def after_read(self, ctx, addr, *, immutable):
            ctx.flush(addr)            # regardless of phase: leaks

    mem = PMem(1 << 12)
    ds = HarrisList(mem)
    tr = PersistTrace().attach(mem)
    run_operation(ds, LeakyPolicy(), "insert", (5, 50))
    run_operation(ds, LeakyPolicy(), "find", (5,))
    rep = check_events(tr.events, end_check=False)
    bad = [f for f in rep.violations
           if f.rule == "traversal-phase-persistence"]
    assert bad, "leaky traversal flush not detected"
    # and the unmutated policy on the same workload is silent
    mem2 = PMem(1 << 12)
    ds2 = HarrisList(mem2)
    tr2 = PersistTrace().attach(mem2)
    run_operation(ds2, NVTraversePolicy(), "insert", (5, 50))
    run_operation(ds2, NVTraversePolicy(), "find", (5,))
    rep2 = check_events(tr2.events, end_check=False)
    assert not [f for f in rep2.violations
                if f.rule == "traversal-phase-persistence"]


# --------------------------------------------------------------------- #
# static mutations: seeded source-level violations, one rule each        #
# --------------------------------------------------------------------- #
DURABLE_HEADER = "from repro.persistence.manifest import StagedIO\n"


def _lint(tmp_path, source, name="mutant.py"):
    p = tmp_path / name
    p.write_text(source)
    rep = run_static(files=[p])
    return rep


def test_static_publish_without_fence(tmp_path):
    rep = _lint(tmp_path, DURABLE_HEADER + (
        "def save(io):\n"
        "    io.write('m.tmp', b'x')\n"
        "    io.flush('m.tmp')\n"
        "    io.publish('m.tmp', 'm')\n"))
    assert [v.rule for v in rep.violations] == ["publish-needs-fence"]


def test_static_write_between_fence_and_publish(tmp_path):
    rep = _lint(tmp_path, DURABLE_HEADER + (
        "def save(io):\n"
        "    io.write('m.tmp', b'x')\n"
        "    io.flush('m.tmp')\n"
        "    io.fence()\n"
        "    io.write('n.tmp', b'y')\n"
        "    io.publish('m.tmp', 'm')\n"))
    assert [v.rule for v in rep.violations] == ["publish-needs-fence"]


def test_static_fence_dominated_publish_is_clean(tmp_path):
    rep = _lint(tmp_path, DURABLE_HEADER + (
        "def save(io):\n"
        "    io.write('m.tmp', b'x')\n"
        "    io.flush('m.tmp')\n"
        "    io.fence()\n"
        "    io.publish('m.tmp', 'm')\n"))
    assert rep.ok and not rep.waived


def test_static_raw_io_only_in_durable_modules(tmp_path):
    body = "import os\ndef f(p):\n    os.replace(p, p)\n"
    assert [v.rule for v in run_static(
        files=[_write(tmp_path, "a.py", DURABLE_HEADER + body)]
    ).violations] == ["raw-durable-io"]
    # same call in a module that never touches StagedIO: not durable
    assert run_static(files=[_write(tmp_path, "b.py", body)]).ok


def _write(tmp_path, name, source):
    p = tmp_path / name
    p.write_text(source)
    return p


def test_static_flush_in_traverse_method(tmp_path):
    rep = _lint(tmp_path, (
        "class DS:\n"
        "    def traverse(self, ctx, entry):\n"
        "        ctx.flush(entry)\n"
        "        return entry\n"
        "    def critical(self, ctx, tr):\n"
        "        ctx.flush(3)\n"          # fine: destination phase
        "        return tr\n"))
    assert [v.rule for v in rep.violations] == ["traverse-phase-persistence"]
    assert rep.violations[0].line == 3


def test_static_flush_in_traverse_window(tmp_path):
    rep = _lint(tmp_path, (
        "def run(ctx, ds, Phase):\n"
        "    ctx.enter(Phase.TRAVERSE)\n"
        "    ctx.flush(1)\n"
        "    ctx.enter(Phase.CRITICAL)\n"
        "    ctx.flush(2)\n"              # fine: destination phase
        "    ctx.fence()\n"))
    assert [v.rule for v in rep.violations] == ["traverse-phase-persistence"]
    assert rep.violations[0].line == 3


def test_static_unregistered_site_kind(tmp_path):
    rep = _lint(tmp_path, (
        "def f(self):\n"
        "    self.faults.on_site('frobnicate', 'x')\n"
        "    self.faults.on_site('flush', 'x')\n"))
    assert [v.rule for v in rep.violations] == ["crash-site-kinds"]
    assert rep.violations[0].line == 2


def test_static_waiver_suppresses_and_is_counted(tmp_path):
    rep = _lint(tmp_path, DURABLE_HEADER + (
        "import os\n"
        "def f(p):\n"
        "    # persistlint: waive(raw-durable-io) — test justification\n"
        "    os.replace(p, p)\n"))
    assert rep.ok
    assert [v.rule for v in rep.waived] == ["raw-durable-io"]


# --------------------------------------------------------------------- #
# the CLI                                                                #
# --------------------------------------------------------------------- #
def test_cli_static_exits_zero_and_reports_waivers(tmp_path):
    out = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "persist_lint.py"),
         "--static", "--json", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rep = json.loads(out.read_text())
    assert rep["ok"] and rep["static"]["ok"]
    assert rep["static"]["n_waived"] == 2
